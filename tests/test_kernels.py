"""Per-kernel shape/dtype sweeps: pallas_call (interpret) vs ref.py oracle
vs the numpy host codec (three-way agreement)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.coders import DiscreteCoder, quantize_freqs  # noqa: E402
from repro.core.vectorized import encode_batch  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def _coder(rng, n):
    w = 1.0 / np.arange(1, n + 1) ** rng.uniform(0.4, 1.8)
    return DiscreteCoder(quantize_freqs(w * 1e7))


class TestAliasDecodeKernel:
    @pytest.mark.parametrize("n_symbols", [1, 2, 37, 255, 1000])
    @pytest.mark.parametrize("n_codes", [17, 1024, 4097])
    def test_sweep(self, n_symbols, n_codes):
        rng = np.random.default_rng(n_symbols * 1000 + n_codes)
        dc = _coder(rng, n_symbols)
        table, m = ref.pack_tables(dc)
        codes = rng.integers(0, 65536, n_codes).astype(np.int32)
        sym_k, a_k, k_k = ops.alias_decode(jnp.asarray(codes), table, m)
        sym_r, a_r, k_r = ref.alias_decode_ref(jnp.asarray(codes), table, m)
        sym_c, a_c, k_c = dc.inv_translate_batch(codes)
        np.testing.assert_array_equal(np.asarray(sym_k), sym_c)
        np.testing.assert_array_equal(np.asarray(a_k), a_c)
        np.testing.assert_array_equal(np.asarray(k_k), k_c)
        np.testing.assert_array_equal(np.asarray(sym_r), sym_c)


class TestDelayedDecodeKernel:
    @pytest.mark.parametrize("n_slots,n_tuples", [(1, 64), (5, 300), (24, 130)])
    def test_sweep(self, n_slots, n_tuples):
        rng = np.random.default_rng(n_slots * 7 + n_tuples)
        coders = [_coder(rng, int(rng.integers(2, 400)))
                  for _ in range(n_slots)]
        syms = np.stack([rng.integers(0, c.tables.n_symbols, n_tuples)
                         for c in coders], axis=1)
        codes_csr, offsets = encode_batch(syms, coders)
        dense = ops.dense_codes(codes_csr.astype(np.int64), offsets, n_slots)
        tables, mbits = ops.pack_slot_tables(coders)
        out_k = np.asarray(ops.delayed_decode(jnp.asarray(dense), tables,
                                              mbits))
        out_r = np.asarray(ref.delayed_decode_ref(jnp.asarray(dense), tables,
                                                  mbits))
        np.testing.assert_array_equal(out_r, syms)
        np.testing.assert_array_equal(out_k, syms)

    def test_skewed_distributions_stress_virtual_bits(self):
        """Highly skewed slots mark nearly every interval (max virtual use)."""
        w = np.ones(3)
        w[0] = 1e6  # one dominant symbol -> k ~ 2**16 -> constant marking
        coders = [DiscreteCoder(quantize_freqs(w)) for _ in range(30)]
        syms = np.zeros((50, 30), np.int64)
        syms[:, ::7] = 1
        codes_csr, offsets = encode_batch(syms, coders)
        dense = ops.dense_codes(codes_csr.astype(np.int64), offsets, 30)
        tables, mbits = ops.pack_slot_tables(coders)
        out = np.asarray(ops.delayed_decode(jnp.asarray(dense), tables, mbits))
        np.testing.assert_array_equal(out, syms)


class TestKVAttentionKernel:
    @pytest.mark.parametrize("B,S,K,G,D", [
        (1, 256, 1, 1, 64), (2, 1024, 4, 3, 64), (2, 512, 8, 2, 128),
    ])
    @pytest.mark.parametrize("qdtype", [np.float32, jnp.bfloat16])
    def test_sweep(self, B, S, K, G, D, qdtype):
        rng = np.random.default_rng(B * S + K)
        H = K * G
        q = rng.normal(size=(B, H, D)).astype(np.float32)
        kf = rng.normal(size=(B, S, K, D)).astype(np.float32)
        vf = rng.normal(size=(B, S, K, D)).astype(np.float32)
        ks = np.abs(kf).max(-1) / 127.0 + 1e-8
        vs = np.abs(vf).max(-1) / 127.0 + 1e-8
        kq = np.clip(np.round(kf / ks[..., None]), -127, 127).astype(np.int8)
        vq = np.clip(np.round(vf / vs[..., None]), -127, 127).astype(np.int8)
        L = S - S // 3
        qj = jnp.asarray(q).astype(qdtype)
        out_k = np.asarray(ops.kv_attention_int8(
            qj, jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq),
            jnp.asarray(vs), L, chunk=min(512, S)))
        out_r = np.asarray(ref.kv_attention_int8_ref(
            jnp.asarray(qj, jnp.float32), jnp.asarray(kq), jnp.asarray(vq),
            jnp.asarray(ks), jnp.asarray(vs), jnp.asarray(L)))
        tol = 5e-2 if qdtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(out_k, out_r, atol=tol, rtol=tol)

    def test_quantization_error_bounded(self):
        """int8 semantic quantization keeps attention output close to fp."""
        rng = np.random.default_rng(3)
        B, S, K, G, D = 1, 512, 2, 2, 64
        q = rng.normal(size=(B, K * G, D)).astype(np.float32)
        kf = rng.normal(size=(B, S, K, D)).astype(np.float32)
        vf = rng.normal(size=(B, S, K, D)).astype(np.float32)
        ks = np.abs(kf).max(-1) / 127.0 + 1e-8
        vs = np.abs(vf).max(-1) / 127.0 + 1e-8
        kq = np.clip(np.round(kf / ks[..., None]), -127, 127).astype(np.int8)
        vq = np.clip(np.round(vf / vs[..., None]), -127, 127).astype(np.int8)
        out_q = np.asarray(ops.kv_attention_int8(
            jnp.asarray(q), jnp.asarray(kq), jnp.asarray(ks),
            jnp.asarray(vq), jnp.asarray(vs), S))
        # fp reference attention (unquantized)
        import jax
        qf = q.reshape(B, K, G, D) * (D ** -0.5)
        s = np.einsum("bkgd,bskd->bkgs", qf, kf)
        p = np.asarray(jax.nn.softmax(jnp.asarray(s), axis=-1))
        out_f = np.einsum("bkgs,bskd->bkgd", p, vf).reshape(B, K * G, D)
        assert np.abs(out_q - out_f).max() < 0.05


class TestFlashPrefillKernel:
    """Fused prefill attention (§Perf cell-3 structural fix) vs the XLA
    chunked-attention reference across shapes, masks and dtypes."""

    @pytest.mark.parametrize("B,Sq,Sk,K,G,D,causal,win", [
        (2, 128, 128, 2, 3, 64, True, 0),
        (1, 200, 200, 4, 1, 32, True, 48),
        (2, 96, 160, 2, 2, 64, False, 0),
        (1, 64, 64, 1, 8, 128, True, 0),
    ])
    def test_matches_chunked_attention(self, B, Sq, Sk, K, G, D, causal, win):
        import jax
        from repro.kernels.flash_prefill import flash_prefill_attention
        from repro.models.layers import AttnSpec, chunked_attention
        rng = np.random.default_rng(B * Sq + Sk)
        H = K * G
        q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Sk, K, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Sk, K, D)), jnp.float32)
        out_k = flash_prefill_attention(q, k, v, causal=causal, window=win,
                                        q_block=64, kv_chunk=64)
        spec = AttnSpec(causal=causal, q_block=64, kv_chunk=64)
        out_r = chunked_attention(q, k, v, jnp.arange(Sq), spec,
                                  window=(win if win else None))
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16_io(self):
        from repro.kernels.flash_prefill import flash_prefill_attention
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        out = flash_prefill_attention(q, k, v, q_block=32, kv_chunk=32)
        assert out.dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(out, np.float32)).all()
