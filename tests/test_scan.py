"""Scan engine (DESIGN.md §8): pushdown == decode-then-filter, bit-identically.

The pushdown scan (zone-map pruning + code-space predicate eval + selective
decode) must return exactly what the decode-everything reference returns —
through tombstones, the delta overlay, revived keys, mixed plan versions,
and a spilled cold tier, on both decode backends.  A seeded random soak
always runs; a hypothesis property deepens the same invariant where
hypothesis is installed (CI installs it; lean local containers may not).
"""

import numpy as np
import pytest

from repro.adaptive import refit_codec
from repro.core.blitzcrank import ColumnSpec
from repro.db import Database, TableSchema
from repro.oltp.store import BlitzStore
from repro.scan import Eq, In, Range, match_all

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

COLS = [
    ColumnSpec("w", "cat"),
    ColumnSpec("id", "int", growth=8.0),
    ColumnSpec("qty", "int"),
    ColumnSpec("amt", "float", precision=0.01),
]
SCHEMA = TableSchema("t", COLS, "id")


def _row(i, rng):
    return {
        "w": f"w{int(rng.integers(0, 6))}",
        "id": int(i),
        "qty": int(rng.integers(1, 50)),
        "amt": round(float(rng.uniform(0.0, 1000.0)), 2),
    }


def _pred_pool(n):
    return [
        [],
        [Eq("w", "w2")],
        [In("w", ("w0", "w5"))],
        [Eq("w", "nope")],
        [Range("id", lo=int(n * 0.9))],
        [Range("qty", lo=10, hi=20)],
        [Range("amt", lo=250.0, hi=500.0)],
        [Range("id", lo=n // 2), Eq("w", "w1")],
        [Eq("qty", 7)],
        [In("id", (3, n // 2, n - 1, n * 10))],
    ]


def _build_table(n=600, n_shards=2, seed=0, memory_budget=None, churn=True):
    """A blitz table with mixed plan versions, overlay, tombstones, revives."""
    rng = np.random.default_rng(seed)
    rows = [_row(i, rng) for i in range(n)]
    db = Database(backend="blitzcrank", n_shards=n_shards)
    t = db.create_table(SCHEMA, sample_rows=rows[: n // 2], memory_budget=memory_budget)
    t.insert_many(rows[: n // 2])
    # Install a refit codec so later inserts encode under plan v1 while the
    # first half stays on v0 — the scan must handle both in one pass.
    for shard in t.shards:
        shard.install_codec(refit_codec(shard.codec, rows[: n // 4], ["amt"]))
    t.insert_many(rows[n // 2 :])
    deleted: set = set()
    if churn:
        for _ in range(3):
            ups = [int(k) for k in rng.choice(n, size=n // 8, replace=False)]
            upd = [k for k in ups if k not in deleted]
            t.update_many(
                upd,
                [
                    {
                        **t.get(k),
                        "qty": int(rng.integers(1, 50)),
                        "amt": round(float(rng.uniform(0.0, 1000.0)), 2),
                    }
                    for k in upd
                ],
            )
            dels = [
                int(k)
                for k in rng.choice(n, size=n // 10, replace=False)
                if int(k) not in deleted
            ]
            t.delete_many(dels)
            deleted.update(dels)
            revive = sorted(deleted)[: n // 20]
            t.insert_many([_row(k, rng) for k in revive])
            deleted.difference_update(revive)
        t.merge()
        # churn again after the merge so an unmerged overlay + fresh
        # tombstones shadow arena blocks during every scan below
        more = [
            int(k)
            for k in rng.choice(n, size=n // 10, replace=False)
            if int(k) not in deleted
        ]
        t.update_many(
            more, [{**t.get(k), "qty": int(rng.integers(1, 50))} for k in more]
        )
    return db, t


def _reference(t, preds, cols=None):
    out = {}
    for k, row in t.scan():
        if match_all(preds, row):
            out[k] = {c: row[c] for c in cols} if cols is not None else row
    return out


def _check_all_predicates(t, n, cols=None):
    for preds in _pred_pool(n):
        want = _reference(t, preds, cols)
        got = dict(t.scan_where(preds, columns=cols))
        ref = dict(t.scan_where(preds, columns=cols, pushdown=False))
        assert got == want, preds
        assert ref == want, preds


class TestPushdownEqualsReference:
    def test_seeded_soak(self):
        db, t = _build_table(n=600, n_shards=2, seed=1)
        _check_all_predicates(t, 600)
        _check_all_predicates(t, 600, cols=["id", "amt"])

    def test_single_shard(self):
        db, t = _build_table(n=300, n_shards=1, seed=2)
        _check_all_predicates(t, 300)

    def test_under_memory_budget(self):
        """Spilled cold tier: scans read extents through, results identical."""
        db, t = _build_table(n=900, n_shards=2, seed=3, memory_budget=1 << 12)
        res = t.shards[0].stats().get("residency")
        assert res is not None and res["spilled_bytes"] > 0
        _check_all_predicates(t, 900)

    def test_scan_does_not_promote(self):
        db, t = _build_table(n=900, n_shards=1, seed=4, memory_budget=1 << 12)

        def faults():
            return sum(s.stats()["residency"]["faults"] for s in t.shards)

        before = faults()
        hits, stats = t.scan_where([], with_stats=True)
        assert len(hits) == len(t)
        assert stats.spilled_reads > 0  # cold blocks were actually touched
        assert faults() == before  # ...but never faulted into the hot set

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    def test_property(self):
        @settings(
            max_examples=8, deadline=None, suppress_health_check=list(HealthCheck)
        )
        @given(seed=st.integers(0, 2**16), n_shards=st.integers(1, 3))
        def prop(seed, n_shards):
            db, t = _build_table(n=240, n_shards=n_shards, seed=seed)
            _check_all_predicates(t, 240)

        prop()


class TestBackendsBitIdentical:
    def test_numpy_vs_pallas(self):
        pytest.importorskip("jax")
        db, t = _build_table(n=400, n_shards=2, seed=5)
        for preds in _pred_pool(400):
            a = t.scan_where(preds, backend="numpy")
            b = t.scan_where(preds, backend="pallas")
            assert a == b, preds


class TestZoneMaps:
    def test_monotone_prune(self):
        """An insertion-ordered column prunes whole zone chunks untouched."""
        db, t = _build_table(n=2000, n_shards=1, seed=6, churn=False)
        hits, stats = t.scan_where([Range("id", lo=1800)], with_stats=True)
        assert {k for k, _ in hits} == set(range(1800, 2000))
        assert stats.blocks_pruned >= 1024
        assert stats.rows_decoded <= stats.blocks_total - stats.blocks_pruned

    def test_prune_never_drops_matches(self):
        db, t = _build_table(n=800, n_shards=2, seed=7)
        for lo, hi in [(0, 10), (700, 820), (400, 400), (-5, 3)]:
            preds = [Range("id", lo=lo, hi=hi)]
            assert dict(t.scan_where(preds)) == _reference(t, preds)

    def test_zone_survives_snapshot(self):
        """Zone maps persist through snapshot/restore (store level)."""
        rng = np.random.default_rng(8)
        rows = [_row(i, rng) for i in range(500)]
        store = BlitzStore(COLS, rows[:250])
        store.insert_many(rows)
        store.merge()
        before = store.scan_where([Range("id", lo=450)])
        clone = BlitzStore.from_state(COLS, store.snapshot_state())
        after = clone.scan_where([Range("id", lo=450)])
        assert before.ids == after.ids and before.rows == after.rows
        assert after.stats.blocks_pruned > 0


class TestCodeSpaceEval:
    def test_fast_paths_engaged(self):
        db, t = _build_table(n=800, n_shards=1, seed=9, churn=False)
        _, stats = t.scan_where([Eq("w", "w3")], with_stats=True)
        # either the slot-0 LUT or the prefix decode handled the fast
        # blocks; neither path materializes non-matching rows
        assert stats.blocks_lut + stats.rows_prefix_decoded > 0
        assert stats.rows_decoded < stats.blocks_total

    def test_impossible_literal(self):
        db, t = _build_table(n=400, n_shards=1, seed=10, churn=False)
        hits, stats = t.scan_where([Eq("w", "never-seen")], with_stats=True)
        assert hits == []
        # fast blocks are eliminated entirely in code space; only escaped
        # (slow) blocks still need their unconditional scalar decode
        assert stats.rows_decoded == stats.blocks_scalar
        assert stats.blocks_scalar < stats.blocks_total // 10


class TestAggregate:
    def test_matches_manual_groupby(self):
        db, t = _build_table(n=600, n_shards=2, seed=11)
        preds = [Range("qty", lo=5)]
        got = t.aggregate(
            preds,
            group_by=("w",),
            aggs={
                "n": ("count", None),
                "total": ("sum", "amt"),
                "mean": ("avg", "amt"),
                "lo": ("min", "qty"),
                "hi": ("max", "qty"),
            },
        )
        rows = [r for _, r in t.scan_where(preds)]
        want = {}
        for r in rows:
            g = want.setdefault((r["w"],), {"n": 0, "total": 0.0, "qs": []})
            g["n"] += 1
            g["total"] += r["amt"]
            g["qs"].append(r["qty"])
        assert set(got) == set(want)
        for g, w in want.items():
            assert got[g]["n"] == w["n"]
            assert got[g]["total"] == pytest.approx(w["total"])
            assert got[g]["mean"] == pytest.approx(w["total"] / w["n"])
            assert got[g]["lo"] == min(w["qs"])
            assert got[g]["hi"] == max(w["qs"])

    def test_database_query_routes(self):
        db, t = _build_table(n=300, n_shards=1, seed=12)
        preds = [Eq("w", "w1")]
        assert db.query("t", preds) == t.scan_where(preds)
        assert db.query("t", preds, aggs={"n": ("count", None)}) == t.aggregate(
            preds, aggs={"n": ("count", None)}
        )

    def test_unknown_column_raises(self):
        db, t = _build_table(n=200, n_shards=1, seed=13, churn=False)
        with pytest.raises(KeyError):
            t.scan_where([Eq("nope", 1)])
        with pytest.raises(KeyError):
            t.scan_where([], columns=["nope"])


class TestDurability:
    def test_extent_checkpoint_roundtrip(self, tmp_path):
        """Extent-mode checkpoints (offset, length references into a named
        spill file) reopen bit-identically; corrupting the spill file after
        the checkpoint forces the WAL full-history rebuild instead."""
        rng = np.random.default_rng(14)
        rows = [_row(i, rng) for i in range(1200)]
        spill = tmp_path / "t.spill"

        db = Database(
            backend="blitzcrank",
            durability=str(tmp_path / "root"),
            store_kwargs={"spill_path": str(spill)},
        )
        t = db.create_table(SCHEMA, sample_rows=rows[:200], memory_budget=1 << 12)
        t.insert_many(rows)
        upd = list(range(0, 1200, 7))
        t.update_many(upd, [{**t.get(k), "qty": 99} for k in upd])
        keys = list(range(1200))
        want = t.get_many(keys)
        assert t.shards[0].stats()["residency"]["spilled_bytes"] > 0
        db.close()  # final checkpoint references spill extents

        db2 = Database.open(str(tmp_path / "root"))
        assert db2["t"].get_many(keys) == want
        db2.close()

        # tear the spill file: recovery must fall back to WAL replay
        data = bytearray(spill.read_bytes())
        mid = len(data) // 2
        data[mid : mid + 64] = b"\xff" * 64
        spill.write_bytes(bytes(data))
        db3 = Database.open(str(tmp_path / "root"))
        assert db3["t"].get_many(keys) == want
        db3.close()
