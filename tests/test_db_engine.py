"""`repro.db` engine tests (DESIGN.md §5): schema validation, stable key
routing, the cross-shard property test (sharded Table == unsharded
reference under interleaved ops, incl. post-merge/post-migrate reads on
both decode backends), catalog behaviour, and the multi-table TPC-C mix.
"""

import numpy as np
import pytest

from repro.core import ColumnSpec
from repro.db import (Database, Table, TableSchema, stable_key_hash)
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore

ORDERLINE = TableSchema(
    "orderline", tpcc.ORDERLINE_SCHEMA, ("ol_o_id", "ol_number"))


def _gen_orderline_rows(n, seed=0):
    # distinct (ol_o_id, ol_number) pairs: the single-table generator
    # produces exactly 10 lines per order
    return tpcc.gen_orderline(n, seed=seed)


class TestSchema:
    def test_primary_key_validation(self):
        cols = [ColumnSpec("a", "int"), ColumnSpec("b", "float"),
                ColumnSpec("c", "cat")]
        with pytest.raises(ValueError, match="not declared"):
            TableSchema("t", cols, "nope")
        with pytest.raises(ValueError, match="float"):
            TableSchema("t", cols, "b")  # float keys re-quantize: rejected
        with pytest.raises(ValueError, match="empty"):
            TableSchema("t", cols, ())
        with pytest.raises(ValueError, match="repeated"):
            TableSchema("t", cols, ("a", "a"))
        with pytest.raises(ValueError, match="duplicate column"):
            TableSchema("t", cols + [ColumnSpec("a", "int")], "a")

    def test_key_of_scalar_and_composite(self):
        cols = [ColumnSpec("a", "int"), ColumnSpec("c", "cat")]
        assert TableSchema("t", cols, "a").key_of({"a": 7, "c": "x"}) == 7
        assert TableSchema("t", cols, ("c", "a")).key_of(
            {"a": 7, "c": "x"}) == ("x", 7)

    def test_schema_accepted_by_stores_and_codec(self):
        rows = _gen_orderline_rows(200)
        store = BlitzStore(ORDERLINE, rows)  # TableSchema, not a list
        store.insert_many(rows[:50])
        assert store.get(3) is not None
        assert [c.name for c in store.schema] == [
            c.name for c in ORDERLINE.columns
        ]

    def test_stable_hash_is_deterministic_and_typed(self):
        assert stable_key_hash((1, "2")) != stable_key_hash(("1", 2))
        # pinned constants: placement must be stable across processes/runs
        # (Python's own str hash is per-process randomized)
        assert stable_key_hash("x") == 9349625767463028147
        assert stable_key_hash((1, "TX", 42)) == 16384999691884931257
        with pytest.raises(TypeError):
            stable_key_hash(1.5)


def _interleave(table, ref, rows, rng, n_steps=40):
    """Drive random batched ops against table + plain-dict reference."""
    sch = table.schema
    for _ in range(n_steps):
        op = int(rng.integers(0, 4))
        if op == 0:  # insert fresh keys
            fresh = []
            for r in rows:
                if sch.key_of(r) not in ref and len(fresh) < 8:
                    fresh.append(r)
            rows = rows[len(fresh):]
            if fresh:
                table.insert_many(fresh)
                for r in fresh:
                    ref[sch.key_of(r)] = r
        elif op == 1 and ref:  # update live keys
            keys = list(ref)
            picks = [keys[int(i)] for i in
                     rng.integers(0, len(keys), min(6, len(keys)))]
            upd = []
            for k in dict.fromkeys(picks):
                r = dict(ref[k], ol_quantity=int(rng.integers(1, 60)))
                upd.append((k, r))
                ref[k] = r
            table.update_many([k for k, _ in upd], [r for _, r in upd])
        elif op == 2 and ref:  # delete some, incl. repeats
            keys = list(ref)
            picks = [keys[int(i)] for i in
                     rng.integers(0, len(keys), min(4, len(keys)))]
            expect = len(set(picks))
            assert table.delete_many(picks + picks[:1]) == expect
            for k in picks:
                ref.pop(k, None)
        else:  # batched reads incl. unknown keys
            keys = list(ref)[:10] + [(10**9, 1), (10**9, 2)]
            got = table.get_many(keys)
            for k, g in zip(keys, got):
                if k in ref:
                    assert (
                        g is not None
                        and g["ol_number"] == ref[k]["ol_number"]
                    )
                else:
                    assert g is None
    return rows


class TestShardRoutingProperty:
    """A sharded Table must be indistinguishable from an unsharded one
    (and from a plain dict) under any interleaving — the key routing
    invariant the engine is built on."""

    @pytest.mark.parametrize("n_shards", [1, 2, 7])
    def test_sharded_matches_reference_model(self, n_shards):
        rows = _gen_orderline_rows(600, seed=3)
        table = Table(ORDERLINE, backend="blitzcrank", n_shards=n_shards,
                      sample_rows=rows,
                      store_kwargs={"merge_min_bytes": 1 << 10})
        ref = {}
        table.insert_many(rows[:300])
        for r in rows[:300]:
            ref[ORDERLINE.key_of(r)] = r
        rng = np.random.default_rng(100 + n_shards)
        _interleave(table, ref, rows[300:], rng)
        # final sweep, batched via one get_many over every live key
        keys = list(ref)
        for k, g in zip(keys, table.get_many(keys)):
            assert g is not None
            for c in ORDERLINE.columns:
                if c.kind == "float":
                    assert (
                        abs(g[c.name] - ref[k][c.name])
                        <= c.precision / 2 + 1e-9
                    )
                else:
                    assert g[c.name] == ref[k][c.name]
        assert table.n_live == len(ref)
        assert len(list(table.scan())) == len(ref)

    @pytest.mark.parametrize("n_shards", [2, 7])
    def test_sharded_bit_identical_to_unsharded(self, n_shards):
        rows = _gen_orderline_rows(500, seed=4)
        sharded = Table(ORDERLINE, backend="blitzcrank",
                        n_shards=n_shards, sample_rows=rows)
        flat = Table(ORDERLINE, backend="blitzcrank", n_shards=1,
                     sample_rows=rows)
        for t in (sharded, flat):
            t.insert_many(rows)
        rng = np.random.default_rng(5)
        picks = [rows[int(i)] for i in rng.integers(0, len(rows), 120)]
        keys = [ORDERLINE.key_of(r) for r in picks]
        upd = {k: dict(r, ol_quantity=int(rng.integers(60, 90)))
               for k, r in zip(keys, picks)}
        for t in (sharded, flat):
            t.update_many(list(upd), list(upd.values()))
            t.delete_many(keys[:10])
        probe = [ORDERLINE.key_of(r) for r in rows[::3]]
        assert sharded.get_many(probe) == flat.get_many(probe)
        # post-merge() reads stay identical
        for t in (sharded, flat):
            t.merge()
        assert sharded.get_many(probe) == flat.get_many(probe)

    def test_post_merge_and_migrate_backend_identical(self):
        pytest.importorskip("jax")
        rows = _gen_orderline_rows(800, seed=6)
        table = Table(ORDERLINE, backend="blitzcrank", n_shards=3,
                      sample_rows=rows,
                      store_kwargs={"merge_min_bytes": 1 << 10})
        table.insert_many(rows)
        for shard in table.shards:
            assert shard.codec.compile() is not None
        rng = np.random.default_rng(7)
        keys = [ORDERLINE.key_of(rows[int(i)])
                for i in rng.integers(0, len(rows), 200)]
        got = table.get_many(keys)
        table.update_many(keys, [
            dict(r, ol_amount=round(float(rng.uniform(0.01, 20000.0)), 2))
            for r in got])
        table.merge()
        # install a refit plan on every shard, then migrate stale rows
        from repro.adaptive.refit import refit_codec
        for shard in table.shards:
            shard.install_codec(
                refit_codec(shard.codec, rows[:400], ["ol_amount"],
                            numeric_headroom=2.0))
        moved = table.migrate(limit=1 << 14)
        assert moved >= 0
        probe = [ORDERLINE.key_of(r) for r in rows[::2]]
        out_np = table.get_many(probe, backend="numpy")
        out_pl = table.get_many(probe, backend="pallas")
        assert out_np == out_pl  # bit-identical across decode backends
        # and identical to the per-shard scalar reference path
        for k, row in zip(probe, out_np):
            assert row == table.get(k)

    def test_shards_share_one_model_fit(self):
        rows = _gen_orderline_rows(400, seed=8)
        table = Table(ORDERLINE, backend="blitzcrank", n_shards=4,
                      sample_rows=rows)
        codecs = {id(s.codec) for s in table.shards}
        assert len(codecs) == 1  # fit once, shared
        flat = Table(ORDERLINE, backend="blitzcrank", n_shards=1,
                     sample_rows=rows)
        assert table.model_bytes == flat.model_bytes  # deduped accounting


class TestTableSemantics:
    def test_duplicate_insert_raises_and_revive_after_delete(self):
        rows = _gen_orderline_rows(100)
        table = Table(ORDERLINE, backend="silo", n_shards=2,
                      sample_rows=rows)
        table.insert_many(rows)
        with pytest.raises(ValueError, match="duplicate"):
            table.insert(rows[0])
        k = ORDERLINE.key_of(rows[0])
        assert table.delete(k) is True
        assert table.delete(k) is False  # idempotent
        assert table.get_many([k]) == [None]
        with pytest.raises(KeyError):
            table.get(k)
        with pytest.raises(KeyError):
            table.update(k, rows[0])
        table.insert(rows[0])  # revive in a fresh slot
        assert table.get(k)["ol_amount"] == rows[0]["ol_amount"]
        assert sum(1 for kk, _ in table.scan() if kk == k) == 1

    def test_update_cannot_change_primary_key(self):
        rows = _gen_orderline_rows(50)
        table = Table(ORDERLINE, backend="silo", sample_rows=rows)
        table.insert_many(rows[:20])
        k = ORDERLINE.key_of(rows[0])
        with pytest.raises(ValueError, match="primary key"):
            table.update(k, dict(rows[0], ol_number=99))

    def test_missing_column_rejected_on_insert(self):
        rows = _gen_orderline_rows(50)
        table = Table(ORDERLINE, backend="silo", sample_rows=rows)
        bad = dict(rows[0])
        del bad["ol_dist_info"]
        with pytest.raises(KeyError, match="ol_dist_info"):
            table.insert(bad)

    def test_lazy_shard_build_on_first_insert(self):
        table = Table(ORDERLINE, backend="silo", n_shards=3)
        assert table.get_many([(1, 1)]) == [None]
        rows = _gen_orderline_rows(60)
        table.insert_many(rows)
        assert table.n_live == 60 and len(table.shards) == 3


class TestDatabaseCatalog:
    def test_register_lookup_drop(self):
        db = Database(backend="silo")
        rows = _gen_orderline_rows(30)
        db.create_table(ORDERLINE, sample_rows=rows)
        assert "orderline" in db and db["orderline"].n_live == 0
        with pytest.raises(ValueError, match="already registered"):
            db.create_table(ORDERLINE)
        with pytest.raises(KeyError, match="registered"):
            db.table("nope")
        db.drop_table("orderline")
        assert "orderline" not in db

    def test_stats_aggregate_across_tables(self):
        rows = _gen_orderline_rows(200)
        db = Database(backend="silo", n_shards=2)
        t1 = db.create_table(ORDERLINE, sample_rows=rows)
        t1.insert_many(rows)
        other = TableSchema("ol2", tpcc.ORDERLINE_SCHEMA,
                            ("ol_o_id", "ol_number"))
        t2 = db.create_table(other, sample_rows=rows)
        t2.insert_many(rows[:100])
        s = db.stats()
        assert s["n_tables"] == 2
        assert s["n_live"] == 300 == db.n_live
        assert s["nbytes"] == t1.nbytes + t2.nbytes == db.nbytes
        assert set(s["tables"]) == {"orderline", "ol2"}


class TestMultiTableTPCC:
    @pytest.fixture(scope="class")
    def pop(self):
        return tpcc.generate_tpcc(n_warehouses=2, districts_per_wh=2,
                                  customers_per_district=30, n_items=80,
                                  orders_per_district=12, seed=1)

    @pytest.mark.parametrize("backend", ["silo", "blitzcrank", "raman"])
    def test_mix_runs_and_agrees_across_backends(self, pop, backend):
        db, _ = tpcc.build_tpcc_database(backend=backend, n_shards=2,
                                         population=pop)
        assert db.table_names == sorted(tpcc.TPCC_TABLES)
        counts = tpcc.run_tpcc_mix(db, 150, seed=2)
        assert counts["new_orders"] > 0 and counts["payments"] > 0
        assert counts["order_lines"] >= 5 * counts["new_orders"]
        # cross-table integrity: every inserted order's lines are readable
        orders = db["orders"]
        order_line = db["order_line"]
        for ok, orow in list(orders.scan())[-20:]:
            lk = [(ok[0], ok[1], ok[2], ln)
                  for ln in range(1, orow["o_ol_cnt"] + 1)]
            lines = order_line.get_many(lk)
            assert all(row is not None for row in lines)
            assert all(row["ol_o_id"] == ok[2] for row in lines)

    def test_mix_deterministic_across_backends(self, pop):
        counts = {}
        for backend in ("silo", "blitzcrank"):
            db, _ = tpcc.build_tpcc_database(backend=backend, n_shards=3,
                                             population=pop)
            counts[backend] = tpcc.run_tpcc_mix(db, 120, seed=5)
        assert counts["silo"] == counts["blitzcrank"]

    def test_zstd_backend_if_available(self, pop):
        pytest.importorskip("zstandard")
        db, _ = tpcc.build_tpcc_database(backend="zstd", n_shards=2,
                                         population=pop)
        counts = tpcc.run_tpcc_mix(db, 60, seed=3)
        assert counts["ops"] == 60

    def test_payment_moves_money(self, pop):
        db, _ = tpcc.build_tpcc_database(backend="silo", population=pop)
        w0 = db["warehouse"].get(1)["w_ytd"]
        tpcc.run_tpcc_mix(db, 200, seed=4, p_new_order=0.0, p_payment=1.0,
                          p_order_status=0.0, p_delivery=0.0)
        assert db["warehouse"].get(1)["w_ytd"] > w0

    def test_new_order_advances_district_and_stock(self, pop):
        db, _ = tpcc.build_tpcc_database(backend="silo", population=pop)
        before = {k: r["d_next_o_id"] for k, r in db["district"].scan()}
        n_orders = db["orders"].n_live
        tpcc.run_tpcc_mix(db, 120, seed=6, p_new_order=1.0, p_payment=0.0,
                          p_order_status=0.0, p_delivery=0.0)
        after = {k: r["d_next_o_id"] for k, r in db["district"].scan()}
        assert db["orders"].n_live - n_orders == 120
        assert sum(after[k] - before[k] for k in before) == 120
