"""Escape/fallback encode paths of the compiled slot plan (plan.py).

Every way a row can fail to conform — unseen category, out-of-range or
non-finite or non-numeric value, off-template or dictionary-miss string —
must (a) still roundtrip exactly through the scalar escape encoding, and
(b) charge the same per-column escape counters whether the row went through
the batch ``encode_rows`` masks or the scalar ``row_conforms`` probe
(unified accounting, DESIGN.md §4.1).
"""

import numpy as np
import pytest

from repro.core import ColumnSpec, CompressedTable, TableCodec

SCHEMA = [
    ColumnSpec("city", "cat"),
    ColumnSpec("qty", "int"),
    ColumnSpec("amount", "float", precision=0.01),
    ColumnSpec("note", "str"),
]
CITIES = ["Paris", "Rome", "Oslo", "Lima"]
WORDS = ["red", "blue", "jade", "gold"]


def gen_rows(n, seed=0):
    rng = np.random.default_rng(seed)
    return [{
        "city": CITIES[int(rng.integers(0, len(CITIES)))],
        "qty": int(rng.integers(0, 5000)),
        "amount": round(float(rng.uniform(0.0, 100.0)), 2),
        "note": f"{WORDS[int(rng.integers(0, 4))]}-"
                f"{WORDS[int(rng.integers(0, 4))]}",
    } for _ in range(n)]


@pytest.fixture(scope="module")
def codec():
    c = TableCodec.fit(gen_rows(600), SCHEMA)
    assert c.compile() is not None
    return c


# Each case: (column, escaping value). All must decode back exactly.
ESCAPES = [
    ("city", "Kyoto"),                      # unseen category
    ("qty", 10**7),                         # out-of-range integer
    ("amount", 5000.25),                    # out-of-range float
    ("amount", float("inf")),               # non-finite
    ("note", "one two three words here"),   # off-template segment count
    ("note", "zzzz-qqqq"),                  # dictionary-miss words
]


class TestEscapeRoundtrip:
    @pytest.mark.parametrize("col,val", ESCAPES)
    def test_escaping_value_roundtrips_exactly(self, codec, col, val):
        plan = codec.compile(force=True)
        row = dict(gen_rows(1, seed=9)[0])
        row[col] = val
        before = plan.escape_counts[col]
        table = CompressedTable(codec)
        table.extend([row] + gen_rows(4, seed=10))
        assert plan.escape_counts[col] >= before + 1
        assert not table.block_fast[0]          # escaped row routes slow
        assert table.block_fast[1:].all()       # the rest stay fast
        got = table.get(0)
        if col == "amount":
            assert got[col] == val              # raw float64: exact
        else:
            assert got[col] == val
        # and the batch read path agrees with the scalar one
        assert table.get_many([0, 1]) == [table.get(0), table.get(1)]

    def test_non_numeric_in_float_column_charges_only_that_row(self, codec):
        plan = codec.compile(force=True)
        rows = gen_rows(8, seed=3)
        rows[2] = dict(rows[2], amount="not a number")
        syms, ok = plan.encode_rows(rows)
        assert not ok[2]
        assert ok[[0, 1, 3, 4, 5, 6, 7]].all()  # neighbours unaffected
        assert plan.escape_counts["amount"] == 1


class TestCounterAgreement:
    """Property-style: scalar and batch paths charge identical counters."""

    def _mutate(self, rng, row):
        """Randomly corrupt 0-2 columns; returns the mutated row."""
        mutations = [
            ("city", lambda: f"Nowhere{int(rng.integers(0, 99))}"),
            ("qty", lambda: int(rng.integers(10**6, 10**7))),
            ("amount", lambda: float(rng.uniform(1e4, 1e6))),
            ("amount", lambda: "abc"),
            ("note", lambda: "a b c d e"),
            ("note", lambda: f"xx{int(rng.integers(0, 99))}-yy"),
        ]
        for _ in range(int(rng.integers(0, 3))):
            col, fn = mutations[int(rng.integers(0, len(mutations)))]
            row = dict(row, **{col: fn()})
        return row

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_scalar_matches_batch_per_column(self, codec, seed):
        rng = np.random.default_rng(seed)
        rows = [self._mutate(rng, r) for r in gen_rows(120, seed=seed + 50)]

        batch_plan = codec.compile(force=True)
        _, ok = batch_plan.encode_rows(rows)

        scalar_plan = codec.compile(force=True)
        scalar_ok = [scalar_plan.row_conforms(r) for r in rows]

        assert ok.tolist() == scalar_ok
        assert batch_plan.escape_counts == scalar_plan.escape_counts
        assert batch_plan.rows_seen == scalar_plan.rows_seen == len(rows)

    def test_window_reset_keeps_cumulative(self, codec):
        plan = codec.compile(force=True)
        rows = gen_rows(20, seed=77)
        rows[0] = dict(rows[0], city="Gotham")
        plan.encode_rows(rows)
        assert plan.window_escapes["city"] == 1
        assert plan.window_rows == 20
        snap = plan.reset_escapes()
        assert snap["city"] == 1
        assert plan.window_escapes["city"] == 0 and plan.window_rows == 0
        assert plan.escape_counts["city"] == 1      # cumulative survives
        assert plan.rows_seen == 20
        assert plan.escape_rates()["city"] == 0.0   # empty window -> 0.0


class TestStoreSurfacesCounters:
    def test_stats_reports_cumulative_and_window(self):
        from repro.oltp.store import BlitzStore
        rows = gen_rows(300)
        store = BlitzStore(SCHEMA, rows)
        store.insert_many(rows)
        store.insert(dict(rows[0], city="Atlantis"))
        s = store.stats()
        assert s["escapes"]["city"] >= 1
        assert s["escapes_window"]["city"] >= 1
        assert s["window_rows"] >= 301
        assert s["plan_versions"] == 1
        store.codec.compile().reset_escapes()
        s2 = store.stats()
        assert s2["escapes"]["city"] >= 1           # cumulative stays
        assert s2["escapes_window"]["city"] == 0    # window cleared
