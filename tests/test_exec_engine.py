"""Compiled execution engine tests (DESIGN.md §11, ISSUE 10).

Covers the vectorized key router's bit-identity against the scalar FNV
reference, the prepared-plan cache (hit/miss accounting, epoch-keyed
invalidation under adaptive refit vs merge, per-table isolation, schema
checks), replay bit-identity across decode backends and invalidations,
and the digit-cap string path (variable-length digit tokens round-trip
identically on the scalar and plan coders, padding drained).
"""

import numpy as np
import pytest

from repro.core import ColumnSpec
from repro.core.blitzcrank import TableCodec
from repro.db import Database, TableSchema, stable_key_hash
from repro.exec import PreparedOp, shard_keys, stable_key_hash_batch
from repro.exec.prepared import batch_bucket
from repro.oltp import tpcc

ORDERLINE = TableSchema(
    "orderline", tpcc.ORDERLINE_SCHEMA, ("ol_o_id", "ol_number"))


def _orderline_table(n_rows=300, n_shards=2, seed=0):
    db = Database(backend="blitzcrank", n_shards=n_shards)
    rows = tpcc.gen_orderline(n_rows, seed=seed)
    table = db.create_table(ORDERLINE, sample_rows=rows)
    table.insert_many(rows)
    return db, table, rows


class TestRouter:
    def test_int_keys_bit_identical(self):
        rng = np.random.default_rng(0)
        keys = [int(v) for v in rng.integers(-(1 << 61), 1 << 61, 500)]
        keys += [0, 1, -1, 255, 256, -256, (1 << 61) - 1, -(1 << 61) + 1]
        got = stable_key_hash_batch(keys, 1)
        want = np.array([stable_key_hash(k) for k in keys], np.uint64)
        assert (got == want).all()

    def test_composite_keys_bit_identical(self):
        rng = np.random.default_rng(1)
        keys = [(int(a), int(b), int(c)) for a, b, c in zip(
            rng.integers(0, 1 << 40, 300),
            rng.integers(-(1 << 20), 1 << 20, 300),
            rng.integers(0, 100, 300))]
        got = stable_key_hash_batch(keys, 3)
        want = np.array([stable_key_hash(k) for k in keys], np.uint64)
        assert (got == want).all()

    def test_non_int_parts_fall_back_identically(self):
        keys = [("TX", 1), ("CA", 2), ("NY", 3)]
        got = stable_key_hash_batch(keys, 2)
        want = np.array([stable_key_hash(k) for k in keys], np.uint64)
        assert (got == want).all()

    def test_magnitude_edge_falls_back_identically(self):
        keys = [1 << 62, -(1 << 62), (1 << 63) - 1, 5]
        got = stable_key_hash_batch(keys, 1)
        want = np.array([stable_key_hash(k) for k in keys], np.uint64)
        assert (got == want).all()

    def test_shard_keys_matches_scalar_route(self):
        rng = np.random.default_rng(2)
        keys = [(int(a), int(b)) for a, b in zip(
            rng.integers(0, 1 << 30, 200), rng.integers(0, 1 << 10, 200))]
        for n_shards in (1, 2, 5):
            got = shard_keys(keys, 2, n_shards)
            want = [stable_key_hash(k) % n_shards for k in keys]
            assert got.tolist() == want


class TestBatchBucket:
    def test_pow2_buckets_with_floor(self):
        assert batch_bucket(0) == 8
        assert batch_bucket(1) == 8
        assert batch_bucket(8) == 8
        assert batch_bucket(9) == 16
        assert batch_bucket(256) == 256
        assert batch_bucket(257) == 512


class TestPreparedCache:
    def test_hit_miss_accounting_per_bucket(self):
        _db, table, rows = _orderline_table()
        keys = [ORDERLINE.key_of(r) for r in rows]
        op = table.prepare("get")
        op.run(keys[:64])
        assert op.cache_info() == {"entries": 1, "hits": 0, "misses": 1}
        op.run(keys[:64])
        op.run(keys[:50])  # same pow2 bucket (64)
        assert op.cache_info()["hits"] == 2
        op.run(keys[:65])  # new bucket (128) -> one more lowering
        assert op.cache_info()["misses"] == 2

    def test_prepare_caches_handles_per_verb(self):
        _db, table, _rows = _orderline_table(n_rows=50)
        assert table.prepare("get") is table.prepare("get")
        assert table.prepare("get") is not table.prepare("insert")

    def test_schema_mismatch_raises(self):
        _db, table, _rows = _orderline_table(n_rows=50)
        other = TableSchema("other", [ColumnSpec("a", "int")], "a")
        with pytest.raises(ValueError, match="schema"):
            table.prepare("get", schema=other)
        # the table's own schema object is accepted
        assert table.prepare("get", schema=table.schema) is table.prepare("get")

    def test_unknown_verb_raises(self):
        _db, table, _rows = _orderline_table(n_rows=50)
        with pytest.raises(ValueError, match="verb"):
            table.prepare("upsert")

    def test_refit_invalidates_exactly_affected_entries(self):
        """An install_codec version bump on one table invalidates that
        table's prepared entries (by epoch mismatch) and no one else's."""
        _db_a, table_a, rows_a = _orderline_table(seed=3)
        _db_b, table_b, rows_b = _orderline_table(seed=4)
        keys_a = [ORDERLINE.key_of(r) for r in rows_a][:64]
        keys_b = [ORDERLINE.key_of(r) for r in rows_b][:64]
        op_a, op_b = table_a.prepare("get"), table_b.prepare("get")
        op_a.run(keys_a)
        op_b.run(keys_b)
        epoch_before = table_a.plan_epoch

        shard = table_a.shards[0]
        shard.install_codec(
            TableCodec.fit(rows_a, list(ORDERLINE.columns)))
        assert table_a.plan_epoch != epoch_before
        assert table_b.plan_epoch == (0,) * table_b.n_shards

        op_a.run(keys_a)  # epoch mismatch -> re-lower
        op_b.run(keys_b)  # untouched table -> still a hit
        assert op_a.cache_info()["misses"] == 2
        assert op_a.cache_info()["entries"] == 1  # replaced, not grown
        assert op_b.cache_info() == {"entries": 1, "hits": 1, "misses": 1}

    def test_merge_keeps_entries_valid(self):
        """Merges/rewrites that keep the plan leave the epoch unchanged,
        so lowered entries stay valid (no spurious re-lowering)."""
        _db, table, rows = _orderline_table()
        keys = [ORDERLINE.key_of(r) for r in rows][:64]
        op = table.prepare("get")
        op.run(keys)
        epoch = table.plan_epoch
        table.update_many(keys[:16], [dict(r, ol_quantity=int(r["ol_quantity"]) + 1)
                                      for r in rows[:16]])
        for shard in table.shards:
            shard.merge()
        assert table.plan_epoch == epoch
        op.run(keys)
        info = op.cache_info()
        assert info["misses"] == 1 and info["hits"] >= 1

    def test_explicit_invalidate_drops_entries(self):
        _db, table, rows = _orderline_table(n_rows=50)
        keys = [ORDERLINE.key_of(r) for r in rows]
        op = table.prepare("get")
        op.run(keys)
        assert op.cache_info()["entries"] == 1
        op.invalidate()
        assert op.cache_info()["entries"] == 0
        op.run(keys)
        assert op.cache_info()["misses"] == 2


class TestReplayIdentity:
    def test_backends_identical_across_invalidation(self):
        """Replayed reads stay bit-identical numpy-vs-pallas before and
        after a refit bump + migration invalidates the cached plans."""
        _db, table, rows = _orderline_table(n_rows=400)
        keys = [ORDERLINE.key_of(r) for r in rows]
        op = table.prepare("get")
        before_np = op.run(keys, backend="numpy")
        before_pl = op.run(keys, backend="pallas")
        assert before_np == before_pl

        for shard in table.shards:
            shard.install_codec(
                TableCodec.fit(rows, list(ORDERLINE.columns)))
            shard.migrate(limit=1 << 16, resident_only=False)
            shard.merge()
        after_np = op.run(keys, backend="numpy")
        after_pl = op.run(keys, backend="pallas")
        assert after_np == after_pl == before_np

    def test_prepared_matches_legacy_and_scalar_paths(self):
        _db, table, rows = _orderline_table(n_rows=200)
        keys = [ORDERLINE.key_of(r) for r in rows]
        prepared = table.prepare("get").run(keys)
        assert prepared == table.get_many(keys)
        assert prepared[:20] == [table.get(k) for k in keys[:20]]

    def test_session_shares_prepared_handles(self):
        db, table, rows = _orderline_table(n_rows=60)
        keys = [ORDERLINE.key_of(r) for r in rows]
        ses = db.session()
        assert ses.prepared("orderline", "get") is ses.prepared(
            "orderline", "get")
        assert ses.get("orderline", keys) == table.get_many(keys)

    def test_scalar_get_raises_on_missing(self):
        _db, table, _rows = _orderline_table(n_rows=30)
        with pytest.raises(KeyError):
            table.get((999999, 999999))


class TestDigitCaps:
    """Variable-length digit tokens (street numbers) take the cap-padded
    digit path on both the scalar coder and the vectorized plan."""

    SCHEMA = [ColumnSpec("k", "int"), ColumnSpec("addr", "str")]

    @staticmethod
    def _rows(n=400, seed=5):
        rng = np.random.default_rng(seed)
        streets = ["Elm Grove", "Oak Lane", "Pine Road", "Birch Way"]
        return [{"k": i,
                 "addr": f"{int(rng.integers(1, 10 ** int(rng.integers(1, 5))))}"
                         f" {streets[int(rng.integers(0, len(streets)))]}"}
                for i in range(n)]

    def test_scalar_round_trip_all_widths(self):
        rows = self._rows()
        codec = TableCodec.fit(rows, self.SCHEMA)
        for r in rows[:80]:
            block = codec.compress_block([r])
            assert codec.decompress_block(block, 1) == [r]

    def test_plan_matches_scalar_stream_and_decode(self):
        rows = self._rows()
        codec = TableCodec.fit(rows, self.SCHEMA)
        plan = codec.compile()
        assert plan is not None
        codes, offsets, fast = codec.compress_rows(rows)
        assert fast.mean() > 0.9  # digit caps keep 1-4 digit numbers fast
        idx = np.flatnonzero(fast)
        # plan batch decode == original rows (so == scalar stream decode)
        got = codec.decompress_rows(codes, offsets, idx)
        assert got == [rows[int(i)] for i in idx]
        # and the plan's codes for a conforming row match the scalar coder
        for i in map(int, idx[:40]):
            scalar_codes = codec.compress_block([rows[i]])
            assert (codes[offsets[i]:offsets[i + 1]] == scalar_codes).all()

    def test_minority_width_pads_and_drains(self):
        # one 1-digit number among 3-digit ones: encoded at the shared
        # cap with zero padding, which decode must drain exactly
        rows = [{"k": i, "addr": f"{100 + i} Elm Grove"} for i in range(60)]
        rows.append({"k": 60, "addr": "7 Elm Grove"})
        codec = TableCodec.fit(rows, self.SCHEMA)
        for r in (rows[0], rows[-1]):
            block = codec.compress_block([r])
            assert codec.decompress_block(block, 1) == [r]
        codes, offsets, fast = codec.compress_rows(rows)
        idx = np.flatnonzero(fast)
        got = codec.decompress_rows(codes, offsets, idx)
        assert got == [rows[int(i)] for i in idx]

    def test_over_cap_digits_escape_but_round_trip(self):
        rows = [{"k": i, "addr": f"{10 + i} Oak Lane"} for i in range(50)]
        codec = TableCodec.fit(rows, self.SCHEMA)
        huge = {"k": 99, "addr": "123456789012 Oak Lane"}  # over any cap
        block = codec.compress_block([huge])
        assert codec.decompress_block(block, 1) == [huge]
