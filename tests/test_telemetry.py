"""Telemetry layer (DESIGN.md §9): metric primitives, spans, exporters.

The load-bearing invariants: histogram merge is lossless (commutative,
associative, equal to observing the concatenated stream), the event ring
survives wraparound with ordering intact, ScanStats keeps its attribute
API while flowing deltas into shared registry counters without
double-counting on merge, and — most importantly — disabling telemetry
changes *nothing* about engine behaviour: an enabled and a disabled run
produce bit-identical store state.
"""

import dataclasses
import pickle
import random

import pytest

from repro import telemetry
from repro.core.blitzcrank import ColumnSpec
from repro.oltp.store import BlitzStore
from repro.scan.engine import ScanStats
from repro.telemetry import (
    N_BUCKETS,
    EventLog,
    Histogram,
    Registry,
    SpanEvent,
    bucket_index,
    bucket_lo,
)


@pytest.fixture(autouse=True)
def _clean_registry():
    """Each test sees a zeroed global registry and enabled telemetry."""
    prev = telemetry.set_enabled(True)
    telemetry.reset()
    yield
    telemetry.set_enabled(prev)
    telemetry.reset()


# -- histogram geometry ---------------------------------------------------


def test_bucket_boundaries():
    assert bucket_index(0) == 0
    assert bucket_index(0.5) == 0
    assert bucket_index(1.0) == 0
    # a point safely inside bucket i lands in bucket i (buckets are a
    # factor 2**0.25 ~ 1.19 wide, so *1.1 stays inside)
    for i in range(0, 220, 7):
        inside = bucket_lo(i) * 1.1
        assert bucket_index(inside) == i
        assert bucket_lo(i) <= inside < bucket_lo(i + 1)
    # durations beyond the last edge clamp instead of overflowing
    assert bucket_index(1e30) == N_BUCKETS - 1


def test_histogram_observe_and_percentiles():
    h = Histogram("t")
    for ns in (100, 200, 300, 400, 1_000_000):
        h.observe(ns)
    assert h.count == 5
    assert h.sum_ns == 1_001_000
    assert h.min_ns == 100 and h.max_ns == 1_000_000
    # p50 lands near the middle observations, clamped to observed range
    assert 100 <= h.percentile(0.5) <= 400 * 1.2
    # the top quantile reports its bucket's midpoint: within one bucket
    # width (2**0.25 ~ 19%) of the true max, never above it
    assert 1_000_000 / 1.2 <= h.percentile(1.0) <= 1_000_000


def test_empty_histogram_percentiles_are_zero():
    h = Histogram("empty")
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert h.percentile(q) == 0.0
    s = h.summary()
    assert s["count"] == 0 and s["p99_us"] == 0.0


def _hist_from(samples):
    h = Histogram("x")
    for s in samples:
        h.observe(s)
    return h


def _hist_eq(a, b):
    return (
        a.count == b.count
        and a.sum_ns == b.sum_ns
        and a.min_ns == b.min_ns
        and a.max_ns == b.max_ns
        and a.buckets == b.buckets
    )


def test_merge_is_lossless_commutative_associative():
    rng = random.Random(7)
    sa = [rng.randrange(1, 10**9) for _ in range(200)]
    sb = [rng.randrange(1, 10**6) for _ in range(50)]
    sc = [rng.randrange(10**3, 10**12) for _ in range(80)]

    # merge == observing the concatenated stream
    ab = _hist_from(sa)
    ab.merge(_hist_from(sb))
    assert _hist_eq(ab, _hist_from(sa + sb))

    # commutative
    ba = _hist_from(sb)
    ba.merge(_hist_from(sa))
    assert _hist_eq(ab, ba)

    # associative
    left = _hist_from(sa)
    left.merge(_hist_from(sb))
    left.merge(_hist_from(sc))
    bc = _hist_from(sb)
    bc.merge(_hist_from(sc))
    right = _hist_from(sa)
    right.merge(bc)
    assert _hist_eq(left, right)

    # merging an empty histogram is the identity
    before = _hist_from(sa)
    before.merge(Histogram("e"))
    assert _hist_eq(before, _hist_from(sa))


# -- event ring -----------------------------------------------------------


def test_event_log_wraparound():
    log = EventLog(capacity=8)
    n = 2 * 8 + 3
    for i in range(n):
        log.append(SpanEvent(i, f"ev{i}", 0, i * 10, 5))
    assert len(log) == 8
    assert log.total == n
    evs = log.events()
    # oldest dropped, order kept: the retained tail is the last 8 appends
    assert [e.seq for e in evs] == list(range(n - 8, n))


def test_span_nesting_depth_and_histogram():
    with telemetry.span("repro.test.outer"):
        with telemetry.span("repro.test.inner"):
            pass
    evs = [e for e in telemetry.EVENTS.events() if e.name.startswith("repro.test.")]
    # inner closes first, one level deeper
    assert [(e.name, e.depth) for e in evs] == [
        ("repro.test.inner", 1),
        ("repro.test.outer", 0),
    ]
    assert telemetry.REGISTRY.histogram("repro.test.outer").count == 1


def test_disabled_mode_is_inert():
    c = telemetry.counter("repro.test.c")
    h = telemetry.histogram("repro.test.h")
    prev = telemetry.set_enabled(False)
    try:
        assert telemetry.clock() == 0
        c.add(5)
        h.observe(123)
        h.observe_since(0)
        telemetry.record("repro.test.h", 0)
        with telemetry.span("repro.test.h"):
            pass
        assert c.value == 0
        assert h.count == 0
        assert telemetry.EVENTS.total == 0
    finally:
        telemetry.set_enabled(prev)


# -- ScanStats on shared registry counters --------------------------------


def test_scan_stats_attribute_api_and_registry():
    c = telemetry.counter("repro.scan.rows_decoded")
    s = ScanStats()
    assert s.rows_decoded == 0
    s.rows_decoded = 5
    assert s.rows_decoded == 5
    assert c.value == 5
    # overwriting flows the *delta*, so the registry nets to the final value
    s.rows_decoded = 3
    assert c.value == 3
    s2 = ScanStats(rows_decoded=4, blocks_total=2)
    assert c.value == 7


def test_scan_stats_merge_does_not_double_count():
    c = telemetry.counter("repro.scan.blocks_pruned")
    a = ScanStats(blocks_pruned=3)
    b = ScanStats(blocks_pruned=4)
    assert c.value == 7  # both scans registered their deltas when they ran
    a.merge(b)
    assert a.blocks_pruned == 7
    # merge is registry-neutral: folding per-shard stats into a table
    # total must not re-register work the shards already counted
    assert c.value == 7


def test_scan_stats_equality_and_repr():
    a = ScanStats(rows_decoded=2)
    b = ScanStats(rows_decoded=2)
    assert a == b
    assert "rows_decoded" in repr(a)


# -- exporters ------------------------------------------------------------


def test_snapshot_prefix_filter():
    # blitzlint: waive[BL002] -- scratch names probe registry prefix filtering; cataloguing them would defeat the test
    telemetry.counter("repro.db.x").add(1)
    # blitzlint: waive[BL002] -- scratch names probe registry prefix filtering; cataloguing them would defeat the test
    telemetry.counter("repro.wal.y").add(2)
    snap = telemetry.snapshot(prefix="repro.db.")
    assert "repro.db.x" in snap["counters"]
    assert "repro.wal.y" not in snap["counters"]
    snap2 = telemetry.snapshot(prefix=("repro.db.", "repro.wal."))
    assert {"repro.db.x", "repro.wal.y"} <= set(snap2["counters"])


def test_prometheus_exposition_format():
    telemetry.counter("repro.db.get_many.rows").add(3)
    telemetry.histogram("repro.db.get_many").observe(1500)
    text = telemetry.to_prometheus()
    assert "repro_db_get_many_rows_total 3" in text
    assert 'repro_db_get_many_us{quantile="0.5"}' in text
    assert "repro_db_get_many_us_count 1" in text


def test_phase_breakdown_folds_and_covers():
    reg = Registry()
    reg.histogram("repro.core.encode").observe(0.2e9)
    reg.histogram("repro.core.decode").observe(0.1e9)
    reg.histogram("repro.wal.fsync").observe(0.1e9)
    bd = telemetry.phase_breakdown(0.5, registry=reg)
    assert bd["phases_s"]["encode"] == pytest.approx(0.2)
    assert bd["phases_s"]["decode"] == pytest.approx(0.1)
    assert bd["phases_s"]["fsync"] == pytest.approx(0.1)
    assert bd["phases_s"]["python_glue"] == pytest.approx(0.1)
    assert bd["coverage"] == 1.0
    assert sum(bd["phases_s"].values()) == pytest.approx(0.5)

    # `since` scopes the fold to work done after the captured baseline
    base = reg.hist_seconds()
    reg.histogram("repro.core.encode").observe(0.3e9)
    bd2 = telemetry.phase_breakdown(0.4, registry=reg, since=base)
    assert bd2["phases_s"]["encode"] == pytest.approx(0.3)
    assert bd2["phases_s"]["python_glue"] == pytest.approx(0.1)


def test_registry_reset_keeps_handles_valid():
    c = telemetry.counter("repro.test.reset")
    c.add(9)
    telemetry.reset()
    assert c.value == 0
    c.add(2)
    assert telemetry.counter("repro.test.reset") is c
    assert c.value == 2


# -- disabled telemetry changes nothing about engine behaviour ------------

COLS = [
    ColumnSpec("w", "cat"),
    ColumnSpec("id", "int", growth=8.0),
    ColumnSpec("qty", "int"),
    ColumnSpec("amt", "float", precision=0.01),
]


def _drive_store(enabled: bool):
    prev = telemetry.set_enabled(enabled)
    try:
        rng = random.Random(1234)
        rows = [
            {
                "w": f"w{rng.randrange(6)}",
                "id": i,
                "qty": rng.randrange(1, 50),
                "amt": round(rng.uniform(0, 1000), 2),
            }
            for i in range(400)
        ]
        store = BlitzStore(COLS, rows, merge_min_bytes=1 << 10)
        store.insert_many(rows)
        for i in range(0, 400, 7):
            # stores take full rows; partial-update merging is Table's job
            store.update_many([i], [dict(rows[i], qty=99)])
        store.delete_many(list(range(0, 400, 13)))
        store.merge()
        live = [i for i in range(400) if i % 13]
        got = store.get_many(live[:100])
        return store.snapshot_state(), got
    finally:
        telemetry.set_enabled(prev)


def _neutralize_fit_timings(state):
    # FitStats carries wall-clock fit timings — run-dependent metadata,
    # not store contents.  Zero them so the comparison is about data.
    for codec in state["table"]["codecs"]:
        codec.stats = dataclasses.replace(
            codec.stats, structuring_s=0.0, generation_s=0.0
        )
    return state


def test_enabled_vs_disabled_bit_identical_state():
    state_on, got_on = _drive_store(True)
    state_off, got_off = _drive_store(False)
    assert got_on == got_off
    on = pickle.dumps(_neutralize_fit_timings(state_on))
    off = pickle.dumps(_neutralize_fit_timings(state_off))
    assert on == off
