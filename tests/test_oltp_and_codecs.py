"""OLTP stores (paper §6/§7 setting) + tensor codecs + HLO analyzer."""

import numpy as np
import pytest

from repro.oltp import tpcc
from repro.oltp.store import (BlitzStore, LRUFastPath, RamanStore,
                              ZstdStore)


def _check_store(store, rows, schema, n=30):
    rng = np.random.default_rng(0)
    for i in rng.integers(0, len(rows), n):
        got, exp = store.get(int(i)), rows[int(i)]
        for c in schema:
            if c.kind == "float":
                assert abs(got[c.name] - exp[c.name]) <= c.precision / 2 + 1e-9
            else:
                assert got[c.name] == exp[c.name], (c.name,)


class TestStores:
    @pytest.mark.parametrize("table", ["customer", "stock", "orderline"])
    def test_blitz_beats_baselines(self, table):
        schema, gen = tpcc.TABLES[table]
        rows = gen(1200)
        raw = tpcc.row_bytes(rows)
        classes = [RamanStore, BlitzStore]
        try:
            import zstandard  # noqa: F401
            classes.insert(0, ZstdStore)
        except ImportError:
            pass  # zstd baseline unavailable in this environment
        factors = {}
        for cls in classes:
            store = cls(schema, rows[:600])
            for r in rows:
                store.insert(r)
            _check_store(store, rows, schema)
            factors[store.name] = raw / store.nbytes
        if "zstd" in factors:
            assert factors["blitzcrank"] > factors["zstd"], factors
        assert factors["blitzcrank"] > 2.0

    def test_unseen_values_after_training(self):
        """Semantic models compress inserts with unseen values (paper §3)."""
        schema, gen = tpcc.TABLES["customer"]
        rows = gen(800)
        store = BlitzStore(schema, rows[:400])
        new = dict(rows[0])
        new.update(c_first="Zyxwv", c_balance=9.9e7, c_zip="00000",
                   c_street="1 Unobtainium Qz")
        i = store.insert(new)
        got = store.get(i)
        assert got["c_first"] == "Zyxwv" and got["c_zip"] == "00000"
        assert got["c_street"] == new["c_street"]

    def test_correlation_learns_hierarchy(self):
        schema, gen = tpcc.TABLES["customer"]
        rows = gen(2500)
        store = BlitzStore(schema, rows, correlation=True, sample=1500)
        parents = store.codec.stats.parents
        assert parents.get("c_city") == "c_state"
        assert parents.get("c_zip") == "c_city"
        for r in rows[:60]:
            store.insert(r)
        _check_store(store, rows[:60], schema, n=10)

    def test_lru_fastpath_zipf(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(400)
        store = BlitzStore(schema, rows[:200])
        for r in rows:
            store.insert(r)
        fp = LRUFastPath(store, capacity=64)
        rng = np.random.default_rng(1)
        keys = (rng.zipf(1.3, 2000) - 1)
        keys = keys[keys < 400][:500]
        for i in keys:
            fp.read_modify_write(int(i), lambda r: r.update(ol_quantity=1))
        assert fp.hits / (fp.hits + fp.misses) > 0.3


class TestTensorCodecs:
    def test_lossless16_exact(self):
        import jax.numpy as jnp
        from repro.tensor.codec import fit_codec
        rng = np.random.default_rng(0)
        w = np.asarray(jnp.asarray(rng.normal(0, 0.02, 4096),
                                   jnp.bfloat16)).view(np.uint16)
        codec = fit_codec(w, "lossless16")
        ct = codec.encode(w)
        assert (codec.decode(ct) == w).all()
        assert ct.ratio() > 1.2

    def test_twolevel_precision_bound(self):
        from repro.tensor.codec import fit_codec
        rng = np.random.default_rng(1)
        x = rng.normal(0, 1.0, 8192).astype(np.float32)
        codec = fit_codec(x, "twolevel", precision=1e-3)
        back = codec.decode(codec.encode(x))
        assert np.abs(back - x).max() <= 5e-4 + 1e-9

    def test_twolevel_outliers_exact(self):
        from repro.tensor.codec import fit_codec
        x = np.concatenate([np.random.default_rng(0).normal(0, 1, 1024),
                            [1e9, -1e9]]).astype(np.float32)
        codec = fit_codec(x[:1024], "twolevel", precision=1e-3)
        back = codec.decode(codec.encode(x))
        assert back[-2] == np.float32(1e9) and back[-1] == np.float32(-1e9)

    def test_kv_store_page_access(self):
        from repro.tensor.kv_cache import CompressedKVStore
        rng = np.random.default_rng(2)
        store = CompressedKVStore(page_tokens=16)
        k = rng.normal(0, 1, (16, 4, 32)).astype(np.float32)
        v = rng.normal(0, 1, (16, 4, 32)).astype(np.float32)
        store.put(0, 0, k, v)
        k2, v2 = store.get(0, 0)
        assert np.abs(k2 - k).max() < 0.2
        assert store.nbytes < k.nbytes + v.nbytes


class TestHloAnalyzer:
    def test_scan_trip_counts(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.hlo import analyze_hlo
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=8)
            return y
        st = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text(), 1)
        assert st.flops / (2 * 64 * 128 * 128) == pytest.approx(8.0)

    def test_grad_scan_counts_remat(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.hlo import analyze_hlo
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=8)
            return jnp.sum(y * y)
        st = analyze_hlo(
            jax.jit(jax.grad(f, argnums=1)).lower(x, w).compile().as_text(), 1)
        # fwd 8 + remat 8 + bwd 2x8 = 32 matmuls
        assert st.flops / (2 * 64 * 128 * 128) == pytest.approx(32.0)

    def test_collective_parse(self):
        from repro.analysis.hlo import analyze_hlo
        hlo = """
HloModule test, entry_computation_layout={()->f32[8]{0}}

ENTRY %main () -> f32[8] {
  %p = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%p), replica_groups=[1,4]<=[4], to_apply=%add
}
"""
        st = analyze_hlo(hlo, 4)
        assert st.collective_counts.get("all-reduce") == 1
        assert st.collective_wire_bytes == pytest.approx(2 * 3 / 4 * 32)
