"""Out-of-core cold tier (DESIGN.md §6): spill/fault correctness.

Property-style tests: under an aggressively tiny ``memory_budget`` every
read must be bit-identical to a fully-resident reference store — through
random insert/update/delete interleavings, ``merge()``, ``rewrite()``,
``migrate_rows()``, and on both decode backends (numpy and pallas).
"""

import numpy as np
import pytest

from repro.core import CompressedTable, TableCodec
from repro.core.arena import FRAME_OVERHEAD, DiskArena
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore, RamanStore, UncompressedStore

SCHEMA, GEN = tpcc.TABLES["orderline"]
TINY = 1 << 13  # 8 KiB: far below any population below, forces deep spill


def _rows_close(got, exp):
    """Row-list equality with float columns compared at model precision.

    The capped and reference stores merge on different cadences (the
    capped arena shrinks at rewrite), so at any instant one may serve a
    raw overlay value where the other serves the re-encoded (quantized)
    one.  Everything non-float must match exactly.
    """
    assert len(got) == len(exp)
    by_name = {c.name: c for c in SCHEMA}
    for g, e in zip(got, exp):
        if g is None or e is None:
            assert g is None and e is None
            continue
        for name, spec in by_name.items():
            if spec.kind == "float":
                assert abs(g[name] - e[name]) <= spec.precision + 1e-9, name
            else:
                assert g[name] == e[name], name


def _rand_row(rng, base):
    r = dict(base[int(rng.integers(0, len(base)))])
    r["ol_quantity"] = int(rng.integers(1, 60))
    r["ol_amount"] = round(float(rng.uniform(0.01, 12000.0)), 2)
    r["ol_o_id"] = int(rng.integers(0, 200))
    return r


def _baseline_makers():
    makers = {
        "silo": UncompressedStore,
        "raman": RamanStore,
    }
    try:
        import zstandard  # noqa: F401

        from repro.oltp.store import ZstdStore

        makers["zstd"] = ZstdStore
    except ImportError:
        pass
    return makers


class TestDiskArena:
    def test_write_read_roundtrip(self):
        arena = DiskArena()
        payloads = [bytes([i]) * (7 + i) for i in range(20)]
        offs = [arena.write(p) for p in payloads]
        for p, off in zip(payloads, offs):
            assert arena.read(off, len(p)) == p
        got = arena.read_many(offs, [len(p) for p in payloads])
        assert got == payloads

    def test_read_many_coalesces_adjacent(self):
        arena = DiskArena()
        seg = b"".join(bytes([i]) * 10 for i in range(8))
        base = arena.write(seg)
        offs = [base + 10 * i for i in range(8)]
        before = arena.reads
        got = arena.read_many(offs, [10] * 8)
        assert got == [bytes([i]) * 10 for i in range(8)]
        assert arena.reads == before + 1  # one pread for the whole range

    def test_compact_in_place(self):
        arena = DiskArena(page_bytes=64)
        payloads = [bytes([i]) * 33 for i in range(10)]
        offs = [arena.write(p) for p in payloads]
        for i in (0, 2, 4, 6, 8):
            arena.free(offs[i], len(payloads[i]))
        keep = [1, 3, 5, 7, 9]
        new_offs = arena.compact(
            [offs[i] for i in keep], [len(payloads[i]) for i in keep]
        )
        for i, off in zip(keep, new_offs):
            assert arena.read(off, len(payloads[i])) == payloads[i]
        assert arena.file_bytes < offs[-1] + 33

    def test_compact_interior_extents(self):
        # Spill segments hold many runs, so live extents have interior
        # (non-page-aligned) offsets; compaction must pack them densely
        # without the write cursor ever clobbering an unread extent.
        arena = DiskArena(page_bytes=4096)
        seg_a = b"A" * 10 + b"B" * 10 + b"C" * 10
        base_a = arena.write(seg_a)
        base_b = arena.write(b"D" * 10)  # page-aligned: offset 4096
        extents = [
            (base_a, 10, b"A" * 10),
            (base_a + 10, 10, b"B" * 10),
            (base_a + 20, 10, b"C" * 10),
            (base_b, 10, b"D" * 10),
        ]
        new_offs = arena.compact(
            [e[0] for e in extents], [e[1] for e in extents]
        )
        for (off, ln, want), new in zip(extents, new_offs):
            assert arena.read(new, ln) == want
        assert arena.file_bytes == 40  # packed dense, file truncated


class TestCompressedTableResidency:
    def _pair(self, n=1500, budget=TINY):
        rows = GEN(n, seed=3)
        codec = TableCodec.fit(rows[:500], SCHEMA)
        ref = CompressedTable(codec)
        ref.extend(rows)
        capped = CompressedTable(codec, memory_budget=budget)
        capped.extend(rows)
        return rows, ref, capped

    def test_reads_bit_identical_under_tiny_budget(self):
        _, ref, capped = self._pair()
        assert capped.spilled_bytes > 0
        rng = np.random.default_rng(0)
        idx = rng.integers(0, len(ref), 600).tolist()
        assert capped.get_many(idx) == ref.get_many(idx)
        for i in idx[:40]:  # scalar read-through path
            assert capped.get(i) == ref.get(i)

    def test_residency_tags_survive_rewrite(self):
        rows, ref, capped = self._pair()
        rng = np.random.default_rng(1)
        idx = rng.choice(len(rows), 200, replace=False).tolist()
        repl = [_rand_row(rng, rows) for _ in idx]
        ref.replace_many(idx, repl)
        capped.replace_many(idx, repl)
        dead = [int(i) for i in rng.choice(len(rows), 50, replace=False)]
        ref.delete_many(dead)
        capped.delete_many(dead)
        ref.rewrite()
        capped.rewrite()  # spilled blocks must carry tags through
        probe = rng.integers(0, len(rows), 500).tolist()
        assert capped.get_many(probe) == ref.get_many(probe)
        res = capped.residency()
        assert res["spilled_blocks"] > 0
        assert res["faults"] >= 0

    def test_budget_bounds_resident_codes(self):
        _, _, capped = self._pair()
        live_codes = capped.used - capped._dead_codes
        assert 2 * live_codes <= capped.memory_budget
        # nbytes means resident memory: the spilled payload is excluded
        assert capped.spilled_bytes > 0
        assert capped.residency()["resident_bytes"] == capped.nbytes


class TestBlitzStoreOutOfCore:
    def _ops(self, store, ref, rows, seed, n_ops=400):
        rng = np.random.default_rng(seed)
        model = {}
        ids = store.insert_many(rows)
        ref_ids = ref.insert_many(rows)
        assert list(ids) == list(ref_ids)
        for i, r in zip(ids, rows):
            model[i] = r
        for _ in range(n_ops):
            op = rng.random()
            live = [i for i in model if ref.is_live(i)]
            if op < 0.30 and live:
                i = int(live[int(rng.integers(0, len(live)))])
                r = _rand_row(rng, rows)
                store.update(i, r)
                ref.update(i, r)
                model[i] = r
            elif op < 0.38 and live:
                i = int(live[int(rng.integers(0, len(live)))])
                assert store.delete(i) == ref.delete(i)
            elif op < 0.50:
                fresh = [_rand_row(rng, rows) for _ in range(8)]
                a = store.insert_many(fresh)
                b = ref.insert_many(fresh)
                assert list(a) == list(b)
                for i, r in zip(a, fresh):
                    model[i] = r
            else:
                probe = rng.integers(0, len(store), 64).tolist()
                _rows_close(store.get_many(probe), ref.get_many(probe))

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_ops_match_resident_reference(self, seed):
        rows = GEN(1200, seed=5)
        ref = BlitzStore(SCHEMA, rows[:400], merge_min_bytes=1 << 10)
        capped = BlitzStore(
            SCHEMA,
            rows[:400],
            merge_min_bytes=1 << 10,
            memory_budget=TINY,
        )
        self._ops(capped, ref, rows, seed)
        capped.merge()
        ref.merge()
        every = list(range(len(ref)))
        _rows_close(capped.get_many(every), ref.get_many(every))
        # within one store the decode backends must be bit-identical,
        # spilled blocks included
        assert capped.get_many(every, backend="pallas") == capped.get_many(
            every, backend="numpy"
        )
        s = capped.stats()
        assert s["spilled_bytes"] > 0
        assert s["residency"]["faults"] > 0
        self._check_accounting(capped)

    @staticmethod
    def _check_accounting(store):
        """The incremental counters must equal ground truth recomputed
        from the block arrays (a sweep double-picking a victim, or a
        leaked disk extent, shows up here as drift)."""
        t = store.table
        nb = t.n_blocks
        lens = t.block_offsets[1:] - t.block_offsets[:-1]
        live_resident = int(lens[t._resident[:nb]].sum())
        # every resident block's run is live or dead; spilled runs are 0-len
        # after rewrite or counted dead before it
        assert t.used - t._dead_codes == live_resident - int(
            lens[t._resident[:nb] & (t._block2row[:nb] < 0)].sum()
        )
        spilled = ~t._resident[:nb]
        assert t._spilled_codes == int(t._disk_len[:nb][spilled].sum())
        # each spilled extent carries a CRC32 frame header on disk
        assert t._res.disk.live_bytes == 2 * t._spilled_codes + \
            FRAME_OVERHEAD * int(spilled.sum())

    def test_migrate_rows_under_budget(self):
        rows = GEN(1500, seed=9)
        sample = rows[:400]
        ref = BlitzStore(SCHEMA, sample)
        capped = BlitzStore(SCHEMA, sample, memory_budget=TINY)
        rng = np.random.default_rng(2)
        drifted = []
        for r in rows:
            r = dict(r)
            # quantities far outside the trained vocab escape the v0 plan
            r["ol_quantity"] = int(rng.integers(500, 600))
            drifted.append(r)
        ref.insert_many(drifted)
        capped.insert_many(drifted)
        from repro.adaptive import refit_codec

        new = refit_codec(ref.codec, drifted[:512], ["ol_quantity"])
        assert new.compile() is not None
        ref.install_codec(new)
        capped.install_codec(refit_codec(capped.codec, drifted[:512], ["ol_quantity"]))
        # resident-only migration must not fault the cold tier in
        faults_before = capped.table.residency()["faults"]
        capped.migrate(1 << 12, resident_only=True)
        assert capped.table.residency()["faults"] == faults_before
        ref.migrate(1 << 12)
        capped.migrate(1 << 12, resident_only=False)  # now drain the rest
        every = list(range(len(ref)))
        _rows_close(capped.get_many(every), ref.get_many(every))
        assert capped.get_many(every, backend="pallas") == capped.get_many(
            every, backend="numpy"
        )


class TestBaselineStoresOutOfCore:
    @pytest.mark.parametrize("name", sorted(_baseline_makers()))
    def test_reads_match_resident_reference(self, name):
        make = _baseline_makers()[name]
        rows = GEN(800, seed=7)
        ref = make(SCHEMA, rows[:300])
        capped = make(SCHEMA, rows[:300], memory_budget=1 << 12)
        ref.insert_many(rows)
        capped.insert_many(rows)
        rng = np.random.default_rng(4)
        for _ in range(60):
            probe = rng.integers(0, len(rows), 48).tolist()
            assert capped.get_many(probe) == ref.get_many(probe)
            i = int(rng.integers(0, len(rows)))
            if ref.is_live(i):
                r = _rand_row(rng, rows)
                ref.update(i, r)
                capped.update(i, r)
            j = int(rng.integers(0, len(rows)))
            assert capped.delete(j) == ref.delete(j)
        every = list(range(len(rows)))
        assert capped.get_many(every) == ref.get_many(every)
        s = capped.stats()
        assert s["spilled_bytes"] > 0
        assert s["residency"]["faults"] > 0
        assert s["nbytes"] < ref.stats()["nbytes"]
        # incremental accounting equals ground truth (no sweep double-picks,
        # no leaked disk extents)
        assert capped._resident_bytes == sum(
            len(r) for r in capped.rows if r
        )
        assert capped._spilled_payload == sum(
            ln for _, ln in capped._spilled.values()
        )
        assert capped._res.disk.live_bytes == (
            capped._spilled_payload + FRAME_OVERHEAD * len(capped._spilled)
        )


class TestDbTableBudget:
    def test_sharded_budget_split_reads_identical(self):
        from repro.db import Database

        pop = tpcc.generate_tpcc(
            n_warehouses=1,
            districts_per_wh=2,
            customers_per_district=60,
            n_items=100,
            orders_per_district=15,
            seed=11,
        )
        ref = Database(backend="blitzcrank", n_shards=2)
        capped = Database(backend="blitzcrank", n_shards=2, memory_budget=2048)
        for db in (ref, capped):
            for tname, schema in tpcc.TPCC_TABLES.items():
                t = db.create_table(schema, sample_rows=pop[tname])
                t.insert_many(pop[tname])
        tpcc.run_tpcc_mix(ref, 120, seed=13)
        tpcc.run_tpcc_mix(capped, 120, seed=13)
        ref.merge_all()
        capped.merge_all()
        for tname in tpcc.TPCC_TABLES:
            keys = [k for k, _ in ref[tname].scan()]
            assert capped[tname].get_many(keys) == ref[tname].get_many(keys)
        s = capped.stats()
        assert s["spilled_bytes"] > 0
        assert s["residency"]["budget_bytes"] > 0
        # per-shard split: each shard of a budgeted table carries a budget
        shard = capped["order_line"].shards[0]
        assert shard.table.memory_budget == 1024
