"""Runtime substrate: trainer + checkpoint/restart + FT + serving engine +
data pipeline + gradient compression."""

import dataclasses
import tempfile

import numpy as np
import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("repro.dist.sharding")  # dist substrate: future PR
import jax.numpy as jnp  # noqa: E402

from repro.configs import reduced_config  # noqa: E402
from repro.data.pipeline import CompressedExampleStore, SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import ShapeConfig  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402
from repro.train.checkpoint import CheckpointManager  # noqa: E402
from repro.train.fault_tolerance import StepWatchdog, run_with_restarts  # noqa: E402
from repro.train.loop import Trainer, TrainerConfig  # noqa: E402

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


class TestTrainer:
    def test_loss_decreases(self):
        from repro.train.optimizer import OptimizerConfig
        cfg = reduced_config("phi4-mini-3.8b")
        tc = TrainerConfig(steps=40, log_every=5)
        opt = OptimizerConfig(peak_lr=5e-3, warmup_steps=5, total_steps=40)
        tr = Trainer(tc, make_host_mesh(), cfg=cfg, shape=SMOKE_SHAPE,
                     opt_cfg=opt)
        tr.run(resume=False)
        first = tr.metrics_log[0]["loss"]
        last = tr.metrics_log[-1]["loss"]
        assert last < first - 0.05, (first, last)

    def test_crash_restart_resume(self):
        cfg = reduced_config("phi3-mini-3.8b")
        with tempfile.TemporaryDirectory() as d:
            tc = TrainerConfig(steps=10, ckpt_dir=d, ckpt_every=4,
                               log_every=2)
            mesh = make_host_mesh()

            def attempt(i):
                tr = Trainer(tc, mesh, cfg=cfg, shape=SMOKE_SHAPE)
                tr.run(resume=True, fail_at_step=6 if i == 0 else None)
                return True

            rep = run_with_restarts(attempt, max_restarts=2)
            assert rep.completed and rep.restarts == 1

    def test_preemption_stops_cleanly(self):
        cfg = reduced_config("phi3-mini-3.8b")
        tc = TrainerConfig(steps=100, log_every=1)
        tr = Trainer(tc, make_host_mesh(), cfg=cfg, shape=SMOKE_SHAPE)
        tr.guard.request_stop()
        out = tr.run(resume=False)
        assert out["steps_done"] == 100  # config count; loop exited early
        assert not tr.metrics_log or tr.metrics_log[-1]["step"] <= 2


class TestCheckpoint:
    def test_atomic_roundtrip_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep_n=2, async_save=False)
            tree = {"a": np.arange(10, dtype=np.float32),
                    "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
            for s in (1, 2, 3):
                cm.save(s, tree, extra={"step": s})
            assert cm.all_steps() == [2, 3]  # keep_n
            step, back, extra = cm.restore()
            assert step == 3 and extra["step"] == 3
            np.testing.assert_array_equal(back["a"], tree["a"])
            assert back["b"]["c"].dtype == jnp.bfloat16

    def test_compressed_checkpoint(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False, compress="blz")
            rng = np.random.default_rng(0)
            tree = {"m": np.abs(rng.normal(0, 1e-3, 8192)).astype(np.float32)}
            cm.save(1, tree)
            _, back, _ = cm.restore()
            scale = float(np.std(tree["m"]))
            assert np.abs(back["m"] - tree["m"]).max() <= scale * 1e-7 + 1e-12

    def test_uncommitted_tmp_ignored(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False)
            cm.save(5, {"x": np.ones(3)})
            (cm.dir / "step_00000009.tmp").mkdir()
            assert cm.latest_step() == 5


class TestFaultTolerance:
    def test_watchdog_fires(self):
        import time
        wd = StepWatchdog(0.05)
        wd.arm(7)
        time.sleep(0.2)
        assert wd.stalled and 7 in wd.stalls

    def test_watchdog_disarm(self):
        import time
        wd = StepWatchdog(0.2)
        wd.arm(1)
        wd.disarm()
        time.sleep(0.3)
        assert not wd.stalled


class TestEngine:
    def test_generate_greedy_deterministic(self):
        cfg = reduced_config("phi4-mini-3.8b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, max_len=48, donate=False)
        toks = np.ones((2, 6), np.int32)
        r1 = eng.generate(toks, max_new=6)
        r2 = eng.generate(toks, max_new=6)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)

    def test_kv_offload_roundtrip(self):
        cfg = dataclasses.replace(reduced_config("gemma2-9b"),
                                  dtype="float32")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, max_len=32, donate=False)
        toks = jnp.ones((2, 8), jnp.int32)
        _, state = eng.prefill(toks)
        store = eng.offload_kv(state, page_tokens=4)
        assert store.nbytes < store.raw_nbytes(4)  # compressed vs f32 raw
        k0, _ = store.get(0, 0)
        assert k0.shape[0] == 4


class TestDataPipeline:
    def test_determinism_across_restart(self):
        lm = SyntheticLM(vocab=128, seq_len=16, global_batch=4, seed=3)
        b5a = lm.batch(5)
        lm2 = SyntheticLM(vocab=128, seq_len=16, global_batch=4, seed=3)
        b5b = lm2.batch(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])

    def test_compressed_store_roundtrip(self):
        lm = SyntheticLM(vocab=512, seq_len=32, global_batch=8, seed=0)
        sample = lm.batch(0)["tokens"]
        store = CompressedExampleStore(sample, vocab=512)
        toks = lm.batch(1)["tokens"]
        store.extend(toks)
        got = store.get_rows(np.arange(8))
        np.testing.assert_array_equal(got, toks)
        assert store.nbytes < store.raw_nbytes(4)


class TestGradCompression:
    def test_error_feedback_reduces_bias(self):
        from repro.tensor.grad_compress import (_dequant_block, _quant_block)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1e-3, 4096), jnp.float32)
        err = jnp.zeros_like(g)
        # accumulate quantized transmissions with error feedback
        total_sent = jnp.zeros_like(g)
        for _ in range(8):
            target = g + err
            q, s = _quant_block(target)
            sent = _dequant_block(q, s, g.shape)
            err = target - sent
            total_sent = total_sent + sent
        # mean of transmissions approaches g much closer than one-shot
        one_q, one_s = _quant_block(g)
        one = _dequant_block(one_q, one_s, g.shape)
        err_fb = float(jnp.abs(total_sent / 8 - g).max())
        err_one = float(jnp.abs(one - g).max())
        assert err_fb <= err_one

    def test_wire_reduction(self):
        from repro.tensor.grad_compress import wire_bytes
        raw, comp = wire_bytes({"w": jnp.zeros((1 << 16,), jnp.float32)})
        assert raw / comp > 3.5
