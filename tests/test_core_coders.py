"""Unit + property tests for the 16-bit interval coders (§4.1, §5.1)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.coders import (TOTAL, DiscreteCoder, UniformCoder,
                               build_alias, quantize_freqs)


def _zipf(n, a=1.2):
    return 1.0 / np.arange(1, n + 1) ** a


class TestQuantize:
    def test_sums_to_total(self):
        for n in (1, 2, 10, 1000):
            k = quantize_freqs(_zipf(n) * 1e6)
            assert int(k.sum()) == TOTAL
            assert (k >= 1).all()

    def test_heavy_skew_keeps_rare_symbols(self):
        counts = np.array([1e9, 1, 1, 1])
        k = quantize_freqs(counts)
        assert (k[1:] >= 1).all() and int(k.sum()) == TOTAL

    def test_rejects_empty(self):
        with pytest.raises(ValueError):
            quantize_freqs(np.array([]))


class TestAlias:
    @pytest.mark.parametrize("n", [1, 2, 3, 17, 255, 256, 1024])
    def test_full_codespace_partition(self, n):
        """Theorem 1: every code maps to exactly one (sym, a) and back."""
        dc = DiscreteCoder(quantize_freqs(_zipf(n) * 1e7))
        codes = np.arange(TOTAL)
        sym, a, k = dc.inv_translate_batch(codes)
        assert (a >= 0).all() and (a < k).all()
        assert (dc.code_for_batch(sym, a) == codes).all()
        # option counts per symbol equal the quantized frequencies
        assert (np.bincount(sym, minlength=n) == dc.tables.k_of).all()

    def test_scalar_matches_batch(self):
        dc = DiscreteCoder(quantize_freqs(_zipf(37) * 1e7))
        rng = np.random.default_rng(0)
        codes = rng.integers(0, TOTAL, 200)
        sym, a, k = dc.inv_translate_batch(codes)
        for i, c in enumerate(codes):
            assert dc.inv_translate(int(c)) == (int(sym[i]), int(a[i]), int(k[i]))
            assert dc.code_for(int(sym[i]), int(a[i])) == int(c)

    def test_bucket_count_power_of_two(self):
        t = build_alias(quantize_freqs(_zipf(300)))
        assert t.n_buckets == 512 and t.m_bits == 9

    def test_lut_agrees(self):
        dc = DiscreteCoder(quantize_freqs(_zipf(99)))
        lut_sym, lut_a = dc.build_lut()
        sym, a, _ = dc.inv_translate_batch(np.arange(TOTAL))
        assert (lut_sym == sym).all() and (lut_a == a).all()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=10**6),
                    min_size=1, max_size=400))
    def test_property_roundtrip(self, counts):
        dc = DiscreteCoder(quantize_freqs(np.array(counts, dtype=float)))
        codes = np.arange(0, TOTAL, 97)
        sym, a, k = dc.inv_translate_batch(codes)
        assert (dc.code_for_batch(sym, a) == codes).all()


class TestUniform:
    @pytest.mark.parametrize("G", [1, 2, 3, 255, 4096, 65535, 65536])
    def test_partition(self, G):
        uc = UniformCoder(G)
        codes = np.arange(TOTAL)
        j, a, k = uc.inv_translate_batch(codes)
        assert (j >= 0).all() and (j < G).all()
        assert (uc.code_for_batch(j, a) == codes).all()
        cnt = np.bincount(j, minlength=G)
        assert cnt.max() - cnt.min() <= 1  # near-exactly uniform

    def test_bad_arity(self):
        with pytest.raises(ValueError):
            UniformCoder(0)
        with pytest.raises(ValueError):
            UniformCoder(TOTAL + 1)
