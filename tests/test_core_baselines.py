"""Baseline coders: arithmetic (App. A), rANS (§6.3), Huffman (Raman-style)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import arithmetic, rans
from repro.core.coders import DiscreteCoder, UniformCoder, quantize_freqs
from repro.core.huffman import BitReader, BitWriter, HuffmanCode


def _mixed_coders(rng, S):
    out = []
    for s in range(S):
        if s % 4 == 2:
            out.append(UniformCoder(int(rng.integers(2, 65537))))
        else:
            n = int(rng.integers(2, 300))
            w = 1.0 / np.arange(1, n + 1) ** 1.2
            out.append(DiscreteCoder(quantize_freqs(w * 1e6)))
    return out


def _draw(rng, c):
    hi = c.G if isinstance(c, UniformCoder) else c.tables.n_symbols
    return int(rng.integers(0, hi))


class TestArithmetic:
    @pytest.mark.parametrize("seed", range(4))
    def test_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        coders = _mixed_coders(rng, int(rng.integers(1, 60)))
        syms = [_draw(rng, c) for c in coders]
        payload, nbits = arithmetic.encode_block(syms, coders)
        assert arithmetic.decode_block(payload, nbits, coders) == syms

    def test_near_optimal_size(self):
        """Arithmetic coding is the entropy yardstick: within 2 bits/block."""
        rng = np.random.default_rng(9)
        coders = _mixed_coders(rng, 32)
        syms = [_draw(rng, c) for c in coders]
        _, nbits = arithmetic.encode_block(syms, coders)
        info = sum(16 - np.log2(c.k(s)) for s, c in zip(syms, coders))
        assert info - 1e-6 <= nbits <= info + 2


class TestRans:
    @pytest.mark.parametrize("seed", range(4))
    def test_roundtrip_alias_layout(self, seed):
        rng = np.random.default_rng(seed)
        coders = _mixed_coders(rng, int(rng.integers(1, 60)))
        syms = [_draw(rng, c) for c in coders]
        words = rans.encode_block(syms, coders)
        out, used = rans.decode_block(words, coders)
        assert out == syms and used == len(words)

    def test_roundtrip_cdf_layout(self):
        rng = np.random.default_rng(5)
        coders = _mixed_coders(rng, 40)
        syms = [_draw(rng, c) for c in coders]
        words = rans.encode_block_cdf(syms, coders)
        out, _ = rans.decode_block_cdf(words, coders)
        assert out == syms

    def test_size_overhead_is_state_flush_only(self):
        rng = np.random.default_rng(6)
        coders = _mixed_coders(rng, 64)
        syms = [_draw(rng, c) for c in coders]
        words = rans.encode_block(syms, coders)
        info = sum(16 - np.log2(c.k(s)) for s, c in zip(syms, coders))
        assert len(words) * 16 <= info + 48  # 32-bit state + <=1 word slack


class TestHuffman:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 300), st.integers(0, 2**31))
    def test_property_roundtrip(self, n, seed):
        rng = np.random.default_rng(seed)
        counts = rng.zipf(1.4, n).astype(float)
        hc = HuffmanCode(counts)
        data = rng.integers(0, n, 64).tolist()
        bw = BitWriter()
        for s in data:
            hc.encode(int(s), bw)
        buf, _ = bw.getvalue()
        br = BitReader(buf)
        assert [hc.decode(br) for _ in data] == data

    def test_mean_length_near_entropy(self):
        w = 1.0 / np.arange(1, 64) ** 1.1
        p = w / w.sum()
        hc = HuffmanCode(w * 1e6)
        H = -(p * np.log2(p)).sum()
        assert H <= hc.mean_bits(p) <= H + 1  # classic Huffman bound
