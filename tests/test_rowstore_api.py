"""RowStore protocol (DESIGN.md §3): property-style reference-model tests,
delta-merge compaction, and backend equivalence after merge.

Every store must behave like a plain dict keyed by dense ids under any
interleaving of insert/update/delete/merge/get_many/scan; BlitzStore's
merge must keep the bytes bounded and never change what reads return.
"""

import numpy as np
import pytest

from repro.core.blitzcrank import _raw_row_bytes
from repro.oltp import tpcc
from repro.oltp.store import (OVERLAY_ENTRY_OVERHEAD, BlitzStore,
                              LRUFastPath, RamanStore, UncompressedStore)

SCHEMA, GEN = tpcc.TABLES["orderline"]


def _rand_row(rng, base):
    r = dict(base[int(rng.integers(0, len(base)))])
    r["ol_quantity"] = int(rng.integers(1, 60))
    # occasionally beyond the trained range: exercises the escape path
    r["ol_amount"] = round(float(rng.uniform(0.01, 12000.0)), 2)
    r["ol_o_id"] = int(rng.integers(0, 200))
    return r


def _assert_row(got, exp):
    assert got is not None
    for c in SCHEMA:
        if c.kind == "float":
            assert abs(got[c.name] - exp[c.name]) <= c.precision / 2 + 1e-9
        else:
            assert got[c.name] == exp[c.name], c.name


def _makers():
    makers = {
        "silo": lambda s, sample: UncompressedStore(s, sample),
        "raman": lambda s, sample: RamanStore(s, sample),
        "blitz_auto": lambda s, sample: BlitzStore(
            s, sample, merge_min_bytes=1 << 10),
        "blitz_manual": lambda s, sample: BlitzStore(
            s, sample, auto_merge=False),
        "lru_blitz": lambda s, sample: LRUFastPath(
            BlitzStore(s, sample, merge_min_bytes=1 << 10), capacity=64),
    }
    try:
        import zstandard  # noqa: F401
        from repro.oltp.store import ZstdStore
        makers["zstd"] = ZstdStore
    except ImportError:
        pass
    return makers


class TestReferenceModel:
    """Any op interleaving matches a plain-dict model, for every store."""

    @pytest.mark.parametrize("kind", sorted(_makers()))
    def test_random_ops_match_reference(self, kind):
        base = GEN(500)
        store = _makers()[kind](SCHEMA, base[:250])
        ref = {}
        dead = set()
        ids = store.insert_many(base[:300])
        for i, r in zip(ids, base[:300]):
            ref[i] = r
        rng = np.random.default_rng(42)

        for step in range(60):
            span = len(ref) + len(dead)
            op = ("insert", "update", "delete", "get",
                  "merge", "scan")[int(rng.integers(0, 6))]
            if op == "insert":
                rows = [_rand_row(rng, base)
                        for _ in range(int(rng.integers(1, 12)))]
                new_ids = store.insert_many(rows)
                assert list(new_ids) == list(range(span, span + len(rows)))
                for i, r in zip(new_ids, rows):
                    ref[i] = r
            elif op == "update" and ref:
                keys = rng.choice(sorted(ref), replace=False,
                                  size=min(len(ref), int(rng.integers(1, 10))))
                rows = [_rand_row(rng, base) for _ in keys]
                store.update_many(keys.tolist(), rows)
                for i, r in zip(keys.tolist(), rows):
                    ref[i] = r
                if dead:  # updating a tombstoned row must raise
                    with pytest.raises(KeyError):
                        store.update(next(iter(dead)), rows[0])
            elif op == "delete" and span:
                keys = rng.integers(0, span, int(rng.integers(1, 6)))
                newly = ({int(i) for i in keys} - dead) & set(ref)
                assert store.delete_many(keys) == len(newly)
                for i in newly:
                    dead.add(i)
                    del ref[i]
            elif op == "get" and span:
                keys = rng.integers(0, span, 20)
                for i, g in zip(keys.tolist(), store.get_many(keys)):
                    if i in dead:
                        assert g is None
                        with pytest.raises(KeyError):
                            store.get(i)
                    else:
                        _assert_row(g, ref[i])
            elif op == "merge":
                if hasattr(store, "merge"):
                    store.merge()
                elif hasattr(store, "sync"):
                    store.sync()
            elif op == "scan":
                seen = dict(store.scan(batch=64))
                assert set(seen) == set(ref)

        # final sweep: every id answers correctly
        span = len(ref) + len(dead)
        assert len(store) == span
        assert store.n_live == len(ref)
        for i, g in zip(range(span), store.get_many(range(span))):
            if i in dead:
                assert g is None
            else:
                _assert_row(g, ref[i])


class TestMergeCompaction:
    def test_auto_merge_bounds_bytes_under_updates(self):
        rows = GEN(2000)
        store = BlitzStore(SCHEMA, rows, merge_min_bytes=1 << 12)
        store.insert_many(rows)
        post_load = store.nbytes
        counts = tpcc.run_transaction_mix(
            store, 6000, seed=5, p_payment=1.0, p_order_status=0.0,
            p_new_order=0.0, p_delivery=0.0, balance_col="ol_amount",
            amount=5.0)
        s = store.stats()
        assert s["merges"] > 0, "auto-merge never triggered"
        assert s["rewrites"] > 0, "dead bytes never reclaimed"
        assert store.nbytes <= 1.6 * post_load, (store.nbytes, post_load)
        assert counts["payments"] > 3000
        # reads identical to the scalar per-tuple decompress_block path
        store.merge()  # drain the overlay so the arena answers everything
        idx = np.random.default_rng(0).integers(0, len(store), 200)
        assert store.get_many(idx) == [store.table.get(int(i)) for i in idx]

    def test_merge_preserves_reads_and_clears_overlay(self):
        rows = GEN(400)
        store = BlitzStore(SCHEMA, rows, auto_merge=False)
        store.insert_many(rows)
        rng = np.random.default_rng(1)
        keys = rng.choice(400, 80, replace=False).tolist()
        new = [dict(rows[i], ol_quantity=int(rng.integers(100, 200)))
               for i in keys]
        store.update_many(keys, new)
        store.delete_many([0, 1, 2])
        before = store.get_many(range(len(store)))
        assert store.stats()["overlay_rows"] == 80
        store.merge()
        s = store.stats()
        assert s["overlay_rows"] == 0 and s["tombstones"] == 0
        # merge re-encodes: floats come back quantized (within precision/2),
        # everything else identical
        after = store.get_many(range(len(store)))
        for a, b in zip(after, before):
            if b is None:
                assert a is None
            else:
                _assert_row(a, b)
        # a second merge is a bit-exact no-op for reads
        store.merge()
        assert store.get_many(range(len(store))) == after

    def test_post_merge_get_many_backend_bit_identical(self):
        pytest.importorskip("jax")
        rows = GEN(1200)
        store = BlitzStore(SCHEMA, rows, merge_min_bytes=1 << 10)
        store.insert_many(rows)
        plan = store.codec.compile()
        assert plan is not None and plan.pallas_ok
        rng = np.random.default_rng(2)
        for _ in range(3):
            keys = rng.choice(1200, 200, replace=False).tolist()
            got = store.get_many(keys)
            store.update_many(
                keys, [dict(r, ol_quantity=int(rng.integers(1, 60)))
                       for r in got])
        store.merge()
        assert store.stats()["overlay_rows"] == 0
        idx = rng.integers(0, 1200, 400)
        out_np = store.get_many(idx, backend="numpy")
        out_pl = store.get_many(idx, backend="pallas")
        assert out_np == out_pl  # bit-identical across decode backends
        assert out_np == [store.table.get(int(i)) for i in idx]  # scalar ref


class TestAccountingAndCounters:
    def test_overlay_reported_separately_with_entry_overhead(self):
        rows = GEN(300)
        store = BlitzStore(SCHEMA, rows, auto_merge=False)
        store.insert_many(rows)
        assert store.stats()["overlay_bytes"] == 0
        r = store.get(5)
        r["ol_quantity"] = 7
        store.update(5, r)
        s = store.stats()
        assert s["overlay_bytes"] == _raw_row_bytes(r) + OVERLAY_ENTRY_OVERHEAD
        assert s["nbytes"] == s["arena_bytes"] + s["overlay_bytes"]
        # re-updating the same row replaces, not accumulates
        store.update(5, r)
        assert store.stats()["overlay_bytes"] == s["overlay_bytes"]
        # deleting the row drops its overlay entry, leaves one tombstone
        store.delete(5)
        s2 = store.stats()
        assert s2["overlay_bytes"] == 0 and s2["tombstones"] == 1

    def test_replace_many_rejects_duplicate_indices(self):
        rows = GEN(100)
        store = BlitzStore(SCHEMA, rows, auto_merge=False)
        store.insert_many(rows)
        with pytest.raises(ValueError, match="unique"):
            store.table.replace_many([5, 5], [rows[5], rows[6]])
        # update_many dedups (last write wins) before reaching the table
        store.update_many([5, 5], [rows[6], rows[7]])
        store.merge()
        assert store.get(5)["ol_amount"] == pytest.approx(
            rows[7]["ol_amount"], abs=0.01)

    def test_escape_counters_track_model_misses(self):
        rows = GEN(300)
        store = BlitzStore(SCHEMA, rows)
        store.insert_many(rows)
        before = store.stats()["escapes"].get("ol_dist_info", 0)
        bad = dict(rows[0])
        bad["ol_dist_info"] = "a layout the template has never seen"
        i = store.insert(bad)
        after = store.stats()["escapes"]["ol_dist_info"]
        assert after >= before + 1
        assert store.get(i)["ol_dist_info"] == bad["ol_dist_info"]

    def test_return_conventions_on_every_store(self):
        """Protocol contract: insert_many -> range, delete_many -> count of
        effective deletes, scalar delete -> bool, all idempotent."""
        rows = GEN(120)
        for kind, maker in _makers().items():
            store = maker(SCHEMA, rows[:60])
            ids = store.insert_many(rows)
            assert isinstance(ids, range) and len(ids) == len(rows), kind
            assert isinstance(store.insert(rows[0]), int)
            assert store.delete(5) is True, kind
            assert store.delete(5) is False, kind  # already dead
            # repeats dedup, dead ids are no-ops: count is effective deletes
            assert store.delete_many([5, 6, 6, 7]) == 2, kind
            assert store.delete_many([5, 6, 7]) == 0, kind
            with pytest.raises(KeyError):
                store.get(6)
            assert store.get_many([5, 6, 7, 8])[:3] == [None] * 3, kind

    def test_stats_protocol_keys_on_every_store(self):
        rows = GEN(120)
        for maker in _makers().values():
            store = maker(SCHEMA, rows[:60])
            store.insert_many(rows)
            s = store.stats()
            for key in ("name", "n_ids", "n_live", "n_deleted", "nbytes"):
                assert key in s, (s.get("name"), key)
            assert s["n_ids"] == len(rows) and s["n_deleted"] == 0
