"""Adaptive model maintenance (repro/adaptive, DESIGN.md §4): drift
detection, per-column background refit, versioned plan migration, and the
maintenance scheduler's deterministic step().

The invariant under test throughout: every plan version ever used to encode
a block stays decodable, and reads through any path (scalar per-block,
batched numpy, Pallas interpret) agree across mixed plan versions.
"""

import numpy as np
import pytest

from repro.adaptive import (DriftConfig, DriftMonitor, MaintenanceConfig,
                            ReservoirSample, refit_codec)
from repro.core import ColumnSpec, CompressedTable, TableCodec
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore

SCHEMA = [
    ColumnSpec("city", "cat"),
    ColumnSpec("qty", "int"),
    ColumnSpec("amount", "float", precision=0.01),
    ColumnSpec("note", "str"),
]
OLD_CITIES = ["Paris", "Rome", "Oslo"]
NEW_CITIES = ["Kyoto", "Quito", "Dakar"]
OLD_WORDS = ["red", "blue", "jade"]
NEW_WORDS = ["onyx", "teal", "plum"]


def gen_rows(n, seed=0, cities=OLD_CITIES, words=OLD_WORDS, amount_hi=100.0):
    rng = np.random.default_rng(seed)
    return [{
        "city": cities[int(rng.integers(0, len(cities)))],
        "qty": int(rng.integers(0, 5000)),
        "amount": round(float(rng.uniform(0.0, amount_hi)), 2),
        "note": f"{words[int(rng.integers(0, len(words)))]}-"
                f"{words[int(rng.integers(0, len(words)))]}",
    } for _ in range(n)]


def drifted_rows(n, seed=1):
    """Rows from the second-generation value sets: escape on 3 columns."""
    return gen_rows(n, seed=seed, cities=NEW_CITIES, words=NEW_WORDS,
                    amount_hi=100.0)


class TestDriftMonitor:
    def test_no_drift_no_trigger(self):
        codec = TableCodec.fit(gen_rows(400), SCHEMA)
        plan = codec.compile()
        mon = DriftMonitor(DriftConfig(rate_threshold=0.02, min_escapes=5,
                                       min_window_rows=50))
        plan.encode_rows(gen_rows(200, seed=2))
        assert mon.check(plan) == []

    def test_rate_and_floor_must_both_trip(self):
        codec = TableCodec.fit(gen_rows(400), SCHEMA)
        plan = codec.compile()
        mon = DriftMonitor(DriftConfig(rate_threshold=0.05, min_escapes=8,
                                       min_window_rows=50))
        # 4 escapes over 204 rows: above neither threshold pair
        plan.encode_rows(gen_rows(200, seed=2) + drifted_rows(4))
        assert mon.check(plan) == []
        # 60 more escaping rows: rate ~0.24 and floor cleared
        plan.encode_rows(drifted_rows(60))
        drifted = mon.check(plan)
        assert set(drifted) == {"city", "note"}
        assert mon.last_report.window_rows == 264

    def test_small_window_never_judged(self):
        codec = TableCodec.fit(gen_rows(400), SCHEMA)
        plan = codec.compile()
        mon = DriftMonitor(DriftConfig(min_window_rows=1000, min_escapes=1,
                                       rate_threshold=0.0001))
        plan.encode_rows(drifted_rows(50))
        assert mon.check(plan) == []


class TestReservoir:
    def test_capacity_bound_and_count(self):
        res = ReservoirSample(capacity=64, seed=0)
        res.add_many(gen_rows(1000))
        assert len(res) == 64 and res.seen == 1000

    def test_holds_recent_values_eventually(self):
        res = ReservoirSample(capacity=128, seed=0)
        res.add_many(gen_rows(128))
        res.add_many(drifted_rows(512))
        cities = {r["city"] for r in res.rows}
        assert cities & set(NEW_CITIES)


class TestRefitCodec:
    def test_refit_preserves_old_vocab_and_covers_new(self):
        old = TableCodec.fit(gen_rows(500), SCHEMA)
        sample = gen_rows(300, seed=1, cities=NEW_CITIES)
        new = refit_codec(old, sample, ["city"])
        plan = new.compile()
        assert plan is not None
        # unchanged columns share the very same model objects
        assert new.models["qty"] is old.models["qty"]
        assert new.models["amount"] is old.models["amount"]
        assert new.models["note"] is old.models["note"]
        # old AND new cities conform under the refit plan (qty/amount may
        # graze their fitted range edges on fresh seeds; city must not)
        plan.encode_rows(
            gen_rows(50, seed=5) + gen_rows(50, seed=6, cities=NEW_CITIES))
        assert plan.escape_counts["city"] == 0
        # the old plan must NOT cover the new cities (sanity of the setup)
        old_plan = old.compile(force=True)
        old_plan.encode_rows(gen_rows(50, seed=6, cities=NEW_CITIES))
        assert old_plan.escape_counts["city"] == 50

    def test_string_refit_covers_new_words_old_rows_stay_on_old_plan(self):
        # String dictionaries are rebuilt from the reservoir only (no vocab
        # carry-over, see refit.py): new-word rows conform under the new
        # plan, old-word rows escape it — they stay readable on their old
        # plan version, which is exactly what versioned blocks are for.
        old = TableCodec.fit(gen_rows(500), SCHEMA)
        new = refit_codec(old, gen_rows(300, seed=2, words=NEW_WORDS),
                          ["note"])
        plan = new.compile()
        assert plan is not None
        plan.encode_rows(gen_rows(50, seed=6, words=NEW_WORDS))
        assert plan.escape_counts["note"] == 0
        plan.encode_rows(gen_rows(50, seed=5))
        assert plan.escape_counts["note"] == 50

    def test_numeric_headroom_extends_range(self):
        old = TableCodec.fit(gen_rows(500), SCHEMA)
        sample = gen_rows(300, seed=3, amount_hi=200.0)
        new = refit_codec(old, sample, ["amount"], numeric_headroom=0.5)
        m = new.models["amount"]
        hi = m.vmin + (m.total_steps - 1) * m.p
        assert hi >= 200.0 + 0.5 * 200.0 * 0.9  # ~50% pad on the span
        # old range stays conforming
        plan = new.compile()
        plan.encode_rows(gen_rows(50, seed=5))
        assert plan.escape_counts["amount"] == 0

    def test_refit_rejects_unknown_or_empty_columns(self):
        old = TableCodec.fit(gen_rows(200), SCHEMA)
        with pytest.raises(ValueError):
            refit_codec(old, gen_rows(50), [])
        with pytest.raises(KeyError):
            refit_codec(old, gen_rows(50), ["nope"])

    def test_conditional_refit_preserves_per_parent_vocab(self):
        from repro.core import (CategoricalModel, ColumnSpec,
                                ConditionalCategoricalModel, FitStats)
        schema = [ColumnSpec("state", "cat"), ColumnSpec("city", "cat")]
        old_pairs = [("CA", c) for c in ("LA", "SF", "SD")] * 10 + [
            ("TX", c) for c in ("Austin", "Dallas")
        ] * 10
        models = {
            "state": CategoricalModel([p for p, _ in old_pairs]),
            "city": ConditionalCategoricalModel(old_pairs, "state"),
        }
        stats = FitStats(order=("state", "city"),
                         parents={"state": None, "city": "state"})
        old = TableCodec(schema, models, ["state", "city"], stats)
        assert old.compile() is not None
        # reservoir: CA appears often but only with a NEW city
        sample = [{"state": "CA", "city": "Fresno"}] * 40
        new = refit_codec(old, sample, ["city"])
        plan = new.compile()
        assert plan is not None
        rows = [{"state": "CA", "city": "SF"},      # old pair
                {"state": "CA", "city": "Fresno"},  # new pair
                {"state": "TX", "city": "Dallas"}]  # old pair, other group
        plan.encode_rows(rows)
        assert plan.escape_counts["city"] == 0

    def test_int_refit_keeps_numeric_model_kind(self):
        from repro.core.models import NumericModel
        old = TableCodec.fit(gen_rows(500), SCHEMA)
        assert isinstance(old.models["qty"], NumericModel)
        # reservoir with few distinct qty values would flip to categorical
        rng = np.random.default_rng(4)
        sample = [dict(r, qty=int(rng.integers(0, 20)) * 10)
                  for r in gen_rows(300, seed=4)]
        new = refit_codec(old, sample, ["qty"])
        assert isinstance(new.models["qty"], NumericModel)
        plan = new.compile()
        plan.encode_rows(gen_rows(50, seed=5))   # old range still covered
        assert plan.escape_counts["qty"] == 0


class TestVersionedTable:
    def _table_with_two_versions(self):
        codec = TableCodec.fit(gen_rows(500), SCHEMA)
        table = CompressedTable(codec)
        table.extend(gen_rows(100, seed=11))     # v0, fast
        table.extend(drifted_rows(40, seed=12))  # v0, slow (escapes)
        new = refit_codec(codec, drifted_rows(300, seed=13),
                          ["city", "note"])
        assert new.compile() is not None
        table.install_codec(new)
        table.extend(drifted_rows(30, seed=14))  # v1, fast
        return table

    def test_mixed_version_reads_agree_with_scalar(self):
        table = self._table_with_two_versions()
        assert table.n_versions == 2
        vr = table.version_rows()
        assert vr[0] == 140 and vr[1] == 30
        idx = list(range(len(table)))
        batched = table.get_many(idx)
        scalar = [table.get(i) for i in idx]
        assert batched == scalar

    def test_migrate_reencodes_only_stale_slow_blocks(self):
        table = self._table_with_two_versions()
        before = [table.get(i) for i in range(len(table))]
        live = table._row2block[:table._rows_stored]
        stale = int((~table.block_fast[live]
                     & (table.block_versions[live] == 0)).sum())
        n_v0_fast = int((table.block_fast[live]
                         & (table.block_versions[live] == 0)).sum())
        assert stale >= 40                   # at least the 40 drifted rows
        n = table.migrate_rows(limit=1000)
        assert n == stale                    # exactly the stale slow blocks
        assert table.migrated_rows == stale
        vr = table.version_rows()
        assert vr[0] == n_v0_fast            # old fast blocks untouched
        assert vr[1] == 30 + stale
        # no stale slow blocks remain; rows conforming to the new plan
        # turned fast (the few that escape on unrefit columns stay slow,
        # but now under the current version so they won't be retried)
        live = table._row2block[:table._rows_stored]
        lb = live[live >= 0]
        assert not (~table.block_fast[lb]
                    & (table.block_versions[lb] < 1)).any()
        assert int(table.block_fast[lb].sum()) >= n_v0_fast + 40
        after = [table.get(i) for i in range(len(table))]
        assert after == before               # reads unchanged bit-for-bit
        assert table.migrate_rows(limit=1000) == 0   # idempotent

    def test_version_tags_survive_rewrite(self):
        table = self._table_with_two_versions()
        table.migrate_rows(limit=1000)
        vr = table.version_rows()
        assert table.dead_bytes > 0
        table.rewrite()
        assert table.dead_bytes == 0
        assert table.version_rows() == vr    # tags carried through
        idx = list(range(len(table)))
        assert table.get_many(idx) == [table.get(i) for i in idx]

    def test_install_codec_guards(self):
        codec = TableCodec.fit(gen_rows(200), SCHEMA)
        table = CompressedTable(codec)
        other = TableCodec.fit(gen_rows(200), list(reversed(SCHEMA)))
        with pytest.raises(ValueError, match="order"):
            table.install_codec(other)
        # the uint16 plan_version tag must never wrap
        table._codecs.extend([codec] * (0xFFFF - len(table._codecs)))
        with pytest.raises(ValueError, match="version limit"):
            table.install_codec(codec)

    def test_migration_does_not_feed_the_drift_window(self):
        table = self._table_with_two_versions()
        plan = table.codec.compile()
        w_rows, w_esc = plan.window_rows, dict(plan.window_escapes)
        assert table.migrate_rows(limit=1000) > 0
        # maintenance re-encodes are invisible to the drift monitor
        assert plan.window_rows == w_rows
        assert plan.window_escapes == w_esc

    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_mixed_version_backends_bit_identical(self, backend):
        pytest.importorskip("jax")
        table = self._table_with_two_versions()
        idx = list(range(len(table)))
        assert table.get_many(idx, backend=backend) == [
            table.get(i) for i in idx
        ]


class TestScheduler:
    CFG = MaintenanceConfig(
        drift=DriftConfig(rate_threshold=0.02, min_escapes=10,
                          min_window_rows=64),
        check_every=10**9,  # automatic stepping off: tests drive step()
        min_refit_rows=64, migrate_rows_per_step=1000)

    def _store(self, adaptive=None):
        store = BlitzStore(SCHEMA, gen_rows(500), auto_merge=False,
                           adaptive=adaptive or self.CFG)
        store.insert_many(gen_rows(400, seed=21))
        return store

    def test_step_without_drift_is_a_noop(self):
        store = self._store()
        rep = store.maintenance.step()
        assert rep["drifted"] == [] and rep["refits"] == 0
        assert store.n_versions == 1

    def test_step_refits_drifted_columns_and_migrates(self):
        store = self._store()
        store.insert_many(drifted_rows(200, seed=22))
        rep = store.maintenance.step()
        assert set(rep["refit_columns"]) >= {"city", "note"}
        assert store.n_versions == 2
        assert rep["migrated_rows"] > 0
        # post-refit drifted inserts take the fast path under the new plan
        plan = store.codec.compile()
        _, ok = plan.encode_rows(drifted_rows(50, seed=23))
        assert ok.all()
        # window was reset on the old plan
        assert rep["window_rows"] >= 200
        s = store.stats()
        assert s["plan_versions"] == 2
        assert s["maintenance"]["refits"] == 1

    def test_futility_freeze_stops_hopeless_columns(self):
        store = self._store()

        def noise(n, seed):
            r = np.random.default_rng(seed)
            return [dict(row, note=f"x{int(r.integers(0, 10**9))}-y")
                    for row in gen_rows(n, seed=seed)]

        sched = store.maintenance
        for i in range(6):
            store.insert_many(noise(150, seed=30 + i))
            sched.step()
            if "note" in sched.frozen:
                break
        assert "note" in sched.frozen
        versions_at_freeze = store.n_versions
        store.insert_many(noise(150, seed=99))
        sched.step()
        assert store.n_versions == versions_at_freeze  # no more churn

    def test_maybe_step_fires_on_write_cadence(self):
        cfg = MaintenanceConfig(
            drift=self.CFG.drift, check_every=128,
            min_refit_rows=64, migrate_rows_per_step=1000)
        store = self._store(adaptive=cfg)
        steps0 = store.maintenance.steps
        store.insert_many(drifted_rows(200, seed=40))
        assert store.maintenance.steps > steps0
        assert store.n_versions == 2   # the drift was refit automatically


class TestEndToEndDriftMix:
    def test_adaptive_store_on_drifting_tpcc_mix(self):
        schema, gen = tpcc.TABLES["customer"]
        rows = gen(1200)
        cfg = MaintenanceConfig(
            drift=DriftConfig(rate_threshold=0.02, min_escapes=24,
                              min_window_rows=192),
            check_every=512, min_refit_rows=128,
            migrate_rows_per_step=2000)
        store = BlitzStore(schema, rows, sample=1 << 12,
                           merge_min_bytes=1 << 13, adaptive=cfg)
        store.insert_many(rows)
        tpcc.run_transaction_mix(
            store, 6000, seed=5, batch=64, p_payment=0.3,
            p_order_status=0.15, p_new_order=0.5, p_delivery=0.05,
            new_row_fn=tpcc.drifting_customer_row, drift=1.0)
        s = store.stats()
        assert s["plan_versions"] >= 2, "drift never triggered a refit"
        assert len(s["version_rows"]) >= 2, "no mixed-version arena"
        # reads across mixed plan versions == scalar per-block reference
        rng = np.random.default_rng(7)
        idx = [int(i) for i in rng.integers(0, len(store), 300)]

        def scalar_ref(i):
            if i in store._tombstones:
                return None
            ov = store._overlay.get(i)
            if ov is not None:
                return dict(ov)
            return (store.table.get(i)
                    if store.table.is_live(i) else None)

        ref = [scalar_ref(i) for i in idx]
        assert store.get_many(idx, backend="numpy") == ref
