"""Compiled fast path (DESIGN.md §2): slot plans, CSR stores, batch decode.

Three-way equivalence on random schemas: the scalar TableCodec encode/decode
vs the compiled ``encode_batch``/``decode_batch``/``decode_select`` vs the
Pallas ``delayed_decode`` kernel (interpret mode) must produce identical
symbols and identical code streams.
"""

import numpy as np
import pytest

from repro.core import ColumnSpec, CompressedTable, TableCodec
from repro.core.coders import TOTAL, UniformCoder
from repro.oltp.store import BlitzStore, LRUFastPath
from repro.oltp import tpcc


def _mixed_rows(n, seed=0):
    rng = np.random.default_rng(seed)
    cities = [f"City{i:02d}" for i in range(30)]
    words = ["alpha", "beta", "gamma", "delta"]
    return [{
        "id": int(i),
        "city": cities[int(rng.zipf(1.3)) % 30],
        "qty": int(rng.integers(1, 100)),
        "amount": float(np.round(rng.uniform(0.01, 999.99), 2)),
        "info": f"{words[int(rng.integers(0, 4))]}-"
                f"{words[int(rng.integers(0, 4))]}"
                f"#{int(rng.integers(0, 50)):02d}",
    } for i in range(n)]


MIXED_SCHEMA = [
    ColumnSpec("id", "int"), ColumnSpec("city", "cat"),
    ColumnSpec("qty", "int"), ColumnSpec("amount", "float", precision=0.01),
    ColumnSpec("info", "str"),
]


def _hier_rows(n, seed=1):
    rng = np.random.default_rng(seed)
    states = ["CA", "TX", "NY"]
    city_of = {"CA": ["LA", "SF"], "TX": ["HOU", "AUS"], "NY": ["NYC", "BUF"]}
    rows = []
    for _ in range(n):
        st = states[int(rng.integers(0, 3))]
        ci = city_of[st][int(rng.integers(0, 2))]
        zp = f"z{(hash((st, ci)) % 89):02d}{int(rng.integers(0, 4))}"
        rows.append({"state": st, "city": ci, "zip": zp})
    return rows


HIER_SCHEMA = [ColumnSpec("state", "cat"), ColumnSpec("city", "cat"),
               ColumnSpec("zip", "cat")]


class TestUniformTables:
    """UniformCoder lowered to the [M, 7] bucket table == closed form."""

    @pytest.mark.parametrize("G", [1, 2, 3, 5, 7, 255, 256, 1000, 4096,
                                   50000, 65536])
    def test_all_codes(self, G):
        jax = pytest.importorskip("jax")  # noqa: F841
        from repro.kernels import ref
        uc = UniformCoder(G)
        tab, m = ref.pack_tables_uniform(uc)
        codes = np.arange(TOTAL, dtype=np.int64)
        sym_r, a_r, k_r = (np.asarray(x) for x in
                           ref.alias_decode_ref(__import__("jax").numpy.asarray(
                               codes.astype(np.int32)), tab, m))
        sym_c, a_c, k_c = uc.inv_translate_batch(codes)
        np.testing.assert_array_equal(sym_r, sym_c)
        np.testing.assert_array_equal(a_r, a_c)
        np.testing.assert_array_equal(k_r, k_c)


class TestPlanEquivalence:
    @pytest.mark.parametrize("rows_fn,schema", [
        (_mixed_rows, MIXED_SCHEMA),
    ])
    def test_codes_identical_to_scalar(self, rows_fn, schema):
        rows = rows_fn(1500)
        codec = TableCodec.fit(rows, schema, sample=1024)
        plan = codec.compile()
        assert plan is not None, codec.plan_fallback_reason
        syms, ok = plan.encode_rows(rows[:300])
        assert ok.mean() > 0.5  # the schema is mostly plan-conforming
        sel = np.nonzero(ok)[0][:60]
        batch_codes, offsets = plan.encode_batch(syms[sel])
        for j, r in enumerate(sel):
            scalar = codec._scalar_compress([rows[int(r)]])
            np.testing.assert_array_equal(
                scalar, batch_codes[offsets[j]:offsets[j + 1]])

    def test_decode_batch_and_select_roundtrip(self):
        rows = _mixed_rows(1200, seed=3)
        codec = TableCodec.fit(rows, MIXED_SCHEMA, sample=1024)
        plan = codec.compile()
        syms, ok = plan.encode_rows(rows)
        syms = syms[ok]
        codes, offsets = plan.encode_batch(syms)
        back = plan.decode_batch(codes, offsets)
        np.testing.assert_array_equal(back, syms)
        rng = np.random.default_rng(0)
        sel = rng.integers(0, syms.shape[0], 200)
        np.testing.assert_array_equal(
            plan.decode_select(codes, offsets, sel), syms[sel])
        # decoded rows match the scalar decoder's reconstruction
        rows_b = plan.decode_syms_to_rows(syms[sel][:20])
        kept = [r for r, o in zip(rows, ok) if o]
        for r, i in zip(rows_b, sel[:20]):
            scalar = codec.decompress_block(
                codes[offsets[i]:offsets[i + 1]], 1)[0]
            assert r == scalar
            assert r["id"] == kept[int(i)]["id"]

    def test_pallas_matches_numpy_and_scalar(self):
        pytest.importorskip("jax")
        rows = _mixed_rows(900, seed=5)
        codec = TableCodec.fit(rows, MIXED_SCHEMA, sample=512)
        plan = codec.compile()
        assert plan.pallas_ok
        syms, ok = plan.encode_rows(rows)
        syms = syms[ok]
        codes, offsets = plan.encode_batch(syms)
        rng = np.random.default_rng(1)
        sel = rng.integers(0, syms.shape[0], 300)
        out_np = plan.decode_select(codes, offsets, sel, backend="numpy")
        out_pl = plan.decode_select(codes, offsets, sel, backend="pallas")
        np.testing.assert_array_equal(out_np, syms[sel])
        np.testing.assert_array_equal(out_pl, syms[sel])

    def test_conditional_chain_plan(self):
        rows = _hier_rows(2500)
        codec = TableCodec.fit(rows, HIER_SCHEMA, correlation=True,
                               sample=2048)
        if not any(codec.stats.parents.values()):
            pytest.skip("structure learning found no parents")
        plan = codec.compile()
        assert plan is not None, codec.plan_fallback_reason
        assert not plan.pallas_ok  # conditional slots are numpy-only
        for r in rows[:80]:
            scalar = codec._scalar_compress([r])
            syms, ok = plan.encode_rows([r])
            if not ok[0]:
                continue
            codes, offs = plan.encode_batch(syms)
            np.testing.assert_array_equal(scalar, codes)
        table = CompressedTable(codec)
        table.extend(rows)
        table.flush()
        idx = np.random.default_rng(2).integers(0, len(rows), 400)
        got = table.get_many(idx)
        for g, i in zip(got, idx):
            assert g == rows[int(i)]

    def test_fallback_reasons(self):
        rows = _mixed_rows(400)
        codec = TableCodec.fit(rows, MIXED_SCHEMA, sample=256, block_tuples=4)
        assert codec.compile() is None
        assert "block_tuples" in codec.plan_fallback_reason
        ts_rows = [{"t": float(i) + 0.1 * (i % 7)} for i in range(300)]
        ts_codec = TableCodec.fit(ts_rows, [ColumnSpec("t", "ts")], sample=128)
        assert ts_codec.compile() is None
        assert "time-series" in ts_codec.plan_fallback_reason
        # scalar fallback still round-trips through the store
        table = CompressedTable(codec)
        for r in rows[:40]:
            table.append(r)
        table.flush()
        got = table.get_many(range(40))
        assert [g["id"] for g in got] == [r["id"] for r in rows[:40]]


class TestStoreBatchPath:
    def test_get_many_matches_get(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(900)
        store = BlitzStore(schema, rows[:450])
        store.insert_many(rows)
        rng = np.random.default_rng(0)
        idx = rng.integers(0, 900, 300)
        batch = store.get_many(idx)
        scalar = [store.get(int(i)) for i in idx]
        assert batch == scalar

    def test_batched_point_gets_helper(self):
        schema, gen = tpcc.TABLES["stock"]
        rows = gen(400)
        store = BlitzStore(schema, rows[:200])
        store.insert_many(rows)
        rng = np.random.default_rng(3)
        keys = tpcc.zipf_keys(rng, 400, 250)
        out = tpcc.batched_point_gets(store, keys, batch=64)
        assert len(out) == 250
        assert out[0] == store.get(int(keys[0]))

    def test_updates_visible_through_batch_gets(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(200)
        store = BlitzStore(schema, rows[:100])
        store.insert_many(rows)
        row = store.get(7)
        row["ol_quantity"] = 999
        store.update(7, row)
        assert store.get(7)["ol_quantity"] == 999
        assert store.get_many([6, 7, 8])[1]["ol_quantity"] == 999

    def test_nbytes_counts_pending_and_offsets(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(300)
        store = BlitzStore(schema, rows[:150], block_tuples=8)
        flushed_zero = store.nbytes
        for r in rows[:4]:  # stays pending: block not full
            store.insert(r)
        assert store.table._pending, "rows should be buffered"
        assert store.nbytes > flushed_zero, \
            "pending rows must count toward nbytes"


class TestLRUWriteback:
    def test_eviction_writes_back_dirty_rows(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(120)
        store = BlitzStore(schema, rows[:60])
        store.insert_many(rows)
        fp = LRUFastPath(store, capacity=8)
        for i in range(50):  # far beyond capacity: forces evictions
            fp.read_modify_write(i, lambda r, i=i: r.update(ol_quantity=1000 + i))
        fp.sync()
        assert fp.writebacks >= 42
        for i in range(50):
            assert store.get(i)["ol_quantity"] == 1000 + i, i
        # unmodified rows unchanged
        assert store.get(60)["ol_quantity"] == rows[60]["ol_quantity"]

    def test_zero_capacity_cache_never_loses_updates(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(40)
        store = BlitzStore(schema, rows[:20])
        store.insert_many(rows)
        fp = LRUFastPath(store, capacity=0)
        for i in range(10):
            fp.read_modify_write(i, lambda r, i=i: r.update(ol_quantity=i + 500))
            fp.read_modify_write(i, lambda r, i=i: r.update(ol_number=i))
        fp.sync()  # must not raise on dangling dirty ids
        for i in range(10):
            got = store.get(i)
            assert got["ol_quantity"] == i + 500 and got["ol_number"] == i


class TestGetManyContracts:
    def test_duplicate_slow_path_indices_get_fresh_dicts(self):
        rows = _mixed_rows(60)
        codec = TableCodec.fit(rows, MIXED_SCHEMA, sample=64, block_tuples=4)
        assert codec.compile() is None  # every block takes the slow path
        table = CompressedTable(codec)
        for r in rows:
            table.append(r)
        table.flush()
        a, b = table.get_many([3, 3])
        assert a == b and a is not b

    def test_get_many_accepts_one_shot_iterator_with_updates(self):
        schema, gen = tpcc.TABLES["orderline"]
        rows = gen(50)
        store = BlitzStore(schema, rows[:25])
        store.insert_many(rows)
        row = store.get(5)
        row["ol_quantity"] = 777
        store.update(5, row)
        got = store.get_many(iter([4, 5, 6]))
        assert got[1]["ol_quantity"] == 777
        assert got[0] == store.get(4)
