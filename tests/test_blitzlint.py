"""blitzlint framework tests: every rule fires on its violation fixture,
stays quiet on its clean fixture, waivers behave, and the repo itself
lints clean (the same gate CI runs).

Also pins the dynamic telemetry names: ``repro.scan.<field>`` counters
are generated from ``ScanStats._FIELDS`` at import time, so the catalog
must enumerate them explicitly (see the BL002 waiver in scan/engine.py).
"""

from __future__ import annotations

import pathlib

import pytest

from tools.blitzlint import (
    RULES,
    lint_paths,
    lint_source,
    load_catalog,
    make_config,
)
from tools.blitzlint.core import NAME_RE

ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tools" / "blitzlint" / "fixtures"
CFG = make_config(ROOT)

# Rule -> the repo-relative path the fixture pretends to live at (rules
# are path-scoped; this picks a path each rule applies to).
FIXTURE_REL = {
    "BL001": "src/repro/core/plan.py",
    "BL002": "src/repro/db/database.py",
    "BL003": "src/repro/core/somefile.py",
    "BL004": "src/repro/oltp/somefile.py",
    "BL005": "src/repro/core/somefile.py",
    "BL006": "src/repro/db/somefile.py",
    "BL007": "src/repro/core/somefile.py",
}

# Findings of the rule under test expected from each violation fixture.
EXPECTED_COUNTS = {
    "BL001": 2,  # rowish loop + range(n) with n = len(rows)
    "BL002": 3,  # off-catalog, pattern-breaking, non-literal
    "BL003": 3,  # dict literal, list() call, list literal
    "BL004": 3,  # attr write, aliased handle write, mutator call
    "BL005": 2,  # astype and asarray forms
    "BL006": 1,
    "BL007": 1,
}

CHECKED_RULES = sorted(FIXTURE_REL)


def run_fixture(name: str, rel: str):
    return lint_source((FIXTURES / name).read_text(), rel, CFG)


def test_registry_metadata():
    assert CHECKED_RULES == sorted(RULES), "every registered rule needs fixtures"
    for rule in RULES.values():
        assert rule.id.startswith("BL") and len(rule.id) == 5
        assert rule.title, rule.id
        assert rule.rationale, rule.id


@pytest.mark.parametrize("rule_id", CHECKED_RULES)
def test_violation_fixture_fires(rule_id):
    findings = run_fixture(
        f"{rule_id.lower()}_violation.py", FIXTURE_REL[rule_id]
    )
    hits = [f for f in findings if f.rule == rule_id]
    assert len(hits) == EXPECTED_COUNTS[rule_id], [f.render() for f in findings]


@pytest.mark.parametrize("rule_id", CHECKED_RULES)
def test_clean_fixture_passes(rule_id):
    findings = run_fixture(f"{rule_id.lower()}_clean.py", FIXTURE_REL[rule_id])
    assert findings == [], [f.render() for f in findings]


def test_waiver_suppresses_and_is_consumed():
    findings = run_fixture("waiver_ok.py", "src/repro/core/somefile.py")
    assert findings == [], [f.render() for f in findings]


def test_reasonless_waiver_is_flagged_and_does_not_suppress():
    findings = run_fixture("waiver_reasonless.py", "src/repro/core/somefile.py")
    rules = sorted(f.rule for f in findings)
    assert rules == ["BL000", "BL007"], [f.render() for f in findings]


def test_unused_waiver_is_flagged():
    findings = run_fixture("waiver_unused.py", "src/repro/core/somefile.py")
    assert [f.rule for f in findings] == ["BL000"], [
        f.render() for f in findings
    ]


def test_unknown_rule_waiver_is_flagged():
    findings = run_fixture("waiver_unknown.py", "src/repro/core/somefile.py")
    assert any(
        f.rule == "BL000" and "unknown" in f.message for f in findings
    ), [f.render() for f in findings]


def test_repo_lints_clean():
    """The CI gate: the repo itself carries zero findings."""
    paths = [
        ROOT / p
        for p in ("src", "tools", "tests", "benchmarks", "examples")
        if (ROOT / p).exists()
    ]
    findings = lint_paths(paths, ROOT, CFG)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_catalog_loads_without_import():
    names = load_catalog(ROOT, CFG.catalog_rel)
    assert names, "catalog must parse statically (stdlib-only CI job)"
    assert len(set(names)) == len(names)
    for n in names:
        assert NAME_RE.match(n), n


def test_scan_stats_fields_catalogued():
    """Pins the BL002 waiver in scan/engine.py: the dynamically generated
    ``repro.scan.<field>`` counters must all be enumerated in the catalog."""
    from repro.scan.engine import ScanStats
    from repro.telemetry.catalog import CATALOG

    for field in ScanStats._FIELDS:
        assert f"repro.scan.{field}" in CATALOG, field
    assert "repro.scan.scan_table" in CATALOG


def test_catalog_module_agrees_with_static_load():
    from repro.telemetry import catalog

    assert tuple(catalog.METRICS) == load_catalog(ROOT, CFG.catalog_rel)
    assert catalog.is_catalogued("repro.core.encode")
    assert not catalog.is_catalogued("repro.core.enc0de")
