"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step + one decode step on CPU, shape and NaN checks, and
decode-vs-teacher-forcing consistency (including across page flushes)."""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("repro.dist.sharding")  # dist substrate: future PR
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config, reduced_config  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import SHAPES, shape_applies  # noqa: E402

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_prefix, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["encoder_frames"] = jnp.zeros(
            (B, cfg.encoder.n_ctx, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = reduced_config(arch)
        params = T.init_params(cfg, KEY)
        batch = _batch(cfg)
        loss, metrics = jax.jit(
            lambda p, b: T.loss_fn(p, cfg, b))(params, batch)
        assert np.isfinite(float(loss))
        assert 2.0 < float(loss) < 12.0  # ~ln(vocab) at init
        grads = jax.grad(lambda p: T.loss_fn(p, cfg, _batch(cfg))[0])(params)
        gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                 for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_decode_step(self, arch):
        cfg = reduced_config(arch)
        params = T.init_params(cfg, KEY)
        B = 2
        state = T.init_decode_state(cfg, B, 32)
        tok = jnp.ones((B, 1), jnp.int32)
        step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
        logits, state = step(params, state, tok)
        logits, state = step(params, state, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(state["pos"]) == 2


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "gemma2-9b",
                                  "deepseek-moe-16b", "whisper-tiny",
                                  "xlstm-1.3b", "hymba-1.5b"])
def test_decode_matches_forward_across_flushes(arch):
    cfg = reduced_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32", decode_tail=4)
    if cfg.moe is not None:  # avoid capacity-drop mismatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, KEY)
    B, S = 2, 11
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "audio":
        kw["encoder_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * 0.01
    h, _ = T.forward(params, cfg, toks, None, kw.get("encoder_frames"))
    ref = np.asarray(T.unembed(params, cfg, h))
    state = T.init_decode_state(cfg, B, 32)
    if cfg.family == "audio":
        enc = T._encoder_apply(params, cfg, kw["encoder_frames"])
        state["cross_k"] = jnp.einsum("btd,ldkx->lbtkx", enc,
                                      params["blocks"]["cross"]["wk"])
        state["cross_v"] = jnp.einsum("btd,ldkx->lbtkx", enc,
                                      params["blocks"]["cross"]["wv"])
    step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    flush = jax.jit(lambda s: T.flush_tail(cfg, s))
    outs = []
    for t in range(S):
        lg, state = step(params, state, toks[:, t:t + 1])
        outs.append(np.asarray(lg[:, 0]))
        if int(state["pos"]) % 4 == 0:
            state = flush(state)
    err = np.abs(np.stack(outs, 1) - ref[:, :S]).max()
    assert err / (np.abs(ref[:, :S]).max() + 1e-9) < 2e-3


def test_kv_quant_decode_close_to_fp():
    cfg = dataclasses.replace(reduced_config("gemma2-9b"), dtype="float32",
                              decode_tail=4, kv_quant=True)
    params = T.init_params(cfg, KEY)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    h, _ = T.forward(params, cfg, toks)
    ref = np.asarray(T.unembed(params, cfg, h))
    state = T.init_decode_state(cfg, B, 32)
    assert state["k"].dtype == jnp.int8
    step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    flush = jax.jit(lambda s: T.flush_tail(cfg, s))
    outs = []
    for t in range(S):
        lg, state = step(params, state, toks[:, t:t + 1])
        outs.append(np.asarray(lg[:, 0]))
        if int(state["pos"]) % 4 == 0:
            state = flush(state)
    # int8 semantic quantization: close, not exact
    err = np.abs(np.stack(outs, 1) - ref[:, :S]).max()
    assert err / (np.abs(ref).max() + 1e-9) < 0.08


def test_shape_applicability_matrix():
    """40 cells: every (arch × shape) either runs or is a documented skip."""
    n_run = n_skip = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = shape_applies(cfg, shape)
            if ok:
                n_run += 1
            else:
                assert "sub-quadratic" in why
                n_skip += 1
    assert n_run + n_skip == 40
    assert n_skip == 8  # long_500k skipped for the 8 quadratic-attention archs


def test_bf16_scores_close():
    cfg = dataclasses.replace(reduced_config("phi4-mini-3.8b"),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    h1, _ = T.forward(params, cfg, toks)
    h2, _ = T.forward(params, dataclasses.replace(cfg, attn_f32_scores=False),
                      toks)
    rel = float(jnp.abs(h1 - h2).max() / (jnp.abs(h1).max() + 1e-9))
    assert rel < 0.02
