"""Residency accounting invariants (DESIGN.md §6/§7), property-style.

Hypothesis drives random interleavings of the operations that move bytes
between the resident arena and the spill file — inserts that trigger
spills, reads that fault cold rows back in, delta merges, arena
rewrites, and plan migrations — and after every step the incremental
counters (``resident_bytes``/``spilled_bytes``/disk ``live_bytes``) must
equal ground truth recomputed from the raw block/row structures.  A
sweep that double-picks a victim, a fault-in that forgets to free its
extent, or a rewrite that drops a residency tag shows up here as counter
drift long before it corrupts a read.

Covers both store shapes: the compressed code arena
(``CompressedTable`` inside ``BlitzStore``) and the byte-payload stores
(``_BytesRowStore`` via ``UncompressedStore``).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.adaptive import refit_codec
from repro.core import TableCodec
from repro.core.arena import FRAME_OVERHEAD
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore, UncompressedStore

SCHEMA, GEN = tpcc.TABLES["orderline"]
ROWS = GEN(500, seed=21)
# Rows whose quantity escapes the v0 vocab: gives migrate real work.
DRIFTED = [dict(r, ol_quantity=520 + (i % 50)) for i, r in enumerate(ROWS)]
CODEC = TableCodec.fit(ROWS[:256], SCHEMA)
CODEC_V1 = refit_codec(CODEC, DRIFTED[:256], ["ol_quantity"])
TINY = 1 << 13

OP = st.one_of(
    st.tuples(st.just("insert"), st.integers(0, 2**16)),
    st.tuples(st.just("update"), st.integers(0, 2**16)),
    st.tuples(st.just("delete"), st.integers(0, 2**16)),
    st.tuples(st.just("read"), st.integers(0, 2**16)),
    st.tuples(st.just("merge"), st.just(0)),
    st.tuples(st.just("rewrite"), st.just(0)),
    st.tuples(st.just("migrate"), st.just(0)),
)
OPS = st.lists(OP, min_size=4, max_size=20)


def _fresh_rows(rng, k):
    out = []
    for _ in range(k):
        r = dict(ROWS[int(rng.integers(0, len(ROWS)))])
        r["ol_quantity"] = int(rng.integers(1, 60))
        r["ol_amount"] = round(float(rng.uniform(0.01, 9000.0)), 2)
        out.append(r)
    return out


def _check_table_accounting(store):
    """CompressedTable counters vs ground truth from the block arrays."""
    t = store.table
    nb = t.n_blocks
    lens = t.block_offsets[1:nb + 1] - t.block_offsets[:nb]
    resident = t._resident[:nb]
    live_resident = int(lens[resident].sum())
    dead_resident = int(lens[resident & (t._block2row[:nb] < 0)].sum())
    assert t.used - t._dead_codes == live_resident - dead_resident
    spilled = ~resident
    assert t._spilled_codes == int(t._disk_len[:nb][spilled].sum())
    # resident + spilled covers every live code byte exactly once, and
    # each spilled extent carries one CRC32 frame on disk
    assert t._res.disk.live_bytes == (
        2 * t._spilled_codes + FRAME_OVERHEAD * int(spilled.sum()))
    res = t.residency()
    assert res["resident_bytes"] == t.nbytes
    assert res["spilled_bytes"] == 2 * t._spilled_codes


def _check_bytes_accounting(store):
    """_BytesRowStore counters vs ground truth from the row list."""
    assert store._resident_bytes == sum(
        len(r) for r in store.rows if r)
    assert store._spilled_payload == sum(
        ln for _, ln in store._spilled.values())
    assert store._res.disk.live_bytes == (
        store._spilled_payload + FRAME_OVERHEAD * len(store._spilled))
    assert store.spilled_bytes == store._spilled_payload


@settings(max_examples=12, deadline=None)
@given(ops=OPS)
def test_compressed_table_accounting_invariant(ops):
    # Same codec, same verb sequence: the capped store must stay
    # bit-identical to the uncapped reference while its counters track
    # ground truth through every spill/fault/merge/rewrite/migrate.
    ref = BlitzStore(SCHEMA, None, codec=CODEC, auto_merge=False)
    cap = BlitzStore(SCHEMA, None, codec=CODEC, auto_merge=False,
                     memory_budget=TINY)
    for s in (ref, cap):
        s.insert_many(ROWS)
        s.insert_many(DRIFTED[:128])  # stale once v1 installs
        s.install_codec(CODEC_V1)
    for kind, seed in ops:
        rng = np.random.default_rng(seed)
        if kind == "insert":
            fresh = _fresh_rows(rng, int(rng.integers(1, 16)))
            assert list(cap.insert_many(fresh)) == list(
                ref.insert_many(fresh))
        elif kind == "update":
            live = [i for i in range(len(ref)) if ref.is_live(i)]
            if live:
                picks = rng.choice(len(live), min(8, len(live)),
                                   replace=False)
                idxs = [live[int(j)] for j in picks]
                rows = _fresh_rows(rng, len(idxs))
                ref.update_many(idxs, rows)
                cap.update_many(idxs, rows)
        elif kind == "delete":
            idxs = rng.integers(0, len(ref), 6).tolist()
            assert cap.delete_many(idxs) == ref.delete_many(idxs)
        elif kind == "read":
            probe = rng.integers(0, len(ref), 48).tolist()
            assert cap.get_many(probe) == ref.get_many(probe)
        elif kind == "merge":
            ref.merge()
            cap.merge()
        elif kind == "rewrite":
            ref.table.rewrite()
            cap.table.rewrite()
        elif kind == "migrate":
            ref.migrate(256, resident_only=False)
            cap.migrate(256, resident_only=False)
        _check_table_accounting(cap)
    every = list(range(len(ref)))
    assert cap.get_many(every) == ref.get_many(every)


@settings(max_examples=12, deadline=None)
@given(ops=OPS)
def test_bytes_store_accounting_invariant(ops):
    ref = UncompressedStore(SCHEMA, ROWS[:64])
    cap = UncompressedStore(SCHEMA, ROWS[:64], memory_budget=2048)
    ref.insert_many(ROWS[:256])
    cap.insert_many(ROWS[:256])
    for kind, seed in ops:
        rng = np.random.default_rng(seed)
        if kind == "insert":
            fresh = _fresh_rows(rng, int(rng.integers(1, 16)))
            assert list(cap.insert_many(fresh)) == list(
                ref.insert_many(fresh))
        elif kind == "update":
            live = [i for i in range(len(ref)) if ref.is_live(i)]
            if live:
                picks = rng.choice(len(live), min(8, len(live)),
                                   replace=False)
                idxs = [live[int(j)] for j in picks]
                rows = _fresh_rows(rng, len(idxs))
                ref.update_many(idxs, rows)
                cap.update_many(idxs, rows)
        elif kind == "delete":
            idxs = rng.integers(0, len(ref), 6).tolist()
            assert cap.delete_many(idxs) == ref.delete_many(idxs)
        else:  # read / merge / rewrite / migrate: reads fault cold rows
            probe = rng.integers(0, len(ref), 48).tolist()
            assert cap.get_many(probe) == ref.get_many(probe)
        _check_bytes_accounting(cap)
    every = list(range(len(ref)))
    assert cap.get_many(every) == ref.get_many(every)
    cap.close(unlink=True)
