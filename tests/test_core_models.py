"""Semantic column models (§4) and the TableCodec facade (§3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ColumnSpec, CompressedTable, TableCodec
from repro.core.delayed import BlockDecoder, encode_block
from repro.core.models import (BlockEncoder, ByteMarkov, CategoricalModel,
                               NumericModel, StringModel, TimeSeriesModel)


def _roundtrip(model, values):
    enc = BlockEncoder()
    if hasattr(model, "reset_block"):
        model.reset_block()
    for v in values:
        model.encode_value(v, enc)
    codes = encode_block(enc.slots)
    dec = BlockDecoder(codes)
    if hasattr(model, "reset_block"):
        model.reset_block()
    return [model.decode_value(dec) for _ in values], codes


class TestCategorical:
    def test_seen_and_unseen(self):
        m = CategoricalModel(["a", "b", "b", "c"] * 50)
        out, _ = _roundtrip(m, ["a", "b", "c", "zebra", "b"])
        assert out == ["a", "b", "c", "zebra", "b"]

    def test_skew_gives_short_codes(self):
        m = CategoricalModel(["x"] * 999 + ["y"])
        assert m.est_bits("x") < 0.01
        assert m.est_bits("y") > 5

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=40))
    def test_property(self, vals):
        m = CategoricalModel(list("abcdefg") * 10)
        out, _ = _roundtrip(m, vals)
        assert out == vals


class TestNumeric:
    def test_integers_exact(self):
        rng = np.random.default_rng(0)
        data = rng.poisson(100, 2000).astype(int).tolist()
        m = NumericModel(data, precision=1, integer=True)
        out, _ = _roundtrip(m, data[:100])
        assert out == data[:100]

    def test_floats_within_precision(self):
        rng = np.random.default_rng(1)
        data = rng.normal(0, 10, 2000).tolist()
        p = 0.01
        m = NumericModel(data, precision=p)
        out, _ = _roundtrip(m, data[:100])
        for got, exp in zip(out, data[:100]):
            assert abs(got - exp) <= p / 2 + 1e-9

    def test_outlier_escape(self):
        m = NumericModel([1.0, 2.0, 3.0] * 100, precision=0.1)
        out, _ = _roundtrip(m, [2.0, 1e9, -77.7])
        assert abs(out[0] - 2.0) <= 0.05
        assert out[1] == 1e9 and out[2] == -77.7  # escapes are exact float64

    def test_skew_helps(self):
        """Level-1 frequency intervals give skewed data shorter codes."""
        rng = np.random.default_rng(2)
        skewed = np.abs(rng.normal(0, 1, 4000))
        m = NumericModel(skewed.tolist(), precision=1e-3)
        common, rare = m.est_bits(0.1), m.est_bits(skewed.max() * 0.99)
        assert common < rare

    def test_wide_integer_range_multilevel(self):
        data = [0, 2**40, 2**40 + 12345, 17]
        m = NumericModel(data, precision=1, T=16, integer=True)
        assert len(m.l2) >= 2  # needs chained uniform digits
        out, _ = _roundtrip(m, data)
        assert out == data

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(-10**6, 10**6), min_size=2, max_size=50))
    def test_property_integers(self, data):
        m = NumericModel(data, precision=1, integer=True)
        out, _ = _roundtrip(m, data)
        assert out == data


class TestString:
    CORPUS = [f"{n} Main St, Springfield" for n in range(100, 200)] + \
             [f"{n} Oak Ave, Shelbyville" for n in range(10, 60)]

    def test_roundtrip(self):
        m = StringModel(self.CORPUS)
        vals = ["150 Main St, Springfield", "11 Oak Ave, Shelbyville",
                "9999 Unknown Blvd, Nowhere"]
        out, _ = _roundtrip(m, vals)
        assert out == vals

    def test_prefix_queue_within_block(self):
        m = StringModel(self.CORPUS)
        vals = ["150 Main St, Springfield", "150 Main St, Springfield apt 4"]
        out, codes = _roundtrip(m, vals)
        assert out == vals

    def test_unicode_escape(self):
        m = StringModel(self.CORPUS)
        out, _ = _roundtrip(m, ["héllo wörld ✓"])
        assert out == ["héllo wörld ✓"]

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.text(alphabet=st.characters(codec="utf-8"),
                            max_size=30), min_size=1, max_size=5))
    def test_property_any_text(self, vals):
        m = StringModel(self.CORPUS)
        out, _ = _roundtrip(m, vals)
        assert out == vals


class TestMarkovAndTimeSeries:
    def test_markov_words(self):
        m = ByteMarkov([b"street", b"stream", b"string"])
        enc = BlockEncoder()
        m.encode_word(b"strap", enc)
        codes = encode_block(enc.slots)
        assert m.decode_word(BlockDecoder(codes)) == b"strap"

    def test_timeseries_residual_beats_raw(self):
        rng = np.random.default_rng(3)
        walk = np.cumsum(rng.normal(0, 1, 5000)) + 100
        ts = TimeSeriesModel(walk.tolist(), precision=0.01)
        raw = NumericModel(walk.tolist(), precision=0.01)
        vals = walk[:256].tolist()
        out_ts, codes_ts = _roundtrip(ts, vals)
        out_raw, codes_raw = _roundtrip(raw, vals)
        for got, exp in zip(out_ts, vals):
            assert abs(got - exp) <= 0.01  # p/2 per step, reconstruction-tracked
        assert len(codes_ts) < len(codes_raw), "AR(1) residuals must compress better"


class TestTableCodec:
    SCHEMA = [ColumnSpec("k", "int"), ColumnSpec("c", "cat"),
              ColumnSpec("f", "float", precision=0.01), ColumnSpec("s", "str")]

    def _rows(self, n=2000, seed=0):
        rng = np.random.default_rng(seed)
        cats = ["aa", "bb", "cc", "dd"]
        return [{"k": int(i), "c": cats[int(rng.integers(0, 4))],
                 "f": float(np.round(rng.normal(10, 2), 2)),
                 "s": f"{int(rng.integers(1, 99))} Elm St"} for i in range(n)]

    def test_block_roundtrip(self):
        rows = self._rows()
        codec = TableCodec.fit(rows, self.SCHEMA, sample=512)
        blk = rows[100:108]
        back = codec.decompress_block(codec.compress_block(blk), len(blk))
        for got, exp in zip(back, blk):
            assert got["k"] == exp["k"] and got["c"] == exp["c"]
            assert got["s"] == exp["s"]
            assert abs(got["f"] - exp["f"]) <= 0.005 + 1e-9

    def test_compressed_table_random_access(self):
        rows = self._rows(500)
        codec = TableCodec.fit(rows, self.SCHEMA, sample=256, block_tuples=4)
        table = CompressedTable(codec)
        for r in rows:
            table.append(r)
        table.flush()
        assert len(table) == 500
        rng = np.random.default_rng(1)
        for i in rng.integers(0, 500, 50):
            assert table.get(int(i))["k"] == rows[int(i)]["k"]

    def test_correlation_improves_or_matches(self):
        rng = np.random.default_rng(7)
        states = ["CA", "TX", "NY"]
        city_of = {"CA": ["LA", "SF"], "TX": ["HOU"], "NY": ["NYC", "BUF"]}
        rows = []
        for i in range(3000):
            st_ = states[int(rng.integers(0, 3))]
            rows.append({"state": st_,
                         "city": city_of[st_][int(rng.integers(0, len(city_of[st_])))]})
        schema = [ColumnSpec("state", "cat"), ColumnSpec("city", "cat")]
        flat = TableCodec.fit(rows, schema, correlation=False, sample=1024)
        corr = TableCodec.fit(rows, schema, correlation=True, sample=1024)
        bits_flat = sum(len(flat.compress_block([r])) for r in rows[:200])
        bits_corr = sum(len(corr.compress_block([r])) for r in rows[:200])
        assert bits_corr <= bits_flat
        back = corr.decompress_block(corr.compress_block(rows[:5]), 5)
        assert back == rows[:5]


class TestJsonModel:
    """Appendix E.1: JSON node model (optional nodes, multi-type nodes)."""

    SAMPLES = [
        {"name": "John", "age": 18, "job": "student",
         "tags": ["a", "b"], "address": {"city": "LA", "zip": "90001"}},
        {"name": "Mary", "age": "Eighteen", "tags": [],
         "address": {"city": "SF", "zip": "94105"}},
        {"name": "Ann", "age": 44, "job": "doctor", "tags": ["c"],
         "address": {"city": "LA", "zip": "90002"}},
    ] * 20

    def _codec(self):
        from repro.core.json_model import JsonCodec
        return JsonCodec(self.SAMPLES)

    def test_roundtrip_optional_and_multitype(self):
        codec = self._codec()
        for obj in self.SAMPLES[:3]:
            codes = codec.encode(obj)
            assert codec.decode(codes) == obj

    def test_unseen_values_and_keys(self):
        codec = self._codec()
        obj = {"name": "Zed", "age": 3.5, "tags": ["x", "y", "z"],
               "address": {"city": "NYC", "zip": "10001"},
               "brand_new_key": {"nested": [1, 2]}}
        codes = codec.encode(obj)
        back = codec.decode(codes)
        assert back["name"] == "Zed"
        assert abs(back["age"] - 3.5) < 1e-5
        assert back["brand_new_key"] == {"nested": [1, 2]}

    def test_beats_raw_json(self):
        import json as _json
        codec = self._codec()
        raw = comp = 0
        for obj in self.SAMPLES[:30]:
            raw += len(_json.dumps(obj))
            comp += 2 * len(codec.encode(obj))
        assert raw / comp > 2.0, raw / comp
