"""Dry-run machinery smoke test: a subprocess with 8 placeholder devices
builds, lowers and compiles cells on a small (2, 2, 2) pod mesh — exercising
the same mesh/sharding/lower/compile/roofline path as the 512-chip run
without the compile cost.  (Device count is process-global, hence the
subprocess.)"""

import json
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.steps import build_cell, lower_cell
    from repro.analysis import roofline as rf

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    out = {}
    for arch, shape_name in [("whisper-tiny", "train_4k"),
                             ("whisper-tiny", "decode_32k"),
                             ("xlstm-1.3b", "long_500k")]:
        shape = SHAPES_BY_NAME[shape_name]
        import dataclasses
        cfg = get_config(arch)
        # shrink to keep the smoke compile fast
        cfg = dataclasses.replace(cfg, n_layers=8 if cfg.family == "ssm" else 2,
                                  vocab=1024)
        shape = dataclasses.replace(shape, global_batch=8,
                                    seq_len=256 if shape.kind != "decode" else 512)
        cell = build_cell(arch, shape, mesh, cfg=cfg)
        with mesh:
            lowered = lower_cell(cell)
            compiled = lowered.compile()
        roof = rf.analyze(arch, shape.name, "smoke2x2x2", 8,
                          compiled.cost_analysis() or {}, compiled.as_text(),
                          rf.model_flops_for(cfg, shape))
        out[f"{arch}:{shape_name}"] = {
            "bottleneck": roof.bottleneck,
            "flops": roof.hlo_gflops,
            "wire": roof.wire_gbytes_per_chip,
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_multipod_smoke_mesh_compiles():
    pytest.importorskip("repro.dist")  # dist substrate: future PR
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=600, env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert len(out) == 3
    for cell, rec in out.items():
        assert rec["flops"] > 0, cell
        assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_production_mesh_shapes():
    """Mesh functions (not constants) with the mandated shapes/axes."""
    import inspect
    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


def test_dryrun_sets_device_flag_first():
    import pathlib
    text = pathlib.Path("src/repro/launch/dryrun.py").read_text()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    assert lines[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in lines[1]


def test_input_specs_cover_all_cells():
    pytest.importorskip("repro.dist")  # dist substrate: future PR
    from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applies
    from repro.launch.steps import input_specs
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = shape_applies(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            elif cfg.family == "vlm":
                assert specs["tokens"].shape[1] + cfg.n_prefix == shape.seq_len


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, tempfile
    import jax, numpy as np
    from repro.configs import reduced_config
    from repro.dist import partitioning as parts
    from repro.dist.sharding import ShardingRules, use_rules
    from repro.launch import steps as steps_lib
    from repro.models import transformer as tfm
    from repro.models.config import ShapeConfig
    from repro.train.checkpoint import CheckpointManager
    from repro.train import optimizer as opt_lib

    cfg = reduced_config("phi3-mini-3.8b")
    shape = ShapeConfig("smoke", 32, 8, "train")
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))  # elastic rescale

    def build(mesh):
        rules = steps_lib.rules_for(mesh, shape)
        p_shape = steps_lib.abstract_params(cfg)
        p_shard = parts.param_shardings(rules, p_shape)
        return rules, p_shard

    rules_a, shard_a = build(mesh_a)
    with mesh_a, use_rules(rules_a):
        params = jax.jit(lambda k: tfm.init_params(cfg, k),
                         out_shardings=shard_a)(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, async_save=False)
        cm.save(1, {"params": params})
        # restore onto the *different* mesh (reshard-on-restore)
        rules_b, shard_b = build(mesh_b)
        _, tree, _ = cm.restore(shardings={"params": shard_b})
    params_b = tree["params"]
    # run one loss step on mesh B to prove the restored tree is usable
    batch = {"tokens": np.ones((8, 32), np.int32),
             "labels": np.ones((8, 32), np.int32)}
    with mesh_b, use_rules(rules_b):
        loss, _ = jax.jit(lambda p, b: tfm.loss_fn(p, cfg, b))(params_b, batch)
    a0 = np.asarray(jax.tree.leaves(params)[0])
    b0 = np.asarray(jax.tree.leaves(params_b)[0])
    assert (a0 == b0).all(), "values must survive resharding"
    assert np.isfinite(float(loss))
    print("RESULT elastic ok", float(loss))
""")


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    pytest.importorskip("repro.dist")  # dist substrate: future PR
    """Checkpoint written under one mesh restores onto another (ZeRO-style
    elastic rescale) and trains — the node-failure recovery contract."""
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC], capture_output=True, text=True,
        timeout=600, env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESULT elastic ok" in proc.stdout
