"""Boundary sanitizer tests (DESIGN.md §10): seeded corruption.

Each test flips one structural field the way a real bug would — a torn
CSR offset, a drifted residency counter, a wrapped plan-version tag —
and asserts the *next boundary crossing* raises the matching typed
:class:`~repro.sanitize.SanitizeError` subclass.  The same corruptions
under ``override(False)`` must stay silent: the sanitize-off hot path
is a falsy branch, never a behaviour change.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro import sanitize
from repro.core import TableCodec
from repro.core.arena import OS_IO
from repro.core.blitzcrank import CompressedTable
from repro.core.casts import NarrowingCastError, checked_asarray, checked_astype
from repro.durability.wal import WriteAheadLog
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore

SCHEMA, GEN = tpcc.TABLES["orderline"]
ROWS = GEN(1500, seed=11)
CODEC = TableCodec.fit(ROWS[:256], SCHEMA)
TINY = 1 << 13


def _table(budget=None):
    t = CompressedTable(CODEC, memory_budget=budget)
    t.extend(ROWS)
    return t


# -- override plumbing -------------------------------------------------------


def test_override_restores_prior_state():
    prev = sanitize.ENABLED
    with sanitize.override(True):
        assert sanitize.enabled()
        with sanitize.override(False):
            assert not sanitize.enabled()
        assert sanitize.enabled()
    assert sanitize.ENABLED is prev


# -- seeded corruption: CSR offsets ------------------------------------------


def test_corrupt_arena_offset_caught_at_next_boundary():
    t = _table()
    t._offsets[1] = -5  # a torn write: offsets decrease at block 0
    with sanitize.override(True):
        with pytest.raises(sanitize.CsrInvariantError, match="decrease"):
            t.get_many([0, 1, 2])


def test_corrupt_tail_offset_caught():
    t = _table()
    t._offsets[t.n_blocks] = t.used + 999  # extent runs past the arena
    with sanitize.override(True):
        with pytest.raises(sanitize.CsrInvariantError, match="exceeds arena"):
            t.get_many([0])


# -- seeded corruption: residency counter ------------------------------------


def test_corrupt_residency_counter_caught():
    t = _table(budget=TINY)
    assert t.spilled_bytes > 0, "fixture must actually spill"
    t._spilled_codes += 7  # counter drift vs recomputed ground truth
    with sanitize.override(True):
        with pytest.raises(sanitize.ResidencyInvariantError, match="ground truth"):
            t.get_many([0])


def test_corrupt_residency_counter_silent_when_off():
    t = _table(budget=TINY)
    with sanitize.override(False):
        want = t.get_many([0])
        t._spilled_codes += 7
        assert t.get_many([0]) == want  # reads unaffected, no raise


# -- seeded corruption: plan-version tags ------------------------------------


def test_corrupt_plan_version_tag_caught():
    t = _table()
    t._plan_ver[0] = 999  # tag names a codec version that never existed
    with sanitize.override(True):
        with pytest.raises(sanitize.PlanVersionInvariantError, match="999"):
            t.get_many([0])


# -- seeded corruption: overlay/tombstones -----------------------------------


def test_overlay_tombstone_conflict_caught_at_merge():
    store = BlitzStore(SCHEMA, ROWS[:256], auto_merge=False)
    store.insert_many(ROWS[:64])
    store.update_many([3], [dict(ROWS[3], ol_quantity=9)])
    store._tombstones.add(3)  # bug: deleted without dropping the overlay row
    with sanitize.override(True):
        with pytest.raises(sanitize.OverlayInvariantError, match="tombstoned"):
            store.merge()


# -- seeded corruption: WAL torn write ---------------------------------------


class _TornIO:
    """Proxy io that can drop the second half of one pwrite."""

    def __init__(self, inner):
        self._inner = inner
        self.torn = False

    def pwrite(self, fd, buf, off):
        if self.torn:
            buf = buf[: len(buf) // 2]
        return self._inner.pwrite(fd, buf, off)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_wal_torn_write_caught_at_flush(tmp_path):
    io = _TornIO(OS_IO)
    wal = WriteAheadLog(str(tmp_path / "t.wal"), io=io)
    wal.log("insert", [{"k": 1}])
    io.torn = True
    with sanitize.override(True):
        with pytest.raises(sanitize.WalInvariantError, match="backwards"):
            wal.log("insert", [{"k": 2}])
    wal.close()


# -- checked casts -----------------------------------------------------------


def test_checked_astype_catches_overflow():
    wide = np.array([1, 70_000], dtype=np.int64)
    with sanitize.override(True):
        with pytest.raises(NarrowingCastError, match="uint16"):
            checked_astype(wide, np.uint16, where="test")
        with pytest.raises(NarrowingCastError):
            checked_asarray([-1], np.uint16, where="test")
        ok = checked_astype(np.array([0, 65_535]), np.uint16, where="test")
        assert ok.dtype == np.uint16


def test_checked_astype_wraps_silently_when_off():
    wide = np.array([70_000], dtype=np.int64)
    with sanitize.override(False):
        out = checked_astype(wide, np.uint16, where="test")
    assert out.dtype == np.uint16  # plain astype semantics, no check


# -- check functions directly ------------------------------------------------


def test_check_code_range():
    with sanitize.override(True):
        sanitize.check_code_range(np.array([0, 4]), 5, where="t")
        with pytest.raises(sanitize.CsrInvariantError, match="slot 2"):
            sanitize.check_code_range(np.array([5]), 5, where="t", slot=2)


def test_check_zone_maps():
    with sanitize.override(True):
        # untouched (+inf, -inf) chunks are fine; an inverted finite pair is not
        sanitize.check_zone_maps(
            np.array([[np.inf, 1.0]]), np.array([[-np.inf, 2.0]]), where="t"
        )
        with pytest.raises(sanitize.ZoneMapInvariantError, match="inverted"):
            sanitize.check_zone_maps(
                np.array([[3.0]]), np.array([[2.0]]), where="t"
            )


def test_check_wal_lsn():
    with sanitize.override(True):
        sanitize.check_wal_lsn(10, 10, where="t")
        sanitize.check_wal_lsn(10, 12, where="t")
        with pytest.raises(sanitize.WalInvariantError):
            sanitize.check_wal_lsn(10, 9, where="t")


def test_failures_counter_increments():
    from repro import telemetry

    c = telemetry.counter("repro.sanitize.failures")
    prev = telemetry.set_enabled(True)
    try:
        before = c.value
        with sanitize.override(True):
            with pytest.raises(sanitize.CsrInvariantError):
                sanitize.check_csr_offsets(np.array([-1, 2]), 10, where="t")
        assert c.value == before + 1
    finally:
        telemetry.set_enabled(prev)
