"""Delayed coding (§5): Figure-7 exactness, roundtrips, Theorem-2 behaviour."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.coders import TOTAL, DiscreteCoder, UniformCoder, quantize_freqs
from repro.core.delayed import (decode_block, encode_block, encode_symbols,
                                wasted_bits, Slot)
from repro.core.vectorized import decode_batch, decode_select, encode_batch


class _Contig:
    """Contiguous-interval coder used only to replay the paper's Figure 7."""

    def __init__(self, bounds):
        self.bounds = bounds

    def k(self, sym):
        L, R = self.bounds[sym]
        return R - L

    def code_for(self, sym, a):
        return self.bounds[sym][0] + a

    def inv_translate(self, code):
        for s, (L, R) in enumerate(self.bounds):
            if L <= code < R:
                return s, code - L, R - L
        raise AssertionError

    def inv_translate_batch(self, codes):
        out = np.array([self.inv_translate(int(c)) for c in codes])
        return out[:, 0], out[:, 1], out[:, 2]

    def code_for_batch(self, syms, a):
        return np.array([self.code_for(int(s), int(x))
                         for s, x in zip(syms, a)])


FIG7_CODERS = [
    _Contig([(0, 32768), (32768, 65536)]),
    _Contig([(0, 10011), (10011, 10027), (10027, 65536)]),
    _Contig([(0, 3), (3, 32772), (32772, 65536)]),
    _Contig([(0, 1023), (1023, 1028), (1028, 65536)]),
]


class TestFigure7:
    """The paper's fully worked example must reproduce bit-for-bit."""

    def test_encode_bitstream(self):
        codes = encode_symbols([1, 1, 1, 1], FIG7_CODERS)
        assert codes == [0x8040, 0x271D]

    def test_decode(self):
        syms, used = decode_block([0x8040, 0x271D], FIG7_CODERS)
        assert syms == [1, 1, 1, 1] and used == 2

    def test_waste_is_20_options(self):
        assert wasted_bits([32768, 16, 32769, 5]) == pytest.approx(np.log2(20))

    def test_vectorized_matches(self):
        syms = np.array([[1, 1, 1, 1]])
        codes, offs = encode_batch(syms, FIG7_CODERS)
        assert codes.tolist() == [0x8040, 0x271D]
        assert (decode_batch(codes, offs, FIG7_CODERS) == syms).all()


def _random_coders(rng, S):
    coders = []
    for s in range(S):
        if rng.random() < 0.3:
            coders.append(UniformCoder(int(rng.integers(1, TOTAL + 1))))
        else:
            n = int(rng.integers(1, 400))
            w = 1.0 / np.arange(1, n + 1) ** rng.uniform(0.2, 2.0)
            coders.append(DiscreteCoder(quantize_freqs(w * 1e7)))
    return coders


def _n_syms(c):
    return c.G if isinstance(c, UniformCoder) else c.tables.n_symbols


class TestRoundtrip:
    @pytest.mark.parametrize("seed", range(5))
    def test_reference_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        coders = _random_coders(rng, int(rng.integers(1, 50)))
        syms = [int(rng.integers(0, _n_syms(c))) for c in coders]
        codes = encode_symbols(syms, coders)
        out, used = decode_block(codes, coders)
        assert out == syms and used == len(codes)

    def test_vectorized_equals_reference(self):
        rng = np.random.default_rng(10)
        coders = _random_coders(rng, 20)
        N = 300
        syms = np.stack([rng.integers(0, _n_syms(c), N) for c in coders], axis=1)
        codes, offs = encode_batch(syms, coders)
        assert (decode_batch(codes, offs, coders) == syms).all()
        for t in rng.integers(0, N, 20):
            ref = encode_symbols(syms[t].tolist(), coders)
            assert ref == codes[offs[t]:offs[t + 1]].tolist()

    def test_random_access_select(self):
        rng = np.random.default_rng(11)
        coders = _random_coders(rng, 12)
        N = 500
        syms = np.stack([rng.integers(0, _n_syms(c), N) for c in coders], axis=1)
        codes, offs = encode_batch(syms, coders)
        rows = rng.integers(0, N, 64)
        assert (decode_select(codes, offs, coders, rows) == syms[rows]).all()

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_property_roundtrip(self, data):
        seed = data.draw(st.integers(0, 2**31))
        rng = np.random.default_rng(seed)
        coders = _random_coders(rng, int(rng.integers(1, 30)))
        syms = [int(rng.integers(0, _n_syms(c))) for c in coders]
        out, _ = decode_block(encode_symbols(syms, coders), coders)
        assert out == syms

    def test_bad_lambda_rejected(self):
        with pytest.raises(ValueError):
            encode_block([Slot(2, lambda a: a)], lam=100)


class TestTheorem2:
    """Near-entropy compression with fine granularity (§5.7)."""

    def _measure(self, block, n=4096):
        rng = np.random.default_rng(2)
        w = 1.0 / np.arange(1, 301) ** 1.1
        dc = DiscreteCoder(quantize_freqs(w * 1e6))
        p = dc.tables.k_of.astype(np.float64) / TOTAL
        syms = rng.choice(p.size, size=n, p=p)
        bits = 0
        for i in range(0, n, block):
            blk = syms[i:i + block].tolist()
            bits += 16 * len(encode_symbols(blk, [dc] * len(blk)))
        H = -(p * np.log2(p)).sum() * n
        return bits / H

    def test_overhead_shrinks_with_block_size(self):
        r8, r64 = self._measure(8), self._measure(64)
        assert r64 < r8, "larger blocks must compress better (Fig. 12)"
        assert r64 < 1.10, f"64-slot blocks should be near-entropy, got {r64}"

    def test_information_lower_bound(self):
        """No block may beat its own information content."""
        rng = np.random.default_rng(3)
        w = 1.0 / np.arange(1, 64) ** 1.3
        dc = DiscreteCoder(quantize_freqs(w * 1e6))
        kq = dc.tables.k_of.astype(np.float64)
        for _ in range(20):
            blk = rng.integers(0, 63, 32).tolist()
            codes = encode_symbols(blk, [dc] * len(blk))
            info = sum(16 - np.log2(kq[s]) for s in blk)
            assert len(codes) * 16 >= info - 1e-6

    def test_upper_bound_with_mark_losses(self):
        """16*codes <= info + final-counter waste + 1 bit per mark (Thm 2)."""
        rng = np.random.default_rng(4)
        w = 1.0 / np.arange(1, 200) ** 1.0
        dc = DiscreteCoder(quantize_freqs(w * 1e6))
        kq = dc.tables.k_of.astype(np.float64)
        for _ in range(20):
            blk = rng.integers(0, 199, 48).tolist()
            codes = encode_symbols(blk, [dc] * len(blk))
            info = sum(16 - np.log2(kq[s]) for s in blk)
            waste = wasted_bits([int(kq[s]) for s in blk])
            marks = len(blk) - len(codes)
            assert len(codes) * 16 <= info + waste + 1.0 * marks + 1e-6
