"""Durability layer (DESIGN.md §7): WAL, checkpoint, recovery, faults.

Unit coverage for the pieces the crash-matrix harness composes: framed
WAL append/scan with torn-tail truncation and poisoning, atomic
checksummed checkpoints, checked spill reads (short reads and bit flips
become typed errors, never garbage), fd hygiene on ``drop_table``, and a
close/reopen bit-identity round trip through both recovery paths
(checkpoint + tail, and full from-zero replay).  A thin smoke slice of
the harness itself runs here so tier-1 catches a broken crash matrix
without CI's full sweep.
"""

import os

import pytest

from repro.core.arena import (ArenaReadError, DiskArena,
                              ExtentCorruptionError, SpillCorruptionError)
from repro.db import Database, TableSchema
from repro.durability import harness
from repro.durability.checkpoint import (checkpoint_path, load_checkpoint,
                                         write_checkpoint)
from repro.durability.io import DurableIO, FaultInjector, SimulatedCrash
from repro.durability.wal import WalPoisonedError, WriteAheadLog
from repro.oltp import tpcc
from repro.oltp.store import UncompressedStore

CUSTOMER = tpcc.TABLES["customer"][0]


def _customer_schema() -> TableSchema:
    return TableSchema("customer", CUSTOMER, "c_id")


# -- WAL ------------------------------------------------------------------

def test_wal_log_scan_roundtrip(tmp_path):
    path = str(tmp_path / "t.wal")
    wal = WriteAheadLog(path)
    wal.log("insert", [{"a": 1}])
    wal.log("delete", [7, 9])
    assert wal.lsn > 0
    got = [(op, payload) for _lsn, op, payload in wal.scan(0)]
    assert got == [("insert", [{"a": 1}]), ("delete", [7, 9])]
    # LSNs are byte offsets: scanning from the first record's end yields
    # only the second
    first_end = next(wal.scan(0))[0]
    assert [op for _l, op, _p in wal.scan(first_end)] == ["delete"]
    wal.close()


def test_wal_torn_tail_truncated_on_open(tmp_path):
    path = str(tmp_path / "t.wal")
    wal = WriteAheadLog(path)
    for i in range(3):
        wal.log("insert", [i])
    intact = wal.lsn
    wal.close()
    # a torn final record: garbage where a frame should start
    with open(path, "ab") as f:
        f.write(b"\x00garbage-torn-tail")
    wal2 = WriteAheadLog(path)
    assert wal2.truncated_bytes > 0
    assert wal2.lsn == intact
    assert [p for _l, _op, p in wal2.scan(0)] == [[0], [1], [2]]
    wal2.close()


def test_wal_poisons_after_failed_write(tmp_path):
    inj = FaultInjector(seed=1)
    inj.add_fault("pwrite", "enospc")
    wal = WriteAheadLog(str(tmp_path / "t.wal"), io=DurableIO(inj))
    with pytest.raises(OSError):
        wal.log("insert", [1])
    with pytest.raises(WalPoisonedError):
        wal.log("insert", [2])
    assert inj.fired == ["pwrite:enospc"]
    wal.close()


def test_wal_suspend_blocks_appends(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "t.wal"))
    with wal.suspend():
        wal.log("insert", [1])
    assert wal.lsn == 0
    wal.close()


# -- checkpoint -----------------------------------------------------------

def test_checkpoint_roundtrip_and_corruption(tmp_path):
    root = str(tmp_path)
    state = {"tables": {"customer": {"wal_lsn": 123}}, "format": 1}
    size = write_checkpoint(root, state)
    assert size > 0
    assert load_checkpoint(root) == state
    # one flipped byte inside the payload -> CRC mismatch -> None
    path = checkpoint_path(root)
    buf = bytearray(open(path, "rb").read())
    buf[-1] ^= 0x40
    open(path, "wb").write(bytes(buf))
    assert load_checkpoint(root) is None


def test_checkpoint_replace_is_atomic(tmp_path):
    root = str(tmp_path)
    write_checkpoint(root, {"v": "old"})
    inj = FaultInjector(seed=0)
    inj.crash_at("checkpoint.mid")
    with pytest.raises(SimulatedCrash):
        write_checkpoint(root, {"v": "new"}, io=DurableIO(inj))
    # the crash tore the tmp file, not the live checkpoint
    assert load_checkpoint(root) == {"v": "old"}


# -- checked spill reads --------------------------------------------------

def test_arena_short_read_is_typed(tmp_path):
    arena = DiskArena(str(tmp_path / "spill.arena"))
    (off,) = arena.write_many([b"x" * 100])
    os.ftruncate(arena._fd, off + 10)
    with pytest.raises(ArenaReadError):
        arena.read(off, 100)
    with pytest.raises(ExtentCorruptionError):
        arena.read_checked(off, 100)
    arena.close()


def test_arena_bitflip_detected(tmp_path):
    arena = DiskArena(str(tmp_path / "spill.arena"))
    payloads = [bytes([i]) * 64 for i in range(4)]
    offs = arena.write_many(payloads)
    assert arena.read_many_checked(offs, [64] * 4) == payloads
    # flip one payload byte on disk: only that extent is reported bad
    byte = os.pread(arena._fd, 1, offs[2] + 20)
    os.pwrite(arena._fd, bytes([byte[0] ^ 0x01]), offs[2] + 20)
    with pytest.raises(ExtentCorruptionError) as ei:
        arena.read_many_checked(offs, [64] * 4)
    assert ei.value.indices == [2]
    arena.close()


def test_store_truncated_spill_never_serves_garbage(tmp_path):
    rows = tpcc.gen_customer(64)
    store = UncompressedStore(CUSTOMER, memory_budget=2048,
                              spill_path=str(tmp_path / "s.spill"))
    ids = store.insert_many(rows)
    assert store._spilled, "budget should have forced spills"
    os.ftruncate(store._res.disk._fd, 0)
    cold = sorted(store._spilled)[0]
    # no repair_fn installed (no WAL): typed error, never wrong rows
    with pytest.raises(SpillCorruptionError):
        store.get_many([ids[cold]])
    store.close(unlink=True)


# -- resource hygiene (satellite: close/unlink + fd leaks) ----------------

@pytest.mark.skipif(not os.path.exists("/proc/self/fd"),
                    reason="needs procfs to count open fds")
def test_drop_table_releases_files_and_fds(tmp_path):
    rows = tpcc.gen_customer(300)
    before = len(os.listdir("/proc/self/fd"))
    for i in range(3):
        root = str(tmp_path / f"db{i}")
        db = Database(backend="blitzcrank", memory_budget=4 * 1024,
                      durability=root)
        t = db.create_table(_customer_schema(), sample_rows=rows[:256])
        t.insert_many(rows[:256])
        assert os.path.exists(os.path.join(root, "customer.wal"))
        db.drop_table("customer")
        assert not os.path.exists(os.path.join(root, "customer.wal"))
        db.close()
    after = len(os.listdir("/proc/self/fd"))
    assert after <= before, f"leaked {after - before} fds"


def test_disk_arena_context_manager(tmp_path):
    path = str(tmp_path / "spill.arena")
    with DiskArena(path) as arena:
        arena.write_many([b"payload"])
        fd = arena._fd
    with pytest.raises(OSError):
        os.fstat(fd)  # closed on exit
    assert os.path.exists(path)


# -- recovery round trips -------------------------------------------------

def _populated_durable_db(root, rows):
    db = Database(backend="blitzcrank", memory_budget=4 * 1024,
                  durability=root)
    t = db.create_table(_customer_schema(), sample_rows=rows[:256])
    t.insert_many(rows[:256])
    upd = [dict(r, c_balance=float(i)) for i, r in enumerate(rows[:40])]
    t.update_many([r["c_id"] for r in upd], upd)
    t.delete_many(list(range(200, 220)))
    return db


def test_close_reopen_bit_identical(tmp_path):
    root = str(tmp_path / "db")
    rows = tpcc.gen_customer(300)
    db = _populated_durable_db(root, rows)
    keys = [k for k, _ in db["customer"].scan()]
    want = db["customer"].get_many(keys)
    db.close()  # checkpoint + close: recovery is checkpoint + empty tail

    rdb = Database.open(root)
    assert rdb["customer"].get_many(keys) == want
    for t in rdb:
        t.close()

    # corrupting the checkpoint degrades to full from-zero WAL replay,
    # with the same bit-identical answer
    os.unlink(checkpoint_path(root))
    rdb2 = Database.open(root)
    assert rdb2["customer"].get_many(keys) == want
    for t in rdb2:
        t.close()


def test_open_empty_root_is_fresh_durable_db(tmp_path):
    db = Database.open(str(tmp_path / "fresh"))
    assert db.durable and len(db) == 0
    t = db.create_table(_customer_schema(),
                        sample_rows=tpcc.gen_customer(64))
    t.insert_many(tpcc.gen_customer(64))
    db.close()


# -- harness smoke (full matrix runs in the CI recovery-matrix job) -------

@pytest.mark.parametrize("point,backend", [
    ("wal.before_flush", "blitzcrank"),   # in-flight batch is lost
    ("apply.before", "blitzcrank"),       # logged but never applied
    ("checkpoint.mid", "blitzcrank"),     # torn checkpoint tmp file
    ("spill.mid_write", "silo"),          # torn spill segment
])
def test_crash_scenario_smoke(point, backend):
    r = harness.run_crash_scenario(point, backend=backend, seed=0)
    assert r["crashed"], f"{point} never fired"
    assert r["ok"], r["errors"]


def test_corruption_scenarios_smoke():
    errs = harness._scenario_spill_bitflip(0)
    errs += harness._scenario_wal_torn_tail(0)
    assert not errs
