"""HTAP: CH-benCHmark-style OLAP over ``order_line`` concurrent with TPC-C.

The DESIGN.md §8 scan engine evaluates predicates directly on the
compressed code arena — zone maps prune blocks in value space, lowered
predicates eliminate blocks in code space, and only survivors are decoded
(one vectorized ``decode_select`` per plan version).  This bench measures
the three claims that make that an HTAP story rather than a parlor trick:

* **scan throughput** — a selective CH-Q6-style predicate over a loaded,
  transacted ``order_line`` table, pushdown vs the same store's
  decode-everything reference (``pushdown=False``) and vs silo's
  row-store scan; the acceptance gate wants pushdown >= 3x the blitz
  decode-then-filter baseline, with hits bit-identical to the reference
  on both decode backends;
* **OLAP interference on OLTP** — the TPC-C mix runs in fixed-size
  chunks with an analytic aggregate interleaved between chunks; chunked
  txn latency p50 must stay < 2x the txn-only run.  The scan path reads
  cold blocks *without promoting them*, so the analytic side cannot
  evict the transactional working set;
* **cold-tier neutrality** — resident-block population and fault counts
  before/after a burst of pushdown scans, which must not move at all.

Emits ``BENCH_htap.json`` and ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import time
from statistics import median
from typing import Any, Dict, List, Optional, Tuple

from benchmarks.artifact import write_bench_json
from repro.oltp import tpcc
from repro.scan import Range

ACCEPT_SPEEDUP = 3.0        # pushdown vs blitz decode-then-filter
ACCEPT_INTERFERENCE = 2.0   # mixed-chunk p50 vs txn-only p50
TAIL_FRAC = 0.9             # selective predicate: newest ~10% of orders


def _o_tail(db) -> int:
    """Order-id cut for the selective predicate: ``TAIL_FRAC`` of the
    largest minted order id (``ol_o_id`` grows with insertion order, the
    case zone maps are built for)."""
    hi = max(int(r["d_next_o_id"]) for _, r in db["district"].scan())
    return max(1, int(TAIL_FRAC * hi))


def _q_selective(o_tail: int) -> List[Any]:
    return [Range("ol_o_id", lo=o_tail)]


def _time(fn, reps: int) -> Tuple[float, Any]:
    """Median wall seconds over ``reps`` runs + the last return value."""
    times, out = [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return median(times), out


def _residency(db, table: str) -> Optional[Dict[str, int]]:
    res = db[table].stats().get("residency")
    if res is None:
        return None
    return {"faults": res["faults"], "spilled_bytes": res["spilled_bytes"]}


def _scan_arm(db, silo_db, o_tail: int, reps: int, seed: int
              ) -> Dict[str, Any]:
    """Pushdown vs decode-everything on the transacted order_line table."""
    ol = db["order_line"]
    preds = _q_selective(o_tail)
    cols = ["ol_amount", "ol_quantity"]

    before = _residency(db, "order_line")
    t_push, (hits, stats) = _time(
        lambda: ol.scan_where(preds, columns=cols, with_stats=True), reps)
    after = _residency(db, "order_line")
    # pallas decode path must agree bit-for-bit with numpy
    hits_pallas = ol.scan_where(preds, columns=cols, backend="pallas")
    t_silo, silo_hits = _time(
        lambda: silo_db["order_line"].scan_where(preds, columns=cols), 1)
    # the reference LAST: its decode-everything faulting churns the cold
    # tier (that is its cost), which must not contaminate pushdown timing
    t_ref, ref_hits = _time(
        lambda: ol.scan_where(preds, columns=cols, pushdown=False),
        max(1, reps // 2))

    # bit-identity holds within the compressed store (pushdown vs its own
    # decode-everything reference, numpy vs pallas); silo rows carry raw
    # unquantized floats, so only the matched row SET is comparable there
    # (the predicate column is an exact int in both stores)
    identical = bool(hits == ref_hits and hits == hits_pallas
                     and sorted(k for k, _ in hits)
                     == sorted(k for k, _ in silo_hits))
    neutral = (before is None or
               (before["faults"] == after["faults"]
                and before["spilled_bytes"] == after["spilled_bytes"]))
    blocks = max(1, stats.blocks_total)
    return {
        "predicate": f"ol_o_id >= {o_tail}",
        "rows_matched": stats.rows_matched,
        "blocks_total": stats.blocks_total,
        "pruned_frac": round(stats.blocks_pruned / blocks, 4),
        "rows_decoded": stats.rows_decoded,
        "spilled_reads": stats.spilled_reads,
        "push_ms": round(1e3 * t_push, 3),
        "ref_ms": round(1e3 * t_ref, 3),
        "silo_ms": round(1e3 * t_silo, 3),
        "speedup_vs_ref": round(t_ref / max(t_push, 1e-9), 2),
        "speedup_vs_silo": round(t_silo / max(t_push, 1e-9), 2),
        "identical": identical,
        "residency_neutral": bool(neutral),
    }


def _q1(db) -> Dict:
    """CH-Q1 shape: per-line-number totals over delivered lines."""
    return db.query("order_line", [Range("ol_delivery_d", lo=1)],
                    group_by=["ol_number"],
                    aggs={"n": ("count", None),
                          "qty": ("sum", "ol_quantity"),
                          "amt": ("sum", "ol_amount"),
                          "avg_amt": ("avg", "ol_amount")})


def _q6(db, o_tail: int) -> Dict:
    """CH-Q6 shape: revenue from low-quantity lines of recent orders."""
    return db.query("order_line",
                    [Range("ol_o_id", lo=o_tail),
                     Range("ol_quantity", lo=1, hi=5)],
                    aggs={"revenue": ("sum", "ol_amount"),
                          "n": ("count", None)})


def _interference_arm(population, n_shards: int, budgets, n_ops: int,
                      n_chunks: int, seed: int) -> Dict[str, Any]:
    """Chunked TPC-C latency, txn-only vs interleaved with OLAP."""
    per_table = {n: {"memory_budget": b} for n, b in (budgets or {}).items()}

    def build():
        db, _ = tpcc.build_tpcc_database(
            backend="blitzcrank", n_shards=n_shards, population=population,
            per_table_kwargs=per_table or None)
        return db

    chunk = max(1, n_ops // n_chunks)

    def chunked_mix(db, olap=None) -> Tuple[List[float], float]:
        txn_times, olap_s = [], 0.0
        for c in range(n_chunks):
            t0 = time.perf_counter()
            tpcc.run_tpcc_mix(db, chunk, seed=seed + c)
            txn_times.append(time.perf_counter() - t0)
            if olap is not None:
                t0 = time.perf_counter()
                olap(db, c)
                olap_s += time.perf_counter() - t0
        return txn_times, olap_s

    db_alone = build()
    alone_times, _ = chunked_mix(db_alone)

    db_mixed = build()
    o_tail = _o_tail(db_mixed)
    n_olap = 0

    def olap(db, c):
        nonlocal n_olap
        _q1(db) if c % 2 == 0 else _q6(db, o_tail)
        n_olap += 1

    res_before = _residency(db_mixed, "order_line")
    mixed_times, olap_s = chunked_mix(db_mixed, olap)
    res_after = _residency(db_mixed, "order_line")

    p50_alone = median(alone_times)
    p50_mixed = median(mixed_times)
    out = {
        "n_chunks": n_chunks, "ops_per_chunk": chunk, "n_olap": n_olap,
        "txn_p50_alone_ms": round(1e3 * p50_alone, 3),
        "txn_p50_mixed_ms": round(1e3 * p50_mixed, 3),
        "interference_ratio": round(p50_mixed / max(p50_alone, 1e-9), 3),
        "olap_ms_per_query": round(1e3 * olap_s / max(1, n_olap), 3),
    }
    if res_before is not None:
        # faults charged to the analytic queries: total minus what the
        # txn-only run provokes on its own is ~the scans' doing — the
        # engine reads cold blocks without promotion, so this stays 0
        alone_res = _residency(db_alone, "order_line")
        out["txn_only_faults"] = alone_res["faults"]
        out["mixed_faults"] = res_after["faults"]
    return out


def _probe_ol_budget(population, n_shards: int, frac: float) -> int:
    """Cap for order_line: ``frac`` of its fully-resident blitz store
    size, measured by loading just that one table and discarding it."""
    from repro.db.database import Database
    rows = population["order_line"]
    probe = Database(backend="blitzcrank", n_shards=n_shards)
    t = probe.create_table(tpcc.TPCC_TABLES["order_line"],
                           sample_rows=rows)
    t.insert_many(rows)
    budget = max(4096, int(frac * t.stats()["store_bytes"]))
    probe.close()
    return budget


def run(n_warehouses: int = 4, districts_per_wh: int = 10,
        customers_per_district: int = 300, n_items: int = 2000,
        orders_per_district: int = 100, n_shards: int = 4,
        n_warm_ops: int = 1500, n_mix_ops: int = 2400, n_chunks: int = 16,
        scan_reps: int = 5, ol_budget_frac: Optional[float] = None,
        seed: int = 13) -> Dict[str, Any]:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)
    n_ol = len(population["order_line"])
    ol_budget = (None if ol_budget_frac is None else
                 _probe_ol_budget(population, n_shards, ol_budget_frac))
    budgets = {"order_line": ol_budget} if ol_budget else None
    per_table = ({n: {"memory_budget": b} for n, b in budgets.items()}
                 if budgets else None)

    # -- scan arm: loaded + warmed with a transaction prefix -------------
    db, _ = tpcc.build_tpcc_database(backend="blitzcrank",
                                     n_shards=n_shards,
                                     population=population,
                                     per_table_kwargs=per_table)
    silo_db, _ = tpcc.build_tpcc_database(backend="silo",
                                          n_shards=n_shards,
                                          population=population)
    tpcc.run_tpcc_mix(db, n_warm_ops, seed=seed)
    tpcc.run_tpcc_mix(silo_db, n_warm_ops, seed=seed)
    o_tail = _o_tail(db)
    scan = _scan_arm(db, silo_db, o_tail, scan_reps, seed)

    t_q1, q1_groups = _time(lambda: _q1(db), max(1, scan_reps // 2))
    t_q6, q6_out = _time(lambda: _q6(db, o_tail), max(1, scan_reps // 2))

    # -- interference arm: fresh databases, chunked mix ------------------
    interference = _interference_arm(population, n_shards, budgets,
                                     n_mix_ops, n_chunks, seed)

    acc = {
        "speedup_bound": ACCEPT_SPEEDUP,
        "speedup_vs_ref": scan["speedup_vs_ref"],
        "interference_bound": ACCEPT_INTERFERENCE,
        "interference_ratio": interference["interference_ratio"],
        "identical": scan["identical"],
        "residency_neutral": scan["residency_neutral"],
        "pass": bool(scan["speedup_vs_ref"] >= ACCEPT_SPEEDUP
                     and interference["interference_ratio"]
                     < ACCEPT_INTERFERENCE
                     and scan["identical"]
                     and scan["residency_neutral"]),
    }
    return {
        "scale": {
            "n_warehouses": n_warehouses,
            "districts_per_wh": districts_per_wh,
            "customers_per_district": customers_per_district,
            "n_items": n_items, "orders_per_district": orders_per_district,
            "n_shards": n_shards, "order_line_rows": n_ol,
            "n_warm_ops": n_warm_ops, "n_mix_ops": n_mix_ops,
            "ol_budget_frac": ol_budget_frac, "ol_budget": ol_budget,
        },
        "scan": scan,
        "q1": {"ms": round(1e3 * t_q1, 3), "groups": len(q1_groups)},
        "q6": {"ms": round(1e3 * t_q6, 3),
               "result": {k: (round(v, 2) if isinstance(v, float) else v)
                          for k, v in next(iter(q6_out.values())).items()}
               if q6_out else {}},
        "interference": interference,
        "acceptance": acc,
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        report = run(n_warehouses=2, districts_per_wh=2,
                     customers_per_district=30, n_items=100,
                     orders_per_district=12, n_shards=2,
                     n_warm_ops=60, n_mix_ops=120, n_chunks=4,
                     scan_reps=2)
    elif quick:
        report = run(n_warehouses=2, districts_per_wh=6,
                     customers_per_district=120, n_items=800,
                     orders_per_district=60, n_shards=2,
                     n_warm_ops=600, n_mix_ops=1200, n_chunks=8,
                     scan_reps=3, ol_budget_frac=0.35)
    else:
        report = run(ol_budget_frac=0.35)
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("htap", report, schema="tpcc_multi")
    scan, acc = report["scan"], report["acceptance"]
    print(f"htap_scan_push,{1e3 * scan['push_ms']:.0f},"
          f"speedup={scan['speedup_vs_ref']};"
          f"silo_speedup={scan['speedup_vs_silo']};"
          f"pruned_frac={scan['pruned_frac']}")
    print(f"htap_q1,{1e3 * report['q1']['ms']:.0f},"
          f"groups={report['q1']['groups']}")
    inter = report["interference"]
    print(f"htap_mix,{1e3 * inter['txn_p50_mixed_ms']:.0f},"
          f"interference={inter['interference_ratio']};"
          f"olap_ms={inter['olap_ms_per_query']}")
    print(f"htap_acceptance,{acc['speedup_vs_ref']},"
          f"bound={acc['speedup_bound']};identical={acc['identical']};"
          f"interference={acc['interference_ratio']};"
          f"neutral={acc['residency_neutral']};pass={acc['pass']};"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
