"""Shared BENCH_*.json artifact helpers: every artifact is stamped with the
git SHA it was produced at and the schema it measured, so trajectories
across PRs are comparable (ISSUE 2 CI/tooling task)."""

from __future__ import annotations

import json
import subprocess
from pathlib import Path
from typing import Dict

REPO_ROOT = Path(__file__).resolve().parent.parent

# Smoke mode (benchmarks/run.py --smoke): exercise every bench at tiny
# sizes without clobbering the checked-in BENCH_*.json trajectories.
_SMOKE = False


def set_smoke(on: bool) -> None:
    global _SMOKE
    _SMOKE = bool(on)


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "describe", "--always", "--dirty"],
                             cwd=REPO_ROOT, capture_output=True, text=True,
                             timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def write_bench_json(name: str, report: Dict, schema: str) -> Path:
    """Write ``BENCH_<name>.json`` at the repo root with sha+schema stamps.

    In smoke mode the write is skipped (the path is still returned) so a
    tiny-size CI pass can never overwrite a real trajectory artifact.
    """
    report = dict(report)
    report.setdefault("schema_name", schema)
    report["git_sha"] = git_sha()
    path = REPO_ROOT / f"BENCH_{name}.json"
    if not _SMOKE:
        path.write_text(json.dumps(report, indent=2) + "\n")
    return path
