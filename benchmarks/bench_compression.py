"""Figure 9 reproduction: compression factor / insert / random access / training
across compressors on the TPC-C-like tables (§6.1 setting, CPU-scaled sizes)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.oltp import tpcc
from repro.oltp.store import BlitzStore, RamanStore, UncompressedStore, ZstdStore


def run(n_rows: int = 6000, n_access: int = 1500, zipf_a: float = 1.1,
        correlation: bool = False) -> List[Dict]:
    out = []
    for tname, (schema, gen) in tpcc.TABLES.items():
        rows = gen(n_rows)
        raw = tpcc.row_bytes(rows)
        rng = np.random.default_rng(7)
        # YCSB-C style Zipfian point reads
        ranks = tpcc.zipf_keys(rng, n_rows, n_access, a=zipf_a)
        for cls in (UncompressedStore, ZstdStore, RamanStore, BlitzStore):
            kw = {}
            if cls is BlitzStore:
                kw["correlation"] = correlation
            t0 = time.perf_counter()
            try:
                store = cls(schema, rows[:n_rows // 2], **kw)
            except ImportError:  # optional backend (zstandard) not installed
                continue
            t_train = time.perf_counter() - t0
            t0 = time.perf_counter()
            # every store's real batched path (RowStore protocol), so the
            # comparison measures codecs, not Python loop overhead
            store.insert_many(rows)
            t_insert = (time.perf_counter() - t0) / n_rows
            t0 = time.perf_counter()
            for i in ranks:
                store.get(int(i))
            t_access = (time.perf_counter() - t0) / len(ranks)
            # batched point gets (the compiled decode_select path)
            t0 = time.perf_counter()
            tpcc.batched_point_gets(store, ranks, batch=256)
            t_batch = (time.perf_counter() - t0) / len(ranks)
            factor = raw / max(store.nbytes, 1)
            out.append({
                "table": tname, "compressor": store.name,
                "factor": round(factor, 2),
                "insert_us": round(1e6 * t_insert, 1),
                "access_us": round(1e6 * t_access, 1),
                "batch_us": round(1e6 * t_batch, 2),
                "train_s": round(t_train, 3),
                "model_bytes": getattr(store, "model_bytes", 0),
            })
    return out


def main(quick: bool = True, smoke: bool = False):
    if smoke:
        rows = run(n_rows=500, n_access=100)
    else:
        rows = run(n_rows=3000 if quick else 20000,
                   n_access=600 if quick else 5000)
    for r in rows:
        print(f"fig9_{r['table']}_{r['compressor']},"
              f"{r['access_us']},factor={r['factor']}"
              f";insert_us={r['insert_us']};batch_us={r['batch_us']}"
              f";train_s={r['train_s']}"
              f";model_B={r['model_bytes']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
