"""Benchmark drift report: fresh BENCH_*.json vs the committed stamps.

The nightly CI job refreshes every ``BENCH_*.json`` in place with a full
(non-smoke) run and then calls this module to diff the refreshed numbers
against what is committed at ``HEAD``.  The report is a per-metric delta
table — every numeric leaf of every artifact, with relative change and a
drift flag — uploaded as a build artifact so slow regressions that stay
inside the hard ``check_regression`` bounds are still visible as a trend.

This is a *report*, not a gate: it always exits 0 unless an artifact is
unreadable.  The hard bounds live in ``benchmarks/check_regression.py``.

Usage::

    python -m benchmarks.drift_report                # table to stdout
    python -m benchmarks.drift_report --out drift.md # and to a file
    python -m benchmarks.drift_report --ref HEAD~1   # diff another ref
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent

# Relative change beyond which a metric is flagged.  Wall-clock numbers
# are noisy between runners, so the flag threshold is deliberately loose;
# the table itself carries the exact deltas for trend reading.
FLAG_REL = 0.15

# Bookkeeping leaves that aren't measurements: identity stamps and scale
# knobs change legitimately and would only add noise to the table.
SKIP_KEYS = {"git_sha", "schema_name", "mode", "seed"}
SKIP_TOP = {"scale"}


def _leaves(doc: object, prefix: str = "") -> Iterator[Tuple[str, float]]:
    """Yield ``dotted.path -> value`` for every numeric leaf (bools as
    0/1 so correctness flips show up as a 100% drift)."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            if k in SKIP_KEYS or (not prefix and k in SKIP_TOP):
                continue
            yield from _leaves(v, f"{prefix}.{k}" if prefix else k)
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            yield from _leaves(v, f"{prefix}[{i}]")
    elif isinstance(doc, bool):
        yield prefix, float(doc)
    elif isinstance(doc, (int, float)):
        yield prefix, float(doc)


def _committed(root: Path, ref: str, name: str) -> Optional[dict]:
    try:
        blob = subprocess.run(
            ["git", "-C", str(root), "show", f"{ref}:{name}"],
            capture_output=True,
            check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None  # new artifact this cycle, or ref predates it


def diff_artifact(
    fresh: dict, committed: Optional[dict]
) -> List[Tuple[str, Optional[float], Optional[float], Optional[float]]]:
    """Rows of ``(metric, old, new, rel_change)``; ``None`` old marks a
    metric (or whole artifact) new since the ref."""
    new_map = dict(_leaves(fresh))
    old_map = dict(_leaves(committed)) if committed else {}
    rows = []
    for key in sorted(set(new_map) | set(old_map)):
        old, new = old_map.get(key), new_map.get(key)
        rel = None
        if old is not None and new is not None:
            rel = (new - old) / abs(old) if old != 0 else (0.0 if new == 0 else None)
        rows.append((key, old, new, rel))
    return rows


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "—"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}"


def render(
    per_artifact: Dict[str, List[Tuple]], ref: str, flag_rel: float = FLAG_REL
) -> str:
    lines = [
        f"# Benchmark drift vs `{ref}`",
        "",
        f"Flag threshold: ±{flag_rel:.0%} relative change. "
        "Report only — hard bounds are enforced by `check_regression.py`.",
        "",
    ]
    n_flagged = 0
    for name, rows in per_artifact.items():
        flagged = [
            r for r in rows if r[3] is not None and abs(r[3]) > flag_rel
        ]
        n_flagged += len(flagged)
        status = f"{len(flagged)} flagged" if flagged else "stable"
        lines += [f"## {name} ({status})", ""]
        lines += [
            "| metric | committed | fresh | Δ |",
            "|---|---:|---:|---:|",
        ]
        for key, old, new, rel in rows:
            mark = ""
            if rel is not None and abs(rel) > flag_rel:
                mark = " ⚠"
            delta = "new" if old is None else (
                "gone" if new is None else f"{rel:+.1%}" if rel is not None else "?"
            )
            lines.append(
                f"| `{key}` | {_fmt(old)} | {_fmt(new)} | {delta}{mark} |"
            )
        lines.append("")
    lines.insert(1, "")
    lines.insert(
        1,
        f"**{n_flagged} metric(s) flagged** across "
        f"{len(per_artifact)} artifact(s).",
    )
    return "\n".join(lines)


def render_phases(
    fresh_docs: Dict[str, dict], committed_docs: Dict[str, Optional[dict]]
) -> str:
    """Per-phase wall-time section (DESIGN.md §9.4): each artifact that
    carries a top-level ``phases`` dict gets a table of where its measured
    wall time went, with the committed fraction alongside so a phase
    quietly swallowing the budget (fsync creep, a cold jit cache) is
    visible as a trend even when total wall moved less than the flag."""
    lines: List[str] = []
    for name, doc in fresh_docs.items():
        ph = doc.get("phases")
        if not isinstance(ph, dict) or "phases_s" not in ph:
            continue
        old = (committed_docs.get(name) or {}).get("phases") or {}
        old_frac = old.get("phase_frac", {})
        lines += [
            f"### {name} — wall {_fmt(ph.get('wall_s'))}s, "
            f"coverage {_fmt(ph.get('coverage'))}",
            "",
            "| phase | seconds | frac | committed frac |",
            "|---|---:|---:|---:|",
        ]
        fracs = ph.get("phase_frac", {})
        for phase, secs in ph["phases_s"].items():
            lines.append(
                f"| {phase} | {_fmt(secs)} | {_fmt(fracs.get(phase))} "
                f"| {_fmt(old_frac.get(phase))} |"
            )
        lines.append("")
    if not lines:
        return ""
    return "\n".join(["## Phase breakdown", ""] + lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=str(REPO_ROOT))
    ap.add_argument("--ref", default="HEAD", help="git ref to diff against")
    ap.add_argument("--out", default=None, help="also write the report here")
    ap.add_argument(
        "--flag-rel",
        type=float,
        default=FLAG_REL,
        help="relative change beyond which a metric is flagged",
    )
    args = ap.parse_args(argv)
    root = Path(args.root)

    per_artifact: Dict[str, List[Tuple]] = {}
    fresh_docs: Dict[str, dict] = {}
    committed_docs: Dict[str, Optional[dict]] = {}
    for path in sorted(root.glob("BENCH_*.json")):
        try:
            fresh = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            print(f"drift_report: {path.name}: invalid JSON ({e})", file=sys.stderr)
            return 1
        fresh_docs[path.name] = fresh
        committed_docs[path.name] = _committed(root, args.ref, path.name)
        per_artifact[path.name] = diff_artifact(fresh, committed_docs[path.name])
    if not per_artifact:
        print("drift_report: no BENCH_*.json artifacts found", file=sys.stderr)
        return 1

    report = render(per_artifact, args.ref, args.flag_rel)
    phases = render_phases(fresh_docs, committed_docs)
    if phases:
        report = report + "\n" + phases
    print(report)
    if args.out:
        Path(args.out).write_text(report + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
