"""Adaptive model maintenance under a drifting TPC-C mix: refit on vs off.

The paper's §5 headline over prior semantic compressors is *dynamic value
sets*: compression that holds up as the workload drifts.  This bench drives
the drifting customer mix (``tpcc.drifting_customer_row`` — new names,
cities, employers, widening balances, with intensity growing over the run)
through two BlitzStores:

* ``refit_off`` — the fitted models are frozen at load time; late-run
  inserts escape the plan on several columns and the store degrades
  toward raw size;
* ``refit_on``  — the ``repro.adaptive`` maintenance loop (DESIGN.md §4)
  detects the drift from the plan's escape-rate windows, refits the
  drifted column models on a reservoir of recent writes into new plan
  versions, and opportunistically migrates stale escaped blocks.

Acceptance (ISSUE 3): refit-on ends the run with a compression factor
>= 1.5x refit-off, and mixed-plan-version batched reads (numpy AND
Pallas-interpret) are bit-identical to the scalar per-block reference.
Emits ``BENCH_adaptive_refit.json`` and ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.artifact import write_bench_json
from repro.adaptive import DriftConfig, MaintenanceConfig
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore

ACCEPT_RATIO = 1.5

MAINT = MaintenanceConfig(
    drift=DriftConfig(rate_threshold=0.02, min_escapes=32,
                      min_window_rows=256),
    check_every=1024, reservoir_size=4096, min_refit_rows=256,
    migrate_rows_per_step=2048,
    # wide numeric headroom: the drifting balances/keys widen continuously,
    # so each refit should buy a long quiet stretch, not a refit per window
    numeric_headroom=2.0)


def _scalar_reference(store: BlitzStore, i: int) -> Optional[Dict]:
    """Overlay-aware per-tuple scalar decode: the independent read path."""
    if i in store._tombstones:
        return None
    ov = store._overlay.get(i)
    if ov is not None:
        return dict(ov)
    return store.table.get(i) if store.table.is_live(i) else None


def _run_arm(schema, rows, n_ops: int, adaptive: bool, seed: int,
             sample_points: int) -> Dict:
    store = BlitzStore(schema, rows, sample=1 << 14,
                       merge_min_bytes=1 << 14,
                       adaptive=MAINT if adaptive else False)
    store.insert_many(rows)
    post_load = store.stats()
    series: List[Dict] = []

    def on_sample(ops_done: int) -> None:
        st = store.stats()
        series.append({
            "ops": ops_done,
            "total_bytes": st["nbytes"],
            "fast_fraction": round(st["fast_fraction"], 4),
            "plan_versions": st["plan_versions"],
            "migrated_rows": st["migrated_rows"],
        })

    t0 = time.perf_counter()
    counts = tpcc.run_transaction_mix(
        store, n_ops, seed=seed, batch=64,
        p_payment=0.25, p_order_status=0.15, p_new_order=0.55,
        p_delivery=0.05, new_row_fn=tpcc.drifting_customer_row, drift=1.0,
        sample_every=max(1, n_ops // sample_points), on_sample=on_sample)
    mix_s = time.perf_counter() - t0

    live_rows = [r for _, r in store.scan()]
    raw = tpcc.row_bytes(live_rows)
    final = store.stats()

    # Reads across mixed plan versions must be bit-identical to the scalar
    # reference, through both batched decode backends.
    rng = np.random.default_rng(seed + 1)
    idx = [int(i) for i in rng.integers(0, len(store), 1000)]
    ref = [_scalar_reference(store, i) for i in idx]
    id_numpy = store.get_many(idx, backend="numpy") == ref
    id_pallas = store.get_many(idx, backend="pallas") == ref

    out = {
        "adaptive": adaptive,
        "mix_s": round(mix_s, 2),
        "ops": counts["ops"],
        "inserts": counts["inserts"],
        "post_load_bytes": post_load["nbytes"],
        "final_bytes": final["nbytes"],
        "raw_bytes": raw,
        "factor": round(raw / final["nbytes"], 3),
        "fast_fraction": round(final["fast_fraction"], 4),
        "plan_versions": final["plan_versions"],
        "version_rows": {str(k): v for k, v in
                         final["version_rows"].items()},
        "migrated_rows": final["migrated_rows"],
        "model_bytes": final["model_bytes"],
        "reads_identical_numpy": bool(id_numpy),
        "reads_identical_pallas": bool(id_pallas),
        "series": series,
    }
    if final.get("maintenance"):
        m = final["maintenance"]
        out["refits"] = m["refits"]
        out["frozen_columns"] = m["frozen_columns"]
    return out


def run(n_rows: int = 3000, n_ops: int = 20000, seed: int = 7,
        sample_points: int = 20) -> Dict:
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    arms = {
        "refit_on": _run_arm(schema, rows, n_ops, True, seed, sample_points),
        "refit_off": _run_arm(schema, rows, n_ops, False, seed,
                              sample_points),
    }
    on, off = arms["refit_on"], arms["refit_off"]
    ratio = on["factor"] / off["factor"]
    identical = (on["reads_identical_numpy"] and on["reads_identical_pallas"])
    return {
        "n_rows": n_rows,
        "n_ops": n_ops,
        "drift": 1.0,
        "arms": arms,
        "acceptance": {
            "ratio_bound": ACCEPT_RATIO,
            "factor_ratio": round(ratio, 3),
            "mixed_versions": on["plan_versions"] >= 2,
            "reads_identical": identical,
            "pass": bool(ratio >= ACCEPT_RATIO and identical
                         and on["plan_versions"] >= 2),
        },
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    # Smoke barely exercises the loop (sizes too small for a stable ratio);
    # quick shrinks the table, not the story; acceptance-scale is --full.
    if smoke:
        report = run(n_rows=400, n_ops=1500, sample_points=3)
    else:
        report = run(n_rows=3000 if quick else 6000,
                     n_ops=20000 if quick else 50000)
    report["scale"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("adaptive_refit", report, schema="customer")
    for arm_name, arm in report["arms"].items():
        us = 1e6 * arm["mix_s"] / report["n_ops"]
        print(f"adaptive_refit_{arm_name},{us:.1f},"
              f"factor={arm['factor']};versions={arm['plan_versions']};"
              f"identical={arm['reads_identical_numpy']}")
    acc = report["acceptance"]
    print(f"adaptive_refit_acceptance,{acc['factor_ratio']},"
          f"bound={acc['ratio_bound']};pass={acc['pass']};"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
