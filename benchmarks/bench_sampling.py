"""Figure 10 reproduction: sensitivity to the structure-learning sample count
(structuring / generation timings + compression factor vs #samples)."""

from __future__ import annotations

import time
from typing import Dict, List


from repro.core import TableCodec
from repro.oltp import tpcc


def run(samples=(256, 1024, 4096, 16384), n_rows: int = 8000) -> List[Dict]:
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    out = []
    for s in samples:
        codec = TableCodec.fit(rows, schema, correlation=True,
                               sample=min(s, n_rows))
        t0 = time.perf_counter()
        nbytes = sum(2 * codec.compress_block([r]).size for r in rows[:1000])
        comp_s = time.perf_counter() - t0
        raw1k = tpcc.row_bytes(rows[:1000])
        out.append({
            "samples": s,
            "factor": round(raw1k / max(nbytes, 1), 2),
            "structuring_s": round(codec.stats.structuring_s, 3),
            "generation_s": round(codec.stats.generation_s, 3),
            "compress_s": round(comp_s, 3),
            "parents": sum(v is not None
                           for v in codec.stats.parents.values()),
        })
    return out


def main(quick: bool = True, smoke: bool = False):
    if smoke:
        rows = run(samples=(256, 1024), n_rows=800)
    else:
        rows = run(samples=(256, 1024, 4096) if quick else
                   (256, 1024, 4096, 16384, 32768),
                   n_rows=3000 if quick else 16000)
    for r in rows:
        print(f"fig10_samples{r['samples']},{1e6*r['structuring_s']:.0f},"
              f"factor={r['factor']};gen_s={r['generation_s']}"
              f";parents={r['parents']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
