"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).  ``--full``
uses paper-scale row counts; the default is CPU-quick.  ``--smoke`` runs
every bench at tiny sizes with BENCH_*.json artifact writes disabled — the
CI job runs it so benchmark scripts can't silently rot.
"""

from __future__ import annotations

import argparse
import inspect
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, no artifact writes (CI rot check)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (artifact, bench_adaptive_refit, bench_archive,
                            bench_batch_decode, bench_compression,
                            bench_db_tpcc, bench_entropy_coders,
                            bench_exec_engine, bench_fastpath,
                            bench_framework, bench_granularity, bench_htap,
                            bench_out_of_core, bench_recovery,
                            bench_sampling, bench_sanitize,
                            bench_telemetry, bench_update_merge,
                            roofline_report)

    if args.smoke:
        artifact.set_smoke(True)

    benches = {
        "compression": bench_compression,        # Fig 9
        "batch_decode": bench_batch_decode,      # DESIGN.md §2 fast path
        "update_merge": bench_update_merge,      # DESIGN.md §3 delta merge
        "adaptive_refit": bench_adaptive_refit,  # DESIGN.md §4 drift/refit
        "db_tpcc": bench_db_tpcc,                # DESIGN.md §5 engine, §6
        "exec_engine": bench_exec_engine,        # DESIGN.md §11 plan/run
        "out_of_core": bench_out_of_core,        # DESIGN.md §6 cold tier
        "recovery": bench_recovery,              # DESIGN.md §7 durability
        "htap": bench_htap,                      # DESIGN.md §8 scan engine
        "telemetry": bench_telemetry,            # DESIGN.md §9 overhead gate
        "sanitize": bench_sanitize,              # DESIGN.md §10 overhead note

        "sampling": bench_sampling,              # Fig 10
        "entropy": bench_entropy_coders,         # Fig 11
        "granularity": bench_granularity,        # Fig 12
        "fastpath": bench_fastpath,              # Fig 13
        "archive": bench_archive,                # App F / Table 3
        "framework": bench_framework,            # beyond-paper integrations
        "roofline": roofline_report,             # §Dry-run/§Roofline artifacts
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, mod in benches.items():
        if only and name not in only:
            continue
        kwargs = {"quick": quick}
        if args.smoke and "smoke" in inspect.signature(mod.main).parameters:
            kwargs["smoke"] = True
        t0 = time.time()
        try:
            mod.main(**kwargs)
            print(f"bench_{name}_wall,{1e6*(time.time()-t0):.0f},ok")
        except Exception as e:  # noqa: BLE001
            print(f"bench_{name}_wall,0,ERROR={type(e).__name__}:{e}")
            raise


if __name__ == "__main__":
    main()
