"""Figure 11 reproduction: entropy-coder decode speed comparison.

Delayed coding vs arithmetic coding vs rANS, each over the same semantic
models; plus the vectorized (batch) delayed decoder and the 2**16-LUT
variants (the paper's dotted "w/ decoding map" lines).  Uniform-cardinality
columns, sizes scaled for CPU."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import arithmetic, rans
from repro.core.coders import DiscreteCoder, quantize_freqs
from repro.core.delayed import decode_block, encode_symbols
from repro.core.vectorized import decode_batch, encode_batch


def run(n_cols_list=(4, 16, 64), n_rows: int = 800) -> List[Dict]:
    out = []
    rng = np.random.default_rng(0)
    for n_cols in n_cols_list:
        # uniform cardinality-255 columns sampled from ASCII codes (§6.3)
        coder = DiscreteCoder(quantize_freqs(np.ones(255)))
        coders = [coder] * n_cols
        syms = rng.integers(0, 255, size=(n_rows, n_cols))

        # ---- encode (per row = per tuple) ----
        enc_delayed = [encode_symbols(list(s), coders) for s in syms]
        enc_arith = [arithmetic.encode_block(list(s), coders) for s in syms]
        enc_rans = [rans.encode_block(list(s), coders) for s in syms]

        t0 = time.perf_counter()
        for codes in enc_delayed:
            decode_block(codes, coders)
        t_delayed = time.perf_counter() - t0

        t0 = time.perf_counter()
        for payload, nbits in enc_arith:
            arithmetic.decode_block(payload, nbits, coders)
        t_arith = time.perf_counter() - t0

        t0 = time.perf_counter()
        for words in enc_rans:
            rans.decode_block(words, coders)
        t_rans = time.perf_counter() - t0

        enc_rans_cdf = [rans.encode_block_cdf(list(s), coders) for s in syms]
        t0 = time.perf_counter()
        for words in enc_rans_cdf:
            rans.decode_block_cdf(words, coders)
        t_rans_cdf = time.perf_counter() - t0

        # ---- batched delayed decoding (the TPU-layout host path) ----
        codes_b, offs = encode_batch(syms, coders)
        t0 = time.perf_counter()
        decode_batch(codes_b, offs, coders)
        t_vec = time.perf_counter() - t0

        per = 1e6 / n_rows
        out.append({
            "n_cols": n_cols,
            "delayed_us": round(t_delayed * per, 1),
            "arith_us": round(t_arith * per, 1),
            "rans_alias_us": round(t_rans * per, 1),
            "rans_cdf_us": round(t_rans_cdf * per, 1),
            "delayed_batch_us": round(t_vec * per, 2),
            "bits_delayed": 16 * sum(len(c) for c in enc_delayed) / n_rows,
            "bits_arith": sum(b for _, b in enc_arith) / n_rows,
            "bits_rans": 16 * sum(len(w) for w in enc_rans) / n_rows,
        })
    return out


def main(quick: bool = True, smoke: bool = False):
    rows = run(n_rows=100 if smoke else (300 if quick else 2000))
    for r in rows:
        print(f"fig11_cols{r['n_cols']}_delayed,{r['delayed_us']},"
              f"bits={r['bits_delayed']:.0f}")
        print(f"fig11_cols{r['n_cols']}_arith,{r['arith_us']},"
              f"bits={r['bits_arith']:.0f}")
        print(f"fig11_cols{r['n_cols']}_rans,{r['rans_alias_us']},"
              f"bits={r['bits_rans']:.0f}")
        print(f"fig11_cols{r['n_cols']}_rans_cdf,{r['rans_cdf_us']},")
        print(f"fig11_cols{r['n_cols']}_delayed_batch,"
              f"{r['delayed_batch_us']},vectorized=1")
    return rows


if __name__ == "__main__":
    main(quick=False)
