"""Appendix F reproduction: archive-mode comparison (whole-table compression)
vs gzip/zstd-9, plus the time-series (AR residual) ablation of Table 3."""

from __future__ import annotations

import gzip
import json
import time
from typing import Dict, List

import numpy as np

from repro.core import TableCodec
from repro.core.models import NumericModel, TimeSeriesModel, BlockEncoder
from repro.core.delayed import encode_block
from repro.oltp import tpcc


def _table_blob(rows, schema) -> bytes:
    return json.dumps([[r[c.name] for c in schema] for r in rows]).encode()


def run(n_rows: int = 4000) -> List[Dict]:
    import zstandard as zstd
    out = []
    for tname, (schema, gen) in tpcc.TABLES.items():
        rows = gen(n_rows)
        raw = tpcc.row_bytes(rows)
        blob = _table_blob(rows, schema)

        t0 = time.perf_counter()
        gz = gzip.compress(blob, 6)
        t_gz = time.perf_counter() - t0
        t0 = time.perf_counter()
        zs = zstd.ZstdCompressor(level=9).compress(blob)
        t_zs = time.perf_counter() - t0

        # Blitzcrank archive mode: whole table = one big block
        codec = TableCodec.fit(rows, schema, block_tuples=len(rows))
        t0 = time.perf_counter()
        codes = codec.compress_block(rows)
        t_blz = time.perf_counter() - t0
        out.append({
            "table": tname,
            "gzip": round(raw / len(gz), 2),
            "zstd9": round(raw / len(zs), 2),
            "blitz_archive": round(raw / (2 * codes.size), 2),
            "t_gzip_s": round(t_gz, 2), "t_zstd_s": round(t_zs, 2),
            "t_blitz_s": round(t_blz, 2),
        })

    # ---- App F.2: JSON collection vs flattened relation (dblp-style) ----
    from repro.core.json_model import JsonCodec
    rng = np.random.default_rng(1)
    venues = ["VLDB", "SIGMOD", "ICDE", "CIDR", "EDBT"]
    objs = []
    for i in range(800):
        o = {"title": f"Paper {int(rng.zipf(1.4))} on topic "
                      f"{int(rng.integers(0, 40))}",
             "year": int(rng.integers(1995, 2024)),
             "venue": venues[int(rng.zipf(1.5)) % len(venues)],
             "pages": [int(rng.integers(1, 500)),
                       int(rng.integers(500, 999))]}
        if rng.random() < 0.6:
            o["ee"] = f"https://doi.org/10.{int(rng.integers(1000, 9999))}"
        objs.append(o)
    codec_j = JsonCodec(objs[:400])
    comp = sum(2 * len(codec_j.encode(o)) for o in objs)
    raw_j = sum(len(json.dumps(o)) for o in objs)
    zs_j = len(zstd.ZstdCompressor(level=9).compress(
        json.dumps(objs).encode()))
    out.append({
        "table": "json_dblp_like",
        "blitz_json": round(raw_j / comp, 2),
        "zstd9_json": round(raw_j / zs_j, 2),
    })

    # ---- Table 3: AR-residual time-series model vs raw numeric model ----
    rng = np.random.default_rng(0)
    walk = np.cumsum(rng.normal(0, 1.0, 20000)) + 50.0  # Jena-like drift
    vals = np.round(walk, 2).tolist()

    def bits_of(model):
        if hasattr(model, "reset_block"):
            model.reset_block()
        enc = BlockEncoder()
        for v in vals[:4000]:
            model.encode_value(v, enc)
        return 16 * len(encode_block(enc.slots))

    raw_bits = 64 * 4000
    b_numeric = bits_of(NumericModel(vals, precision=0.01))
    b_ts = bits_of(TimeSeriesModel(vals, precision=0.01))
    out.append({
        "table": "jena_like_ts",
        "numeric_factor": round(raw_bits / b_numeric, 2),
        "ts_factor": round(raw_bits / b_ts, 2),
        "improvement_pct": round(100 * (b_numeric - b_ts) / b_numeric, 1),
    })
    return out


def main(quick: bool = True, smoke: bool = False):
    try:
        import zstandard  # noqa: F401  (optional baseline dependency)
    except ImportError:
        print("appF_archive,0,skipped=zstandard-not-installed")
        return []
    rows = run(n_rows=400 if smoke else (1500 if quick else 8000))
    for r in rows:
        if "gzip" in r:
            print(f"appF_{r['table']}_archive,{1e3*r['t_blitz_s']:.0f},"
                  f"blitz={r['blitz_archive']};zstd9={r['zstd9']}"
                  f";gzip={r['gzip']}")
        elif "blitz_json" in r:
            print(f"appF_json,0,blitz={r['blitz_json']}"
                  f";zstd9={r['zstd9_json']}")
        else:
            print(f"appE_timeseries,0,numeric={r['numeric_factor']}"
                  f";ts={r['ts_factor']};improve%={r['improvement_pct']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
