"""Beyond-paper framework benchmarks: compression integrated at the four
storage boundaries (DESIGN.md §3) — KV pages, checkpoints, gradients, data."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp


def run() -> List[Dict]:
    from repro.configs import reduced_config
    from repro.models import transformer as T
    from repro.serve.engine import Engine
    from repro.tensor.codec import fit_codec
    from repro.tensor.grad_compress import wire_bytes, _quant_block, _dequant_block
    from repro.data.pipeline import CompressedExampleStore, SyntheticLM

    out = []
    cfg = reduced_config("gemma2-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # 1. KV page compression (serving)
    eng = Engine(cfg, params, max_len=96)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    _, state = eng.prefill(toks)
    t0 = time.perf_counter()
    store = eng.offload_kv(state, page_tokens=32)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    store.get(0, 0)
    t_fetch = time.perf_counter() - t0
    out.append({"name": "kv_pages",
                "ratio": round(store.raw_nbytes() / max(store.nbytes, 1), 2),
                "offload_us": round(1e6 * t_off, 0),
                "fetch_us": round(1e6 * t_fetch, 0)})

    # 2. checkpoint compression (weights bf16-lossless, moments two-level)
    w = np.asarray(jax.tree.leaves(params)[2]).reshape(-1)
    wv = np.asarray(w, np.float32) if w.dtype.kind == "V" else w
    bf = jnp.asarray(wv, jnp.bfloat16)
    c16 = fit_codec(np.asarray(bf).view(np.uint16), "lossless16")
    ct = c16.encode(np.asarray(bf).view(np.uint16))
    m = np.abs(np.random.default_rng(0).normal(0, 1e-3, 65536)).astype(np.float32)
    cm = fit_codec(m, "twolevel", precision=float(m.std()) * 1e-7)
    ctm = cm.encode(m)
    out.append({"name": "checkpoint",
                "weights_lossless_ratio": round(ct.ratio(), 2),
                "moments_ratio": round(ctm.ratio(), 2)})

    # 3. gradient compression wire bytes (cross-pod, int8 + error feedback)
    g = {"a": jnp.asarray(np.random.default_rng(1).normal(0, 1e-3, (4096,)),
                          jnp.float32)}
    raw, comp = wire_bytes(g)
    q, s = _quant_block(g["a"])
    deq = _dequant_block(q, s, g["a"].shape)
    rel = float(jnp.abs(deq - g["a"]).max() / jnp.abs(g["a"]).max())
    out.append({"name": "grad_compress",
                "wire_reduction": round(raw / comp, 2),
                "max_rel_err": round(rel, 4)})

    # 4. compressed host example store
    lm = SyntheticLM(vocab=2048, seq_len=128, global_batch=8, seed=0)
    sample = lm.batch(0)["tokens"]
    store2 = CompressedExampleStore(sample, vocab=2048)
    for s_ in range(4):
        store2.extend(lm.batch(s_)["tokens"])
    t0 = time.perf_counter()
    rows = store2.get_rows(np.arange(8))
    t_read = time.perf_counter() - t0
    out.append({"name": "example_store",
                "ratio": round(store2.raw_nbytes(2) / max(store2.nbytes, 1), 2),
                "batch_read_us": round(1e6 * t_read, 0)})
    return out


def main(quick: bool = True):
    rows = run()
    for r in rows:
        extras = ";".join(f"{k}={v}" for k, v in r.items() if k != "name")
        print(f"framework_{r['name']},0,{extras}")
    return rows


if __name__ == "__main__":
    main()
