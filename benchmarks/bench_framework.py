"""Beyond-paper framework benchmarks: compression integrated at the four
storage boundaries (DESIGN.md §3) — KV pages, checkpoints, gradients, data."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp


def _kv_pages() -> Dict:
    from repro.configs import reduced_config
    from repro.models import transformer as T
    from repro.serve.engine import Engine

    cfg = reduced_config("gemma2-9b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=96)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    _, state = eng.prefill(toks)
    t0 = time.perf_counter()
    store = eng.offload_kv(state, page_tokens=32)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    store.get(0, 0)
    t_fetch = time.perf_counter() - t0
    return {"name": "kv_pages",
            "ratio": round(store.raw_nbytes() / max(store.nbytes, 1), 2),
            "offload_us": round(1e6 * t_off, 0),
            "fetch_us": round(1e6 * t_fetch, 0)}


def _checkpoint() -> Dict:
    from repro.tensor.codec import fit_codec

    bf = jnp.asarray(np.random.default_rng(2).normal(0, 0.02, 65536)
                     .astype(np.float32), jnp.bfloat16)
    c16 = fit_codec(np.asarray(bf).view(np.uint16), "lossless16")
    ct = c16.encode(np.asarray(bf).view(np.uint16))
    m = np.abs(np.random.default_rng(0).normal(0, 1e-3, 65536)).astype(np.float32)
    cm = fit_codec(m, "twolevel", precision=float(m.std()) * 1e-7)
    ctm = cm.encode(m)
    return {"name": "checkpoint",
            "weights_lossless_ratio": round(ct.ratio(), 2),
            "moments_ratio": round(ctm.ratio(), 2)}


def _grad_compress() -> Dict:
    from repro.tensor.grad_compress import (wire_bytes, _quant_block,
                                            _dequant_block)

    g = {"a": jnp.asarray(np.random.default_rng(1).normal(0, 1e-3, (4096,)),
                          jnp.float32)}
    raw, comp = wire_bytes(g)
    q, s = _quant_block(g["a"])
    deq = _dequant_block(q, s, g["a"].shape)
    rel = float(jnp.abs(deq - g["a"]).max() / jnp.abs(g["a"]).max())
    return {"name": "grad_compress",
            "wire_reduction": round(raw / comp, 2),
            "max_rel_err": round(rel, 4)}


def _example_store() -> Dict:
    from repro.data.pipeline import CompressedExampleStore, SyntheticLM

    lm = SyntheticLM(vocab=2048, seq_len=128, global_batch=8, seed=0)
    sample = lm.batch(0)["tokens"]
    store2 = CompressedExampleStore(sample, vocab=2048)
    for s_ in range(4):
        store2.extend(lm.batch(s_)["tokens"])
    t0 = time.perf_counter()
    store2.get_rows(np.arange(8))
    t_read = time.perf_counter() - t0
    return {"name": "example_store",
            "ratio": round(store2.raw_nbytes(2) / max(store2.nbytes, 1), 2),
            "batch_read_us": round(1e6 * t_read, 0)}


def run() -> List[Dict]:
    # Each storage boundary gates on its own imports: parts of the LM
    # framework absent from this checkout (e.g. repro.dist sharding) skip
    # their section instead of rotting the whole benchmark suite.
    out = []
    for fn in (_kv_pages, _checkpoint, _grad_compress, _example_store):
        try:
            out.append(fn())
        except (ImportError, ModuleNotFoundError) as e:
            out.append({"name": fn.__name__.lstrip("_"),
                        "skipped": f"{type(e).__name__}: {e}"})
    return out


def main(quick: bool = True):
    rows = run()
    for r in rows:
        extras = ";".join(f"{k}={v}" for k, v in r.items() if k != "name")
        print(f"framework_{r['name']},0,{extras}")
    return rows


if __name__ == "__main__":
    main()
