"""Roofline report generator: renders EXPERIMENTS.md §Dry-run/§Roofline tables
from the dry-run artifacts in results/dryrun/."""

from __future__ import annotations

import glob
import json
import pathlib
from typing import Dict, List


def load(results_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(f"{results_dir}/*.json")):
        recs.append(json.loads(pathlib.Path(f).read_text()))
    return recs


def table(recs: List[Dict], mesh: str = "pod16x16",
          layout_suffix: str = "") -> str:
    lines = [
        "| arch | shape | bottleneck | t_comp (s) | t_mem (s) | t_coll (s) "
        "| useful FLOPs | HBM GB/dev | wire GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            continue
        cell = r["cell"]
        parts = cell.split("__")
        if parts[2] != mesh:
            continue
        if (len(parts) > 3) != bool(layout_suffix):
            continue
        if layout_suffix and parts[3] != layout_suffix:
            continue
        rf = r["roofline"]
        mem_gb = (r["memory"].get("temp_size_in_bytes", 0)
                  + r["memory"].get("argument_size_in_bytes", 0)) / 1e9
        lines.append(
            f"| {rf['arch']} | {rf['shape']} | **{rf['bottleneck']}** "
            f"| {rf['t_compute']:.3f} | {rf['t_memory']:.3f} "
            f"| {rf['t_collective']:.3f} | {rf['useful_flops_frac']:.2f} "
            f"| {mem_gb:.1f} | {rf['wire_gbytes_per_chip']:.1f} |")
    return "\n".join(lines)


def skips(recs: List[Dict]) -> str:
    lines = []
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"- `{r['cell']}`: {r['reason']}")
    return "\n".join(sorted(set(lines)))


def summary(recs: List[Dict]) -> Dict[str, int]:
    out = {"ok": 0, "skipped": 0, "error": 0}
    for r in recs:
        out[r.get("status", "error")] = out.get(r.get("status", "error"), 0) + 1
    return out


def main(quick: bool = True):
    recs = load()
    s = summary(recs)
    print(f"roofline_cells,0,ok={s['ok']};skipped={s['skipped']}"
          f";error={s['error']}")
    worst = None
    for r in recs:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        tot = rf["t_compute"] + rf["t_memory"] + rf["t_collective"]
        frac = rf["t_compute"] / tot if tot else 0
        if worst is None or frac < worst[1]:
            worst = (r["cell"], frac)
    if worst:
        print(f"roofline_worst_compute_frac,0,{worst[0]}={worst[1]:.3f}")
    return recs


if __name__ == "__main__":
    main()
