"""Figure 12 reproduction: compression factor & access latency vs block size."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.oltp import tpcc
from repro.oltp.store import BlitzStore


def run(blocks=(1, 2, 4, 8, 16, 32, 128), n_rows: int = 4000,
        n_access: int = 400, table: str = "orderline") -> List[Dict]:
    schema, gen = tpcc.TABLES[table]
    rows = gen(n_rows)
    raw = tpcc.row_bytes(rows)
    out = []
    rng = np.random.default_rng(1)
    idx = rng.integers(0, n_rows, n_access)
    for bt in blocks:
        store = BlitzStore(schema, rows[:n_rows // 2], block_tuples=bt)
        for r in rows:
            store.insert(r)
        t0 = time.perf_counter()
        for i in idx:
            store.get(int(i))
        t_access = (time.perf_counter() - t0) / n_access
        out.append({"block_tuples": bt,
                    "factor": round(raw / max(store.nbytes, 1), 2),
                    "access_us": round(1e6 * t_access, 1)})
    return out


def main(quick: bool = True, smoke: bool = False):
    if smoke:
        rows = run(n_rows=400, n_access=50)
    else:
        rows = run(n_rows=1500 if quick else 8000,
                   n_access=200 if quick else 2000)
    for r in rows:
        print(f"fig12_block{r['block_tuples']},{r['access_us']},"
              f"factor={r['factor']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
