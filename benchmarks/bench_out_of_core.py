"""Out-of-core TPC-C (paper §6.4, Fig. 15): throughput under a memory budget.

The paper's closing claim: for data sets larger than physical memory,
Blitzcrank "helps the database sustain a high throughput for more
transactions before the I/O overhead dominates".  This bench reproduces
the experiment shape with the DESIGN.md §6 cold tier:

* load a small base population, then drive the multi-table TPC-C mix —
  NewOrder keeps inserting orders/order_lines, so the database *grows*
  through the run;
* cap the blitz store at ``budget_frac`` (default 25%) of its
  fully-resident final size, and cap the uncompressed silo store at the
  **same absolute byte budget** (split across tables proportionally to
  the blitz reference, and across shards inside each table);
* sample windowed throughput during the mix.  An arm has *collapsed*
  once its smoothed window rate drops below half of its own uncapped
  reference rate; "sustained transactions" is the op count of the good
  prefix.  The same absolute budget holds several times more tuples for
  blitz than for silo, so blitz sustains far longer — that gap is the
  acceptance metric (>= 3x).

Both arms pay their own cache-maintenance costs (clock sweeps, fault
reads, promotions) in the same Python runtime, so the comparison is
store-vs-store, not language-vs-language.  Post-mix, every capped blitz
read is checked bit-identical against the uncapped reference database
(full-table numpy reads, sampled pallas reads).

Emits ``BENCH_out_of_core.json`` and ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import numpy as np

from benchmarks.artifact import write_bench_json
from repro import telemetry
from repro.oltp import tpcc

ACCEPT_RATIO = 3.0
BUDGET_FRAC = 0.25
COLLAPSE_FRAC = 0.5  # "throughput halves"
SMOOTH_WINDOWS = 3


def _mix_with_windows(db, n_ops: int, seed: int, window: int):
    """Run the TPC-C mix, recording ops/s per sample window."""
    marks: List[tuple] = []
    t0 = time.perf_counter()

    def on_sample(ops_done: int) -> None:
        marks.append((ops_done, time.perf_counter()))

    counts = tpcc.run_tpcc_mix(db, n_ops, seed=seed, sample_every=window,
                               on_sample=on_sample)
    total_s = time.perf_counter() - t0
    rates: List[float] = []
    prev_ops, prev_t = 0, t0
    for ops_done, t in marks:
        dt = max(t - prev_t, 1e-9)
        rates.append((ops_done - prev_ops) / dt)
        prev_ops, prev_t = ops_done, t
    return counts, rates, total_s


def _sustained_ops(rates: List[float], window: int, ref_rate: float,
                   n_ops: int) -> int:
    """Ops completed before the smoothed rate first halves vs reference.

    The smoothing (mean of the last ``SMOOTH_WINDOWS`` windows) keeps a
    single noisy window — a GC pause, an arena rewrite — from reading as
    a collapse; what we want is the knee where faulting *dominates*.
    """
    for w in range(len(rates)):
        lo = max(0, w - SMOOTH_WINDOWS + 1)
        smoothed = float(np.mean(rates[lo:w + 1]))
        if smoothed < COLLAPSE_FRAC * ref_rate:
            return w * window  # ops completed before this window
    return n_ops


def _build(backend: str, population, n_shards: int,
           budgets: Optional[Dict[str, int]] = None):
    per_table = None
    if budgets is not None:
        per_table = {name: {"memory_budget": b}
                     for name, b in budgets.items()}
    db, _ = tpcc.build_tpcc_database(backend=backend, n_shards=n_shards,
                                     population=population,
                                     per_table_kwargs=per_table)
    return db


def _blitz_identity(capped, reference, seed: int, pallas_sample: int = 256):
    """Every post-mix read from the capped store must be bit-identical to
    the uncapped reference — numpy reads over *all* live rows of every
    table, pallas reads over a bounded sample per table."""
    rng = np.random.default_rng(seed)
    for name in tpcc.TPCC_TABLES:
        table, ref = capped[name], reference[name]
        keys = [k for k, _ in ref.scan()]
        if table.get_many(keys, backend="numpy") != ref.get_many(
            keys, backend="numpy"
        ):
            return False
        if keys:
            picks = [keys[int(i)]
                     for i in rng.integers(0, len(keys), pallas_sample)]
            if table.get_many(picks, backend="pallas") != ref.get_many(
                picks, backend="numpy"
            ):
                return False
    return True


def run(n_warehouses: int = 2, districts_per_wh: int = 10,
        customers_per_district: int = 60, n_items: int = 400,
        orders_per_district: int = 10, n_shards: int = 2,
        n_ops: int = 12000, window: int = 400, seed: int = 7,
        budget_frac: float = BUDGET_FRAC) -> Dict[str, Any]:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)

    # ---- uncapped reference arms: the "fits in memory" throughput ----
    arms: Dict[str, Dict[str, Any]] = {}
    ref_dbs: Dict[str, Any] = {}
    for backend in ("blitzcrank", "silo"):
        db = _build(backend, population, n_shards)
        counts, rates, total_s = _mix_with_windows(db, n_ops, seed, window)
        db.merge_all()
        s = db.stats()
        ref_dbs[backend] = db
        arms[backend + "_resident"] = {
            "backend": backend,
            "capped": False,
            "mix_s": round(total_s, 2),
            "ref_rate_tps": round(float(np.median(rates)), 1),
            "final_bytes": s["nbytes"],
            "store_bytes": s["store_bytes"],
            "counts": counts,
        }

    # The budget: budget_frac of the blitz store's fully-resident final
    # size, split across tables proportionally to where those bytes live.
    blitz_ref = ref_dbs["blitzcrank"].stats()
    budgets = {
        name: max(4096, int(budget_frac * t["store_bytes"]))
        for name, t in blitz_ref["tables"].items()
    }
    total_budget = sum(budgets.values())

    # ---- capped arms: same absolute budget for both stores ----
    for backend in ("blitzcrank", "silo"):
        db = _build(backend, population, n_shards, budgets)
        hist_base = telemetry.REGISTRY.hist_seconds()
        counts, rates, total_s = _mix_with_windows(db, n_ops, seed, window)
        # where the capped mix's wall time goes — under a budget the
        # fault_in/spill phases should dominate the delta vs uncapped
        phases = telemetry.phase_breakdown(total_s, since=hist_base)
        ref_rate = arms[backend + "_resident"]["ref_rate_tps"]
        sustained = _sustained_ops(rates, window, ref_rate, n_ops)
        db.merge_all()
        s = db.stats()
        arm = {
            "backend": backend,
            "capped": True,
            "mix_s": round(total_s, 2),
            "window_rates_tps": [round(r, 1) for r in rates],
            "ref_rate_tps": ref_rate,
            # the capped arm's own throughput — what a latency gate on the
            # cold-tier path must measure (ref_rate_tps is the uncapped
            # reference it is judged against)
            "median_rate_tps": round(float(np.median(rates)), 1),
            "phases": phases,
            "sustained_ops": sustained,
            "final_bytes": s["nbytes"],
            "store_bytes": s["store_bytes"],
            "spilled_bytes": s.get("spilled_bytes", 0),
            "residency": s.get("residency", {}),
            "counts": counts,
        }
        if backend == "blitzcrank":
            arm["reads_identical"] = _blitz_identity(
                db, ref_dbs["blitzcrank"], seed)
        arms[backend + "_capped"] = arm

    blitz, silo = arms["blitzcrank_capped"], arms["silo_capped"]
    # A store that collapses inside its very first window sustains less
    # than one window of transactions; floor at one window so the ratio
    # stays finite and auditable.
    ratio = blitz["sustained_ops"] / max(window, silo["sustained_ops"])
    report = {
        "scale": {
            "n_warehouses": n_warehouses,
            "districts_per_wh": districts_per_wh,
            "customers_per_district": customers_per_district,
            "n_items": n_items,
            "orders_per_district": orders_per_district,
            "n_shards": n_shards,
            "n_ops": n_ops,
            "window": window,
        },
        "budget_frac": budget_frac,
        "budget_bytes": total_budget,
        "per_table_budgets": budgets,
        "arms": arms,
        "phases": arms["blitzcrank_capped"]["phases"],
        "acceptance": {
            "bound": ACCEPT_RATIO,
            "sustained_blitz": blitz["sustained_ops"],
            "sustained_silo": silo["sustained_ops"],
            "sustained_ratio": round(ratio, 2),
            "reads_identical": blitz["reads_identical"],
            "pass": bool(ratio >= ACCEPT_RATIO
                         and blitz["reads_identical"]),
        },
    }
    return report


def main(quick: bool = True, smoke: bool = False) -> Dict[str, Any]:
    # Smoke exercises the spill/fault plumbing at toy sizes (collapse
    # knees are meaningless there); quick is CI-sized; full is the
    # acceptance scale.
    if smoke:
        report = run(n_warehouses=1, districts_per_wh=2,
                     customers_per_district=20, n_items=60,
                     orders_per_district=4, n_shards=2,
                     n_ops=240, window=60)
    elif quick:
        report = run(n_ops=6000, window=300,
                     customers_per_district=40, n_items=300)
    else:
        report = run()
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("out_of_core", report, schema="tpcc_multi")
    for name, arm in report["arms"].items():
        # capped arms report their own measured rate, not the reference
        rate = arm.get("median_rate_tps", arm["ref_rate_tps"])
        sus = arm.get("sustained_ops", "-")
        print(f"out_of_core_{name},{round(1e6 / max(rate, 1e-9), 1)},"
              f"rate_tps={rate};sustained={sus};"
              f"spilled={arm.get('spilled_bytes', 0)}")
    acc = report["acceptance"]
    print(f"out_of_core_acceptance,{acc['sustained_ratio']},"
          f"bound={acc['bound']};blitz={acc['sustained_blitz']};"
          f"silo={acc['sustained_silo']};"
          f"identical={acc['reads_identical']};pass={acc['pass']};"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
