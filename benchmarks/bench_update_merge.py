"""Bytes-over-time under a write-heavy OLTP run: delta-merge on vs off.

The paper's headline claim is *sustained* memory reduction under dynamic
TPC-C traffic (§7).  This bench loads the customer table, then drives a
Zipfian read-modify-write (Payment-style) stream through the RowStore
protocol and samples the store footprint as it runs:

* ``merge``    — BlitzStore with auto delta-merge compaction (DESIGN.md §3):
  the overlay is bounded, dirty rows are re-encoded through ``encode_batch``
  back into the CSR arena, dead runs are reclaimed by arena rewrites.
* ``no_merge`` — the pre-redesign behaviour: updates accumulate in an
  uncompressed overlay forever, so total bytes converge toward raw size.

Acceptance (ISSUE 2): at 50k rows / 100k ops the merge arm must keep total
bytes (arena + overlay) within 1.25x of the post-load compressed size, with
batched reads bit-identical to the scalar reference.  Emits
``BENCH_update_merge.json`` and ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.artifact import write_bench_json
from repro.oltp import tpcc
from repro.oltp.store import BlitzStore

ACCEPT_BOUND = 1.25


def _run_arm(schema, rows, n_ops: int, auto_merge: bool, seed: int,
             sample_points: int) -> Dict:
    store = BlitzStore(schema, rows, sample=1 << 14, auto_merge=auto_merge)
    t0 = time.perf_counter()
    store.insert_many(rows)
    load_s = time.perf_counter() - t0
    post_load = store.stats()
    series: List[Dict] = []

    def on_sample(ops_done: int) -> None:
        st = store.stats()
        series.append({
            "ops": ops_done,
            "total_bytes": st["nbytes"],
            "arena_bytes": st["arena_bytes"],
            "overlay_bytes": st["overlay_bytes"],
            "dead_bytes": st["dead_bytes"],
            "merges": st["merges"],
            "rewrites": st["rewrites"],
        })

    t0 = time.perf_counter()
    counts = tpcc.run_transaction_mix(
        store, n_ops, seed=seed, p_payment=1.0, p_order_status=0.0,
        p_new_order=0.0, p_delivery=0.0,
        sample_every=max(1, n_ops // sample_points), on_sample=on_sample)
    mix_s = time.perf_counter() - t0

    # Reads after the run must be bit-identical to the scalar reference:
    # overlay applied over the per-tuple scalar block decode
    # (CompressedTable.get -> decompress_block), a genuinely independent
    # path from the batched decode_select under test.
    rng = np.random.default_rng(seed + 1)
    idx = rng.integers(0, len(store), 1000)

    def scalar_ref(i):
        ov = store._overlay.get(int(i))
        return dict(ov) if ov is not None else store.table.get(int(i))

    identical = store.get_many(idx) == [scalar_ref(i) for i in idx]

    final = store.stats()
    return {
        "auto_merge": auto_merge,
        "load_s": round(load_s, 2),
        "mix_s": round(mix_s, 2),
        "payments": counts["payments"],
        "post_load_bytes": post_load["nbytes"],
        "final_bytes": final["nbytes"],
        "bytes_ratio": round(final["nbytes"] / post_load["nbytes"], 4),
        "merges": final["merges"],
        "rewrites": final["rewrites"],
        "dead_bytes": final["dead_bytes"],
        "overlay_bytes": final["overlay_bytes"],
        "escapes": {k: v for k, v in final["escapes"].items() if v},
        "reads_identical": bool(identical),
        "series": series,
    }


def run(n_rows: int = 50000, n_ops: int = 100000, seed: int = 7,
        sample_points: int = 25) -> Dict:
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    raw = tpcc.row_bytes(rows)
    arms = {
        "merge": _run_arm(schema, rows, n_ops, True, seed, sample_points),
        "no_merge": _run_arm(schema, rows, n_ops, False, seed, sample_points),
    }
    m = arms["merge"]
    return {
        "n_rows": n_rows,
        "n_ops": n_ops,
        "zipf_a": 1.1,
        "raw_bytes": raw,
        "post_load_factor": round(raw / m["post_load_bytes"], 2),
        "arms": arms,
        "acceptance": {
            "bound": ACCEPT_BOUND,
            "bytes_ratio": m["bytes_ratio"],
            "reads_identical": m["reads_identical"],
            "pass": bool(m["bytes_ratio"] <= ACCEPT_BOUND
                         and m["reads_identical"]),
        },
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    # Quick mode shrinks the table, not the story; the acceptance-scale
    # artifact is produced by ``main(quick=False)`` (50k rows / 100k ops).
    if smoke:
        report = run(n_rows=1500, n_ops=3000, sample_points=5)
    else:
        report = run(n_rows=12000 if quick else 50000,
                     n_ops=24000 if quick else 100000)
    report["scale"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("update_merge", report, schema="customer")
    for arm_name, arm in report["arms"].items():
        us = 1e6 * arm["mix_s"] / report["n_ops"]
        print(f"update_merge_{arm_name},{us:.1f},"
              f"ratio={arm['bytes_ratio']};merges={arm['merges']};"
              f"identical={arm['reads_identical']}")
    acc = report["acceptance"]
    print(f"update_merge_acceptance,{acc['bytes_ratio']},"
          f"bound={acc['bound']};pass={acc['pass']};"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
