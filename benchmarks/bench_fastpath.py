"""Figure 13 reproduction: LRU fast-path write-back cache under YCSB-F
(read-modify-write), uniform vs Zipfian key distributions."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.oltp import tpcc
from repro.oltp.store import BlitzStore, LRUFastPath


def run(n_rows: int = 3000, n_ops: int = 2000,
        capacities=(0, 64, 256, 1024)) -> List[Dict]:
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    out = []
    for dist in ("uniform", "zipf"):
        rng = np.random.default_rng(3)
        if dist == "uniform":
            keys = rng.integers(0, n_rows, n_ops)
        else:
            keys = (rng.zipf(1.2, size=4 * n_ops) - 1)
            keys = keys[keys < n_rows][:n_ops].astype(int)
        for cap in capacities:
            store = BlitzStore(schema, rows[:n_rows // 2])
            for r in rows:
                store.insert(r)
            fp = LRUFastPath(store, cap) if cap else None
            t0 = time.perf_counter()
            for i in keys:
                if fp is not None:
                    fp.read_modify_write(int(i),
                                         lambda r: r.update(c_balance=0.0))
                else:
                    r = store.get(int(i))
                    r["c_balance"] = 0.0
                    # re-compress (write path without cache)
                    store.codec.compress_block([r])
            dt = (time.perf_counter() - t0) / len(keys)
            out.append({"dist": dist, "capacity": cap,
                        "op_us": round(1e6 * dt, 1),
                        "hit_rate": round(fp.hits / max(fp.hits + fp.misses, 1), 3)
                        if fp else 0.0})
    return out


def main(quick: bool = True, smoke: bool = False):
    if smoke:
        rows = run(n_rows=400, n_ops=100)
    else:
        rows = run(n_rows=1200 if quick else 5000,
                   n_ops=600 if quick else 5000)
    for r in rows:
        print(f"fig13_{r['dist']}_cap{r['capacity']},{r['op_us']},"
              f"hit_rate={r['hit_rate']}")
    return rows


if __name__ == "__main__":
    main(quick=False)
