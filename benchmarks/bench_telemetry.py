"""Telemetry overhead gate (ISSUE 8): instrumentation must be ~free.

Drives the same seeded TPC-C mix through two blitzcrank-backed
databases — one with telemetry enabled, one disabled — and reports the
throughput ratio.  Shared runners drift by ±10% on ~30 s timescales,
far above the instrumentation cost, so the design cancels drift rather
than averaging over it: both databases are built up front, the mix is
then run in small chunks *interleaved between the two arms* (identical
seeded op sequences), so every enabled/disabled comparison happens
inside a ~2 s window where drift is effectively constant.  Which db
object runs enabled and which runs first both rotate per chunk — the
modes are bit-identical, so heap-layout luck between the two objects
and ordering bias both flip sign across chunks and cancel in log
space.  The reported ratio is the geometric mean of per-chunk ratios
after symmetrically trimming the extremes, so a single contended chunk
(observed excursions reach ±25% on shared runners) cannot sink the
estimate.  The acceptance bound —
enabled >= 0.97x disabled — is what lets every hot path stay
instrumented by default; a counter bump or clock read that creeps into
an inner loop shows up here as a failed gate, not as a mystery
slowdown three PRs later.

Also microbenchmarks the primitives (counter add, histogram observe, in
both modes) and checks the two modes leave **bit-identical** database
contents: recording must never change behaviour.

Emits ``BENCH_telemetry.json`` and ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import statistics
import time
from typing import Dict, List

from benchmarks.artifact import write_bench_json
from repro import telemetry
from repro.oltp import tpcc

ACCEPT_RATIO = 0.97


def _primitive_ns(n: int = 200_000) -> Dict[str, float]:
    """ns/op for the metric primitives, enabled and disabled."""
    c = telemetry.counter("repro.bench.telemetry.counter")
    h = telemetry.histogram("repro.bench.telemetry.hist")
    out: Dict[str, float] = {}
    for mode in ("enabled", "disabled"):
        prev = telemetry.set_enabled(mode == "enabled")
        try:
            t0 = time.perf_counter_ns()
            for _ in range(n):
                c.add(1)
            out[f"counter_add_{mode}_ns"] = round(
                (time.perf_counter_ns() - t0) / n, 2
            )
            t0 = time.perf_counter_ns()
            for _ in range(n):
                h.observe(1234)
            out[f"hist_observe_{mode}_ns"] = round(
                (time.perf_counter_ns() - t0) / n, 2
            )
        finally:
            telemetry.set_enabled(prev)
    return out


def _build(population, n_shards: int, enabled: bool):
    prev = telemetry.set_enabled(enabled)
    try:
        db, _ = tpcc.build_tpcc_database(backend="blitzcrank",
                                         n_shards=n_shards,
                                         population=population)
        return db
    finally:
        telemetry.set_enabled(prev)


def _probe(db) -> tuple:
    """Determinism probe: a fixed slice of post-mix state."""
    customer = db["customer"]
    keys = sorted(k for k, _ in customer.scan())[:200]
    return (customer.get_many(keys), db.stats()["n_live"])


def _chunk(db, n_ops: int, seed: int, enabled: bool) -> float:
    """Run one mix chunk with telemetry forced, return elapsed seconds."""
    prev = telemetry.set_enabled(enabled)
    try:
        t0 = time.perf_counter()
        tpcc.run_tpcc_mix(db, n_ops, seed=seed)
        return time.perf_counter() - t0
    finally:
        telemetry.set_enabled(prev)


def run(n_warehouses: int = 2, districts_per_wh: int = 10,
        customers_per_district: int = 150, n_items: int = 1000,
        orders_per_district: int = 50, n_shards: int = 2,
        n_ops: int = 6000, chunks: int = 24, seed: int = 13) -> Dict:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)

    # Two identical databases; the warmup chunks also pay the
    # process-wide one-offs (jit compiles, codec-fit caches).  Because
    # the modes are bit-identical, *which* db runs enabled can rotate
    # per chunk — heap-layout differences between the two objects (they
    # were allocated at different points in process history) then
    # cancel in the geometric mean instead of masquerading as overhead.
    db_a = _build(population, n_shards, True)
    db_b = _build(population, n_shards, False)
    warm = max(50, n_ops // chunks // 2)
    _chunk(db_a, warm, seed - 1, True)
    _chunk(db_b, warm, seed - 1, False)

    hist_base = telemetry.REGISTRY.hist_seconds()
    chunk_ops = max(20, n_ops // chunks)
    chunk_ratios: List[float] = []
    t_on_total = t_off_total = 0.0
    for i in range(chunks):
        cs = seed + 1 + i       # same op sequence hits both arms
        a_enabled = i % 2 == 0  # rotate mode across db objects
        a_first = (i // 2) % 2 == 0  # rotate run order independently
        seq = [(db_a, a_enabled), (db_b, not a_enabled)]
        if not a_first:
            seq.reverse()
        times = {}
        for db, e in seq:
            times[e] = _chunk(db, chunk_ops, cs, e)
        t_on_total += times[True]
        t_off_total += times[False]
        chunk_ratios.append(times[False] / times[True])  # tps_on / tps_off

    # symmetric trim: drop the k most extreme ratios per side so one
    # contended chunk can't move the gate (k scales with sample count)
    trim = max(0, len(chunk_ratios) // 8)
    kept = sorted(chunk_ratios)[trim: len(chunk_ratios) - trim]
    ratio = statistics.geometric_mean(kept)
    med_on = chunks * chunk_ops / t_on_total
    med_off = chunks * chunk_ops / t_off_total
    # the enabled arm's fold doubles as a sanity view of what the
    # instrumentation attributes its own mix to
    phases = telemetry.phase_breakdown(t_on_total, since=hist_base)
    identical = _probe(db_a) == _probe(db_b)
    report = {
        "scale": {"n_warehouses": n_warehouses,
                  "districts_per_wh": districts_per_wh,
                  "customers_per_district": customers_per_district,
                  "n_items": n_items,
                  "orders_per_district": orders_per_district,
                  "n_shards": n_shards, "n_ops": n_ops,
                  "chunks": chunks},
        "enabled_tps": round(med_on, 1),
        "disabled_tps": round(med_off, 1),
        "chunk_ratios": [round(r, 4) for r in chunk_ratios],
        "primitives": _primitive_ns(),
        "phases": phases,
        "acceptance": {
            "bound": ACCEPT_RATIO,
            "overhead_ratio": round(ratio, 4),
            "identical": identical,
            "pass": bool(ratio >= ACCEPT_RATIO and identical),
        },
    }
    return report


def main(quick: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        report = run(n_warehouses=2, districts_per_wh=2,
                     customers_per_district=30, n_items=100,
                     orders_per_district=12, n_shards=2,
                     n_ops=80, chunks=2)
    elif quick:
        report = run(n_ops=1200, chunks=6)
    else:
        report = run()
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("telemetry", report, schema="tpcc_multi")
    acc = report["acceptance"]
    us_on = 1e6 / report["enabled_tps"]
    us_off = 1e6 / report["disabled_tps"]
    prim = report["primitives"]
    print(f"telemetry_enabled,{us_on:.1f},tps={report['enabled_tps']}")
    print(f"telemetry_disabled,{us_off:.1f},tps={report['disabled_tps']}")
    print(f"telemetry_counter_add,{prim['counter_add_enabled_ns'] / 1e3},"
          f"disabled_ns={prim['counter_add_disabled_ns']}")
    print(f"telemetry_acceptance,{acc['overhead_ratio']},"
          f"bound={acc['bound']};identical={acc['identical']};"
          f"pass={acc['pass']};artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
