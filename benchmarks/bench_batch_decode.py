"""Microbenchmark: scalar vs numpy-batch vs Pallas(interpret) point-get decode.

Times random point gets of 256+ tuples on a 6-column mixed schema
(int id, 2 categoricals, int, float, format-fixed string) through the three
decode paths of the compiled fast path (DESIGN.md §2):

* ``scalar`` — the per-tuple ``decompress_block`` Python loop (paper CPU path)
* ``numpy``  — ``decode_select`` over the CSR arena (vectorized Algorithm 5)
* ``pallas`` — the ``delayed_decode`` kernel in interpret mode on CPU

Decoded rows are checked identical across all paths.  Emits the
``BENCH_batch_decode.json`` artifact (repo root) so future PRs have a
trajectory to beat, and prints ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import ColumnSpec, CompressedTable, TableCodec

SCHEMA = [
    ColumnSpec("id", "int"),
    ColumnSpec("city", "cat"),
    ColumnSpec("grade", "cat"),
    ColumnSpec("qty", "int"),
    ColumnSpec("amount", "float", precision=0.01),
    ColumnSpec("info", "str"),
]

_CITIES = [f"City{i:02d}" for i in range(40)]
_GRADES = list("ABCDEF")
_WORDS = ["alpha", "beta", "gamma", "delta", "epsilon", "zeta"]


def gen_rows(n: int, seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    return [{
        "id": int(i),
        "city": _CITIES[int(rng.zipf(1.3)) % len(_CITIES)],
        "grade": _GRADES[int(rng.integers(0, len(_GRADES)))],
        "qty": int(rng.integers(1, 100)),
        "amount": float(np.round(rng.uniform(0.01, 9999.99), 2)),
        "info": f"{_WORDS[int(rng.integers(0, 6))]}-"
                f"{_WORDS[int(rng.integers(0, 6))]}"
                f"#{int(rng.integers(0, 99)):02d}",
    } for i in range(n)]


def _best(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(n_rows: int = 8192, batches=(256, 2048), reps: int = 5) -> Dict:
    rows = gen_rows(n_rows)
    codec = TableCodec.fit(rows, SCHEMA, sample=4096)
    plan = codec.compile()
    assert plan is not None, codec.plan_fallback_reason
    table = CompressedTable(codec)
    t0 = time.perf_counter()
    table.extend(rows)
    table.flush()
    insert_us = 1e6 * (time.perf_counter() - t0) / n_rows
    fast_frac = float(table.block_fast.mean())

    rng = np.random.default_rng(42)
    results = []
    for R in batches:
        idx = rng.integers(0, n_rows, R)
        exp = [table.get(int(i)) for i in idx]
        got_np = table.get_many(idx, backend="numpy")
        got_pl = table.get_many(idx, backend="pallas")  # also jit warmup
        identical = (got_np == exp) and (got_pl == exp)
        t_scalar = _best(lambda: [table.get(int(i)) for i in idx],
                         max(2, reps // 2)) / R
        t_numpy = _best(lambda: table.get_many(idx, backend="numpy"),
                        reps) / R
        t_pallas = _best(lambda: table.get_many(idx, backend="pallas"),
                         max(2, reps // 2)) / R
        results.append({
            "R": int(R),
            "scalar_us": round(1e6 * t_scalar, 2),
            "numpy_us": round(1e6 * t_numpy, 2),
            "pallas_us": round(1e6 * t_pallas, 2),
            "speedup_numpy": round(t_scalar / t_numpy, 1),
            "speedup_pallas": round(t_scalar / t_pallas, 1),
            "identical": bool(identical),
        })
    return {
        "schema": [f"{c.name}:{c.kind}" for c in SCHEMA],
        "n_rows": int(n_rows),
        "slots": int(plan.S),
        "pallas_ok": bool(plan.pallas_ok),
        "fast_fraction": round(fast_frac, 4),
        "bulk_insert_us": round(insert_us, 2),
        "batches": results,
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    from benchmarks.artifact import write_bench_json
    if smoke:
        report = run(n_rows=1024, batches=(64, 256), reps=2)
    else:
        report = run(n_rows=8192 if quick else 32768,
                     reps=5 if quick else 9)
    artifact = write_bench_json("batch_decode", report,
                                schema="mixed6 (id/city/grade/qty/amount/info)")
    for b in report["batches"]:
        print(f"batch_decode_R{b['R']}_scalar,{b['scalar_us']},baseline")
        print(f"batch_decode_R{b['R']}_numpy,{b['numpy_us']},"
              f"speedup={b['speedup_numpy']};identical={b['identical']}")
        print(f"batch_decode_R{b['R']}_pallas,{b['pallas_us']},"
              f"speedup={b['speedup_pallas']};interpret=True")
    print(f"batch_decode_fast_fraction,{report['fast_fraction']},"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
