"""Durability overhead + recovery speed (DESIGN.md §7): the WAL tax and
how fast a crashed database comes back.

Two questions, two measurements:

* **WAL tax** — the same multi-table TPC-C mix is driven through two
  identically-loaded blitzcrank databases, one plain and one durable
  (per-table redo WAL, ``fsync_every=1`` so every batch verb group-commits
  before it applies).  The acceptance gate is the throughput ratio:
  logging + fsync must cost less than 30% (``wal_on/wal_off >= 0.7``).
  The batched verb design is what makes this cheap — one framed record
  and one fsync cover a whole batch, not a row.  The durable arm is then
  closed (final checkpoint) and reopened, and sampled reads from every
  table must come back bit-identical — the checkpoint-recovery path at
  mix scale.

* **Replay speed** — a single-table workload appends a log of
  ``replay_ops`` row operations (insert/update batches of 256) under the
  production checkpoint cadence, the tables are closed without a final
  snapshot, and ``Database.open`` is timed recovering the lot —
  checkpoint load plus replay of the post-checkpoint tail (the WAL keeps
  its full history for corruption repair; the cadence is what bounds the
  redo).  Acceptance: a database with a 50k-op log recovers in under
  5 s, and the recovered reads are bit-identical to reads taken just
  before the "crash".

Emits ``BENCH_recovery.json`` and ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Any, Dict, List

import numpy as np

from benchmarks.artifact import write_bench_json
from repro.db import Database, TableSchema
from repro.durability.config import DurabilityConfig
from repro.oltp import tpcc

ACCEPT_WAL_RATIO = 0.7   # durable mix throughput vs in-memory mix
ACCEPT_REPLAY_S = 5.0    # full replay of a 50k-op log
ACCEPT_CKPT_SAVED = 0.5   # ckpt bytes saved / spilled payload bytes
REPLAY_BATCH = 256


def _load_mix_db(population, root=None, fsync_every: int = 1) -> Database:
    """One loaded TPC-C database; durable (WAL per table) when ``root``
    is given.  Auto-checkpoints are off so the durable arm's mix time is
    pure logging overhead — the close-time checkpoint is timed apart."""
    durability = None
    if root is not None:
        durability = DurabilityConfig(root=root, fsync_every=fsync_every,
                                      checkpoint_every_ops=0,
                                      checkpoint_on_maintenance=False)
    db = Database(backend="blitzcrank", n_shards=1, durability=durability)
    for name, schema in tpcc.TPCC_TABLES.items():
        rows = population[name]
        table = db.create_table(schema, sample_rows=rows)
        table.insert_many(rows)
    return db


def _sample_reads(db: Database, per_table: int, seed: int) -> Dict[str, Any]:
    """Deterministic sampled numpy reads from every table — captured
    before a close/reopen and compared after, so recovery is judged on
    bit-identical decoded rows, not row counts."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {}
    for name in tpcc.TPCC_TABLES:
        keys = sorted(k for k, _ in db[name].scan())
        if not keys:
            out[name] = ([], [])
            continue
        picks = sorted({int(i) for i in
                        rng.integers(0, len(keys), per_table)})
        sample = [keys[i] for i in picks]
        out[name] = (sample, db[name].get_many(sample, backend="numpy"))
    return out


def _mix_arm(population, n_ops: int, seed: int, root=None,
             fsync_every: int = 1) -> Dict[str, Any]:
    db = _load_mix_db(population, root=root, fsync_every=fsync_every)
    t0 = time.perf_counter()
    counts = tpcc.run_tpcc_mix(db, n_ops, seed=seed)
    mix_s = time.perf_counter() - t0
    arm: Dict[str, Any] = {
        "durable": root is not None,
        "mix_s": round(mix_s, 3),
        "rate_tps": round(n_ops / max(mix_s, 1e-9), 1),
        "counts": counts,
    }
    if root is None:
        db.close()
        return arm
    arm["wal_bytes"] = sum(
        os.path.getsize(os.path.join(root, f))
        for f in os.listdir(root) if f.endswith(".wal"))
    want = _sample_reads(db, per_table=128, seed=seed)
    t0 = time.perf_counter()
    db.close()  # final checkpoint: snapshot + per-table wal_lsn
    arm["checkpoint_s"] = round(time.perf_counter() - t0, 3)
    arm["checkpoint_bytes"] = os.path.getsize(
        os.path.join(root, "checkpoint.bin"))
    t0 = time.perf_counter()
    rdb = Database.open(root)
    arm["checkpoint_recover_s"] = round(time.perf_counter() - t0, 3)
    arm["recovered_identical"] = all(
        rdb[name].get_many(sample, backend="numpy") == rows
        for name, (sample, rows) in want.items())
    for t in rdb:
        t.close()
    return arm


def _replay_arm(n_ops: int, batch: int, root: str,
                ckpt_every: int = 12800) -> Dict[str, Any]:
    """Recover a database whose WAL holds ``n_ops`` logged row operations.

    The log is appended with the production checkpoint cadence
    (``checkpoint_every_ops``): the WAL itself is never truncated — its
    full history is what single-block corruption repair replays — but
    ``Database.open`` only re-applies the tail past the last checkpoint,
    which is exactly how the subsystem bounds recovery time.  The timed
    quantity is the whole reopen: checkpoint load (pickled codecs +
    embedded spill payloads) plus the tail replay, and ``tail_ops``
    records how much redo work that was."""
    # Base population scales with the log so smoke stays snappy; the
    # fit sample spans the whole key range (the TPC-C id domain is
    # known up front), so fresh inserts aren't all escape-coded.
    n_pop = min(8192, max(1024, n_ops))
    schema = TableSchema("customer", tpcc.TABLES["customer"][0], "c_id")
    n_fresh = (n_ops // (2 * batch) + 1) * batch
    rows = tpcc.gen_customer(n_pop + n_fresh, seed=3)
    cfg = DurabilityConfig(root=root, fsync_every=8,
                           checkpoint_every_ops=ckpt_every,
                           checkpoint_on_maintenance=False)
    db = Database(backend="blitzcrank", durability=cfg)
    stride = max(1, len(rows) // n_pop)
    table = db.create_table(schema, sample_rows=rows[::stride][:n_pop])
    table.insert_many(rows[:n_pop])
    # Load-time checkpoint, as a real loader takes one: from here on the
    # cadence keeps the replayable tail bounded by ckpt_every.
    db.checkpoint()

    applied, step, fresh_at = 0, 0, n_pop
    t0 = time.perf_counter()
    while applied < n_ops:
        k = min(batch, n_ops - applied)
        if step % 2 == 0 and fresh_at + k <= len(rows):
            table.insert_many(rows[fresh_at:fresh_at + k])
            fresh_at += k
        else:
            lo = (step * 37) % (n_pop - k)
            upd = [dict(rows[i],
                        c_balance=rows[(i + step) % n_pop]["c_balance"])
                   for i in range(lo, lo + k)]
            table.update_many([r["c_id"] for r in upd], upd)
        applied += k
        step += 1
    log_s = time.perf_counter() - t0

    sample = list(range(0, fresh_at, 97))
    want = table.get_many(sample, backend="numpy")
    log_bytes = os.path.getsize(os.path.join(root, "customer.wal"))
    tail_ops = db._ops_since_ckpt  # redo work recovery must replay
    for t in db:  # close files WITHOUT a fresh checkpoint: the crash
        t.close()

    t0 = time.perf_counter()
    rdb = Database.open(root)
    replay_s = time.perf_counter() - t0
    got = rdb["customer"].get_many(sample, backend="numpy")
    n_live = rdb["customer"].n_live
    for t in rdb:
        t.close()
    return {
        "ops": n_ops,
        "batch": batch,
        "ckpt_every": ckpt_every,
        "tail_ops": tail_ops,
        "log_s": round(log_s, 3),
        "log_bytes": log_bytes,
        "replay_s": round(replay_s, 3),
        "tail_ops_per_s": round(tail_ops / max(replay_s, 1e-9), 1),
        "replay_identical": got == want and n_live == fresh_at,
    }


def _ckpt_shrink_arm(root: str, n_rows: int, budget_frac: float = 0.25,
                     seed: int = 5) -> Dict[str, Any]:
    """Extent-mode checkpoint size win (DESIGN.md §8 satellite).

    With a *named, durable* spill file the snapshot references each
    spilled block by ``(offset, length)`` into that file instead of
    embedding its payload; anonymous spill files (gone after a crash)
    keep the embedded fallback.  Measured as the pickled-snapshot size
    ratio on the same cold-tier table, then proven live: the durable
    database checkpoints in extent mode, reopens, and sampled reads come
    back bit-identical."""
    import pickle

    # orderline: numeric-heavy, so spilled code payloads (not model
    # pickles) dominate the snapshot and the extent win is visible
    rows = tpcc.gen_orderline(n_rows, seed=seed)
    schema = TableSchema("orderline", tpcc.TABLES["orderline"][0],
                         ("ol_o_id", "ol_number"))
    key = schema.key_of

    # probe: fully-resident store size fixes the byte budget
    probe = Database(backend="blitzcrank")
    t = probe.create_table(schema, sample_rows=rows)
    t.insert_many(rows)
    budget = max(4096, int(budget_frac * t.stats()["store_bytes"]))
    probe.close()

    cfg = DurabilityConfig(root=root, fsync_every=8,
                           checkpoint_every_ops=0,
                           checkpoint_on_maintenance=False)
    db = Database(backend="blitzcrank", durability=cfg)
    table = db.create_table(
        schema, sample_rows=rows, memory_budget=budget,
        store_kwargs={"spill_path": os.path.join(root, "orderline.spill")})
    table.insert_many(rows)
    upd = [dict(r, ol_amount=r["ol_amount"] + 1.0) for r in rows[::7]]
    table.update_many([key(r) for r in upd], upd)
    res = table.stats()["residency"]

    tab = table.shards[0].table
    sz_embed = len(pickle.dumps(tab.snapshot_state(embed_spilled=True)))
    sz_extent = len(pickle.dumps(tab.snapshot_state()))

    sample = [key(r) for r in rows[::13]]
    want = table.get_many(sample, backend="numpy")
    db.close()  # extent-mode checkpoint (named spill file survives)
    ckpt_bytes = os.path.getsize(os.path.join(root, "checkpoint.bin"))
    rdb = Database.open(root)
    got = rdb["orderline"].get_many(sample, backend="numpy")
    restored = rdb["orderline"].stats()["residency"]
    for t in rdb:
        t.close()
    return {
        "n_rows": n_rows,
        "budget_bytes": budget,
        "spilled_bytes": res["spilled_bytes"],
        "snapshot_embed_bytes": sz_embed,
        "snapshot_extent_bytes": sz_extent,
        "shrink_ratio": round(sz_embed / max(1, sz_extent), 3),
        # the feature's own yardstick: how much of the spilled payload
        # bytes the extent references kept OUT of the checkpoint
        "saved_frac": round((sz_embed - sz_extent)
                            / max(1, res["spilled_bytes"]), 3),
        "checkpoint_bytes": ckpt_bytes,
        "reopen_identical": bool(got == want),
        "reopen_spilled_bytes": restored["spilled_bytes"],
    }


def run(n_ops: int = 12000, replay_ops: int = 50000,
        replay_batch: int = REPLAY_BATCH, seed: int = 7,
        fsync_every: int = 1, ckpt_rows: int = 20000,
        **gen_kwargs) -> Dict[str, Any]:
    population = tpcc.generate_tpcc(seed=seed, **gen_kwargs)
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        arms = {
            "wal_off": _mix_arm(population, n_ops, seed),
            "wal_on": _mix_arm(population, n_ops, seed,
                               root=os.path.join(tmp, "mix"),
                               fsync_every=fsync_every),
        }
        replay = _replay_arm(replay_ops, replay_batch,
                             os.path.join(tmp, "replay"))
        shrink_root = os.path.join(tmp, "shrink")
        os.makedirs(shrink_root, exist_ok=True)
        ckpt_shrink = _ckpt_shrink_arm(shrink_root, ckpt_rows)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    ratio = arms["wal_on"]["rate_tps"] / max(arms["wal_off"]["rate_tps"],
                                             1e-9)
    identical = (arms["wal_on"]["recovered_identical"]
                 and replay["replay_identical"]
                 and ckpt_shrink["reopen_identical"])
    return {
        "scale": {"n_ops": n_ops, "replay_ops": replay_ops,
                  "replay_batch": replay_batch, "ckpt_rows": ckpt_rows,
                  "fsync_every": fsync_every, **gen_kwargs},
        "arms": arms,
        "replay": replay,
        "ckpt_shrink": ckpt_shrink,
        "acceptance": {
            "wal_ratio_bound": ACCEPT_WAL_RATIO,
            "wal_on_ratio": round(ratio, 3),
            "replay_bound_s": ACCEPT_REPLAY_S,
            "replay_s": replay["replay_s"],
            "ckpt_saved_bound": ACCEPT_CKPT_SAVED,
            "ckpt_saved_frac": ckpt_shrink["saved_frac"],
            "identical": identical,
            "pass": bool(ratio >= ACCEPT_WAL_RATIO
                         and replay["replay_s"] <= ACCEPT_REPLAY_S
                         and ckpt_shrink["saved_frac"] >= ACCEPT_CKPT_SAVED
                         and identical),
        },
    }


def main(quick: bool = True, smoke: bool = False) -> Dict[str, Any]:
    # Smoke exercises log/replay plumbing at toy sizes (the 5 s replay
    # bound only means anything for a 50k-op log); quick is CI-sized;
    # full is the acceptance scale.
    if smoke:
        report = run(n_ops=240, replay_ops=1024, replay_batch=128,
                     ckpt_rows=2000, n_warehouses=1, districts_per_wh=2,
                     customers_per_district=20, n_items=60,
                     orders_per_district=4)
    elif quick:
        report = run(n_ops=4000, replay_ops=10000, ckpt_rows=8000,
                     customers_per_district=40, n_items=300)
    else:
        report = run()
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("recovery", report, schema="tpcc_multi")
    for name, arm in report["arms"].items():
        extra = (f";wal_bytes={arm['wal_bytes']}"
                 f";ckpt_recover_s={arm['checkpoint_recover_s']}"
                 if arm["durable"] else "")
        print(f"recovery_{name},{round(1e6 / arm['rate_tps'], 1)},"
              f"rate_tps={arm['rate_tps']}{extra}")
    rep = report["replay"]
    print(f"recovery_replay,{round(1e6 * rep['replay_s'] / rep['ops'], 2)},"
          f"replay_s={rep['replay_s']};ops={rep['ops']};"
          f"tail_ops={rep['tail_ops']};log_bytes={rep['log_bytes']}")
    shr = report["ckpt_shrink"]
    print(f"recovery_ckpt_shrink,{shr['snapshot_extent_bytes']},"
          f"saved_frac={shr['saved_frac']};"
          f"shrink_ratio={shr['shrink_ratio']};"
          f"embed_bytes={shr['snapshot_embed_bytes']};"
          f"identical={shr['reopen_identical']}")
    acc = report["acceptance"]
    print(f"recovery_acceptance,{acc['wal_on_ratio']},"
          f"bound={acc['wal_ratio_bound']};replay_s={acc['replay_s']};"
          f"replay_bound_s={acc['replay_bound_s']};"
          f"ckpt_saved={acc['ckpt_saved_frac']};"
          f"identical={acc['identical']};pass={acc['pass']};"
          f"artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
