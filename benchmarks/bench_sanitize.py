"""Sanitizer overhead note (ISSUE 9): sanitize-off must be free.

Every boundary check site guards on ``sanitize.ENABLED`` before doing
any work, so the default (off) hot path pays one module-attribute load
and a falsy branch per boundary.  This bench pins that claim two ways:

1. **Primitive**: ns/call for ``CompressedTable.sanitize_boundary`` in
   both modes on a spilled, zone-mapped table — the off cost is the
   guard alone, the on cost is the full vectorized invariant sweep.
   The gate is on the *off* number: a boundary guard that grows real
   work shows up here, not as a mystery OLTP slowdown later.
2. **Mix**: the seeded TPC-C mix run in interleaved chunks with the
   sanitizer toggled per chunk (same drift-cancelling design as
   ``bench_telemetry``).  The on/off throughput ratio is reported as
   the *cost of turning it on* — informational, since CI runs tier-1
   both ways and correctness there is the point, not speed.

Emits ``BENCH_sanitize.json`` and ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import statistics
import time
from typing import Dict, List

from benchmarks.artifact import write_bench_json
from repro import sanitize
from repro.core import TableCodec
from repro.core.blitzcrank import CompressedTable
from repro.oltp import tpcc

# The off-path guard must stay under a microsecond per boundary; the
# measured cost is a ~100 ns Python call + attribute load.
OFF_NS_BOUND = 1_000.0


def _primitive_ns(n: int = 20_000) -> Dict[str, float]:
    """ns/call for a full boundary sweep, sanitize on and off."""
    schema, gen = tpcc.TABLES["orderline"]
    rows = gen(1500, seed=7)
    codec = TableCodec.fit(rows[:256], schema)
    t = CompressedTable(codec, memory_budget=1 << 13)
    t.extend(rows)
    out: Dict[str, float] = {}
    for mode in ("enabled", "disabled"):
        with sanitize.override(mode == "enabled"):
            t0 = time.perf_counter_ns()
            for _ in range(n):
                t.sanitize_boundary("bench")
            out[f"boundary_{mode}_ns"] = round(
                (time.perf_counter_ns() - t0) / n, 2
            )
    return out


def _build(population, n_shards: int):
    db, _ = tpcc.build_tpcc_database(backend="blitzcrank",
                                     n_shards=n_shards,
                                     population=population)
    return db


def _chunk(db, n_ops: int, seed: int, enabled: bool) -> float:
    with sanitize.override(enabled):
        t0 = time.perf_counter()
        tpcc.run_tpcc_mix(db, n_ops, seed=seed)
        return time.perf_counter() - t0


def run(n_warehouses: int = 2, districts_per_wh: int = 10,
        customers_per_district: int = 150, n_items: int = 1000,
        orders_per_district: int = 50, n_shards: int = 2,
        n_ops: int = 6000, chunks: int = 24, seed: int = 13) -> Dict:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)

    db_a = _build(population, n_shards)
    db_b = _build(population, n_shards)
    warm = max(50, n_ops // chunks // 2)
    _chunk(db_a, warm, seed - 1, True)
    _chunk(db_b, warm, seed - 1, False)

    chunk_ops = max(20, n_ops // chunks)
    chunk_ratios: List[float] = []
    t_on_total = t_off_total = 0.0
    for i in range(chunks):
        cs = seed + 1 + i
        a_enabled = i % 2 == 0
        a_first = (i // 2) % 2 == 0
        seq = [(db_a, a_enabled), (db_b, not a_enabled)]
        if not a_first:
            seq.reverse()
        times = {}
        for db, e in seq:
            times[e] = _chunk(db, chunk_ops, cs, e)
        t_on_total += times[True]
        t_off_total += times[False]
        chunk_ratios.append(times[False] / times[True])  # tps_on / tps_off

    trim = max(0, len(chunk_ratios) // 8)
    kept = sorted(chunk_ratios)[trim: len(chunk_ratios) - trim]
    on_cost_ratio = statistics.geometric_mean(kept)
    prim = _primitive_ns()
    report = {
        "scale": {"n_warehouses": n_warehouses,
                  "districts_per_wh": districts_per_wh,
                  "customers_per_district": customers_per_district,
                  "n_items": n_items,
                  "orders_per_district": orders_per_district,
                  "n_shards": n_shards, "n_ops": n_ops,
                  "chunks": chunks},
        "sanitize_on_tps": round(chunks * chunk_ops / t_on_total, 1),
        "sanitize_off_tps": round(chunks * chunk_ops / t_off_total, 1),
        "chunk_ratios": [round(r, 4) for r in chunk_ratios],
        "primitives": prim,
        "acceptance": {
            "off_ns_bound": OFF_NS_BOUND,
            "boundary_disabled_ns": prim["boundary_disabled_ns"],
            "on_cost_ratio": round(on_cost_ratio, 4),
            "pass": bool(prim["boundary_disabled_ns"] <= OFF_NS_BOUND),
        },
    }
    return report


def main(quick: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        report = run(n_warehouses=2, districts_per_wh=2,
                     customers_per_district=30, n_items=100,
                     orders_per_district=12, n_shards=2,
                     n_ops=80, chunks=2)
    elif quick:
        report = run(n_ops=1200, chunks=6)
    else:
        report = run()
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("sanitize", report, schema="tpcc_multi")
    acc = report["acceptance"]
    prim = report["primitives"]
    us_on = 1e6 / report["sanitize_on_tps"]
    us_off = 1e6 / report["sanitize_off_tps"]
    print(f"sanitize_on,{us_on:.1f},tps={report['sanitize_on_tps']}")
    print(f"sanitize_off,{us_off:.1f},tps={report['sanitize_off_tps']}")
    print(f"sanitize_boundary,{prim['boundary_enabled_ns'] / 1e3},"
          f"disabled_ns={prim['boundary_disabled_ns']}")
    print(f"sanitize_acceptance,{acc['on_cost_ratio']},"
          f"off_ns={acc['boundary_disabled_ns']};"
          f"bound_ns={acc['off_ns_bound']};pass={acc['pass']}")
    return report


if __name__ == "__main__":
    main(quick=False)
