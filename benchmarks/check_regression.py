"""CI benchmark-regression gate (ISSUE 5 satellite).

Two layers of protection, both cheap enough to run on every PR:

1. **Committed artifacts** — every ``BENCH_*.json`` at the repo root must
   carry its ``git_sha``/``schema_name`` stamps, its recorded
   ``acceptance.pass`` must be true, and the headline numbers must still
   clear their bounds (factor lower bounds, latency upper bounds with
   slack).  A PR that regresses a benchmark and re-runs it cannot land a
   failing artifact quietly; a PR that edits an artifact by hand trips
   the same checks.

2. **Fresh smoke run** — the ``name,us_per_call,derived`` CSV emitted by
   ``python -m benchmarks.run --smoke`` is checked against bounds that
   are meaningful at toy sizes: every bench must have completed (its
   ``bench_*_wall`` line says ``ok``), correctness booleans
   (``identical=True``) must hold, compression factors must clear loose
   floors, and smoke latencies must stay within a generous slack of the
   committed full-scale numbers — toy sizes are overhead-dominated, so
   the slack catches order-of-magnitude rot, not noise.

Usage (CI wires this right after the smoke step)::

    python -m benchmarks.run --smoke | tee smoke.csv
    python -m benchmarks.check_regression --csv smoke.csv

Exits non-zero listing every violated bound.  ``--skip-smoke`` checks
only the committed artifacts (useful pre-push).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent

# Every bench registered in benchmarks/run.py must complete in smoke mode.
REQUIRED_BENCHES = [
    "compression",
    "batch_decode",
    "update_merge",
    "adaptive_refit",
    "db_tpcc",
    "exec_engine",
    "out_of_core",
    "recovery",
    "htap",
    "telemetry",
    "sampling",
    "entropy",
    "granularity",
    "fastpath",
    "archive",
    "framework",
    "roofline",
]

# Correctness booleans that hold at any scale: decode paths must stay
# bit-identical to their references even at smoke sizes.
SMOKE_IDENTICAL = [
    "batch_decode_R64_numpy",
    "batch_decode_R256_numpy",
    "update_merge_merge",
    "adaptive_refit_refit_on",
    "db_tpcc_acceptance",
    # prepared batched replay must match the scalar verb loop bit-for-bit
    "exec_engine_acceptance",
    "out_of_core_acceptance",
    "recovery_acceptance",
    "htap_acceptance",
    # enabled vs disabled telemetry must leave bit-identical db contents
    "telemetry_acceptance",
]

# (csv name, derived key, lower bound) — loose floors for smoke scale,
# roughly half of the observed toy-size values, far below full scale.
SMOKE_DERIVED_MIN: List[Tuple[str, str, float]] = [
    ("fig9_customer_blitzcrank", "factor", 1.5),
    ("fig9_stock_blitzcrank", "factor", 1.5),
    ("fig9_orderline_blitzcrank", "factor", 1.2),
    ("db_tpcc_blitzcrank", "factor", 1.0),
    # prepared replay beats the scalar loop even at toy sizes, and the
    # plan cache must hit once each bucket is lowered
    ("exec_engine_get_prepared", "speedup", 2.0),
    ("exec_engine_get_prepared", "hit_rate", 0.9),
    ("batch_decode_R64_numpy", "speedup", 1.5),
    ("batch_decode_R256_numpy", "speedup", 2.0),
]

# Smoke latency vs the committed full-scale artifact, with slack: smoke
# sizes are overhead-dominated, so the ceiling is a large multiple — it
# fires on order-of-magnitude regressions (a broken fast path), never on
# noise.  (csv name, artifact, json path to the committed value, slack).
SMOKE_LATENCY_VS_ARTIFACT: List[Tuple[str, str, List[str], float]] = [
    (
        "db_tpcc_blitzcrank",
        "BENCH_db_tpcc.json",
        ["arms", "blitzcrank", "point_get_us"],
        25.0,
    ),
    (
        "out_of_core_blitzcrank_capped",
        "BENCH_out_of_core.json",
        # the capped arm's own measured rate: a cold-tier slowdown moves
        # this metric even when the uncapped reference is unchanged
        ["arms", "blitzcrank_capped", "median_rate_tps"],
        # us_per_call is 1e6/rate, so the ceiling is slack/rate.
        10.0,
    ),
]

# Committed-artifact invariants: (artifact, json path, kind, bound).
# "min" = factor lower bound, "max" = latency upper bound (with slack
# already folded into the bound), "true" = boolean that must hold.
ARTIFACT_RULES: List[Tuple[str, List[str], str, Optional[float]]] = [
    ("BENCH_db_tpcc.json", ["acceptance", "pass"], "true", None),
    ("BENCH_db_tpcc.json", ["acceptance", "factor_vs_silo"], "min", 2.0),
    ("BENCH_db_tpcc.json", ["arms", "blitzcrank", "point_get_us"], "max", 250.0),
    # ISSUE 10: blitz mix wall time within 2x of silo's, with 1.25x
    # timing-noise slack folded into the bound (2.0 * 1.25)
    ("BENCH_db_tpcc.json", ["acceptance", "txn_ratio_vs_silo"], "max", 2.5),
    ("BENCH_exec_engine.json", ["acceptance", "pass"], "true", None),
    ("BENCH_exec_engine.json", ["acceptance", "read_speedup"], "min", 2.0),
    ("BENCH_exec_engine.json", ["acceptance", "hit_rate"], "min", 0.9),
    ("BENCH_exec_engine.json", ["acceptance", "identical"], "true", None),
    ("BENCH_update_merge.json", ["acceptance", "pass"], "true", None),
    ("BENCH_update_merge.json", ["acceptance", "bytes_ratio"], "max", 1.25),
    ("BENCH_adaptive_refit.json", ["acceptance", "pass"], "true", None),
    ("BENCH_adaptive_refit.json", ["acceptance", "factor_ratio"], "min", 1.5),
    ("BENCH_out_of_core.json", ["acceptance", "pass"], "true", None),
    ("BENCH_out_of_core.json", ["acceptance", "sustained_ratio"], "min", 3.0),
    ("BENCH_out_of_core.json", ["acceptance", "reads_identical"], "true", None),
    ("BENCH_batch_decode.json", ["fast_fraction"], "min", 0.95),
    ("BENCH_recovery.json", ["acceptance", "pass"], "true", None),
    ("BENCH_recovery.json", ["acceptance", "wal_on_ratio"], "min", 0.7),
    ("BENCH_recovery.json", ["acceptance", "replay_s"], "max", 5.0),
    ("BENCH_recovery.json", ["acceptance", "identical"], "true", None),
    ("BENCH_recovery.json", ["acceptance", "ckpt_saved_frac"], "min", 0.5),
    ("BENCH_htap.json", ["acceptance", "pass"], "true", None),
    ("BENCH_htap.json", ["acceptance", "speedup_vs_ref"], "min", 3.0),
    ("BENCH_htap.json", ["acceptance", "identical"], "true", None),
    ("BENCH_htap.json", ["acceptance", "interference_ratio"], "max", 2.0),
    ("BENCH_htap.json", ["acceptance", "residency_neutral"], "true", None),
    # telemetry must be ~free (enabled >= 0.97x disabled throughput) and
    # behaviour-neutral; the TPC-C phase breakdown must account for the
    # mix's wall time (coverage ~1.0; >>1 means double-counting timers)
    ("BENCH_telemetry.json", ["acceptance", "pass"], "true", None),
    ("BENCH_telemetry.json", ["acceptance", "overhead_ratio"], "min", 0.97),
    ("BENCH_telemetry.json", ["acceptance", "identical"], "true", None),
    # the boundary sanitizer's off path must stay a falsy branch: the
    # disabled sweep call is bounded in ns (DESIGN.md §10)
    ("BENCH_sanitize.json", ["acceptance", "pass"], "true", None),
    ("BENCH_sanitize.json", ["acceptance", "boundary_disabled_ns"], "max", 1000.0),
    ("BENCH_db_tpcc.json", ["phases", "coverage"], "min", 0.9),
    ("BENCH_db_tpcc.json", ["phases", "coverage"], "max", 1.25),
]


def parse_csv(text: str) -> Dict[str, Tuple[float, Dict[str, str], str]]:
    """Parse ``name,us_per_call,derived`` lines into a metric map of
    ``name -> (us, derived key=value dict, raw derived string)``."""
    out: Dict[str, Tuple[float, Dict[str, str], str]] = {}
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("", "name"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        raw = parts[2] if len(parts) == 3 else ""
        derived: Dict[str, str] = {}
        for kv in raw.split(";"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                derived[k] = v
        out[parts[0]] = (us, derived, raw)
    return out


def dig(obj, path: List[str]):
    for key in path:
        if not isinstance(obj, dict) or key not in obj:
            return None
        obj = obj[key]
    return obj


def check_artifacts(root: Path) -> List[str]:
    failures: List[str] = []
    artifacts: Dict[str, dict] = {}
    for path in sorted(root.glob("BENCH_*.json")):
        try:
            artifacts[path.name] = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            failures.append(f"{path.name}: invalid JSON ({e})")
            continue
        doc = artifacts[path.name]
        for stamp in ("git_sha", "schema_name"):
            if not doc.get(stamp):
                failures.append(f"{path.name}: missing {stamp!r} stamp")
        acc = doc.get("acceptance")
        if isinstance(acc, dict) and acc.get("pass") is not True:
            failures.append(f"{path.name}: acceptance.pass is {acc.get('pass')!r}")
    for name, path, kind, bound in ARTIFACT_RULES:
        doc = artifacts.get(name)
        if doc is None:
            failures.append(f"{name}: artifact missing from repo root")
            continue
        val = dig(doc, path)
        where = f"{name}:{'.'.join(path)}"
        if val is None:
            failures.append(f"{where}: key missing")
        elif kind == "true" and val is not True:
            failures.append(f"{where}: expected true, got {val!r}")
        elif kind == "min" and not float(val) >= bound:
            failures.append(f"{where}: {val} < lower bound {bound}")
        elif kind == "max" and not float(val) <= bound:
            failures.append(f"{where}: {val} > upper bound {bound}")
    return failures


def check_smoke(csv_text: str, root: Path) -> List[str]:
    failures: List[str] = []
    metrics = parse_csv(csv_text)
    if "ERROR" in csv_text:
        for line in csv_text.splitlines():
            if "ERROR" in line:
                failures.append(f"smoke: bench errored: {line.strip()}")
    for bench in REQUIRED_BENCHES:
        wall = metrics.get(f"bench_{bench}_wall")
        if wall is None:
            failures.append(f"smoke: bench_{bench}_wall line missing")
        elif wall[2] != "ok":
            failures.append(f"smoke: bench {bench} did not finish ok")
    for name in SMOKE_IDENTICAL:
        m = metrics.get(name)
        if m is None:
            failures.append(f"smoke: metric {name} missing")
        elif m[1].get("identical") != "True":
            failures.append(
                f"smoke: {name} identical={m[1].get('identical')!r}, "
                "decode no longer bit-identical"
            )
    for name, key, bound in SMOKE_DERIVED_MIN:
        m = metrics.get(name)
        if m is None:
            failures.append(f"smoke: metric {name} missing")
            continue
        try:
            val = float(m[1].get(key, "nan"))
        except ValueError:
            val = float("nan")
        if not val >= bound:
            failures.append(f"smoke: {name} {key}={val} < floor {bound}")
    for name, artifact, path, slack in SMOKE_LATENCY_VS_ARTIFACT:
        m = metrics.get(name)
        apath = root / artifact
        if m is None or not apath.exists():
            failures.append(f"smoke: {name} or {artifact} missing")
            continue
        committed = dig(json.loads(apath.read_text()), path)
        if committed is None:
            failures.append(f"smoke: {artifact}:{'.'.join(path)} missing")
            continue
        committed_us = (
            1e6 / float(committed) if path[-1].endswith("_tps") else float(committed)
        )
        ceiling = slack * committed_us
        if not m[0] <= ceiling:
            failures.append(
                f"smoke: {name} at {m[0]}us exceeds {ceiling:.0f}us "
                f"({slack}x the committed full-scale number)"
            )
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--csv", default=None, help="smoke CSV (default: stdin)")
    ap.add_argument(
        "--skip-smoke",
        action="store_true",
        help="only validate the committed BENCH_*.json artifacts",
    )
    ap.add_argument("--root", default=str(REPO_ROOT))
    args = ap.parse_args(argv)
    root = Path(args.root)

    failures = check_artifacts(root)
    n_smoke = 0
    if not args.skip_smoke:
        if args.csv:
            csv_text = Path(args.csv).read_text()
        else:
            csv_text = sys.stdin.read()
        smoke_failures = check_smoke(csv_text, root)
        n_smoke = len(smoke_failures)
        failures += smoke_failures

    if failures:
        print(f"REGRESSION GATE: {len(failures)} violation(s)")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    checked = len(ARTIFACT_RULES)
    if not args.skip_smoke:
        checked += (
            len(REQUIRED_BENCHES) + len(SMOKE_IDENTICAL) + len(SMOKE_DERIVED_MIN)
        )
    print(f"REGRESSION GATE: pass ({checked} bounds checked, {n_smoke} smoke issues)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
