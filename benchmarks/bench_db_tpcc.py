"""Whole-database TPC-C over the `repro.db` engine: the paper-§6 headline.

Loads the full multi-table TPC-C population (warehouse, district,
customer, item, stock, orders, order_line) into a hash-partitioned
:class:`~repro.db.Database` per backend, drives the cross-table
transaction mix (NewOrder / Payment / OrderStatus / Delivery), compacts,
and reports:

* the **whole-database compression factor** — uncompressed-store bytes
  over each backend's bytes, tuple storage + key directory included
  (model bytes reported separately, as the paper does);
* **batched point-get latency** — Zipfian customer reads driven through
  ``Table.get_many``, which groups keys per shard and issues one
  vectorized decode per shard.

Acceptance (ISSUE 4): BlitzStore's post-mix whole-database factor must be
>= 2x the uncompressed store, with sharded reads identical across decode
backends.  ISSUE 10 adds the throughput side of the gate: with the
compiled execution engine (prepared plans + cross-txn coalescing at
``MIX_BATCH``) the blitzcrank mix must finish within ``RATIO_BOUND``x of
silo's wall time, with ``RATIO_SLACK`` absorbing run-to-run timing noise
(the ratio is gated at full scale only — toy mixes are jit-lowering
dominated).  Emits ``BENCH_db_tpcc.json`` and
``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.artifact import write_bench_json
from repro import telemetry
from repro.oltp import tpcc

ACCEPT_FACTOR = 2.0
# Cross-txn coalescing window for the mix (group-commit idiom): large
# enough that each shard's sub-batch amortises one prepared-plan replay.
MIX_BATCH = 512
# blitz mix wall time must stay within RATIO_BOUND x of silo's;
# RATIO_SLACK covers the measured run-to-run noise of the mix timing.
RATIO_BOUND = 2.0
RATIO_SLACK = 1.25
# Below this op count the jit lowering of the first window dominates the
# blitz arm's wall time, so the ratio gate only applies at full scale.
RATIO_MIN_OPS = 2000


def _point_get_us(db, n_reads: int, batch: int = 256, seed: int = 11,
                  zipf_a: float = 1.1) -> float:
    """Zipfian batched customer point-gets through the sharded table."""
    customer = db["customer"]
    keys = [k for k, _ in customer.scan()]
    rng = np.random.default_rng(seed)
    picks = [keys[int(i)] for i in
             tpcc.zipf_keys(rng, len(keys), n_reads, zipf_a)]
    t0 = time.perf_counter()
    for lo in range(0, len(picks), batch):
        db["customer"].get_many(picks[lo:lo + batch])
    return 1e6 * (time.perf_counter() - t0) / max(1, len(picks))


def _run_backend(backend: str, population, n_shards: int, n_ops: int,
                 n_reads: int, seed: int) -> Dict:
    t0 = time.perf_counter()
    db, _ = tpcc.build_tpcc_database(backend=backend, n_shards=n_shards,
                                     population=population)
    load_s = time.perf_counter() - t0
    post_load = db.stats()

    hist_base = telemetry.REGISTRY.hist_seconds()
    t0 = time.perf_counter()
    counts = tpcc.run_tpcc_mix(db, n_ops, seed=seed, batch=MIX_BATCH)
    mix_s = time.perf_counter() - t0
    # per-phase wall-time breakdown of the mix: where a txn's time goes
    # (encode / decode / jit-compile / fsync / fault-in / python glue)
    phases = telemetry.phase_breakdown(mix_s, since=hist_base)
    db.merge_all()  # steady state: overlays folded back into the arenas

    identical = None
    if backend == "blitzcrank":
        # the acceptance gate's backend-identity check runs on THIS state
        # — post-mix, post-merge, mixed escaped/merged/tombstoned arenas —
        # not on a fresh load that never saw a transaction
        identical = _blitz_reads_identical(db, seed)
    read_us = _point_get_us(db, n_reads)
    s = db.stats()
    out = {
        "backend": backend,
        "load_s": round(load_s, 2),
        "mix_s": round(mix_s, 3),
        "mix_us_per_txn": round(1e6 * mix_s / n_ops, 1),
        "phases": phases,
        "point_get_us": round(read_us, 1),
        "counts": counts,
        "post_load_bytes": post_load["nbytes"],
        "final_bytes": s["nbytes"],
        "store_bytes": s["store_bytes"],
        "index_bytes": s["index_bytes"],
        "model_bytes": s["model_bytes"],
        "n_live": s["n_live"],
        "tables": {n: {"n_live": t["n_live"], "nbytes": t["nbytes"],
                       "store_bytes": t["store_bytes"]}
                   for n, t in s["tables"].items()},
    }
    if backend == "silo":
        # model-free fixed-width reference for the post-mix database
        out["post_mix_raw_bytes"] = tpcc.database_row_bytes(db)
    if identical is not None:
        out["reads_identical"] = identical
    return out


def _blitz_reads_identical(db, seed: int) -> bool:
    """Sharded reads must be bit-identical across decode backends."""
    rng = np.random.default_rng(seed)
    for name in ("customer", "order_line", "stock"):
        table = db[name]
        keys = [k for k, _ in table.scan()]
        picks = [keys[int(i)] for i in rng.integers(0, len(keys), 300)]
        if table.get_many(picks, backend="numpy") != table.get_many(
            picks, backend="pallas"
        ):
            return False
    return True


def run(n_warehouses: int = 4, districts_per_wh: int = 10,
        customers_per_district: int = 300, n_items: int = 2000,
        orders_per_district: int = 100, n_shards: int = 4,
        n_ops: int = 2000, n_reads: int = 4000, seed: int = 9) -> Dict:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)
    raw_bytes = sum(tpcc.row_bytes(rows) for rows in population.values())

    backends = ["silo", "blitzcrank", "raman"]
    try:
        import zstandard  # noqa: F401
        backends.append("zstd")
    except ImportError:
        pass
    arms = {b: _run_backend(b, population, n_shards, n_ops, n_reads, seed)
            for b in backends}

    silo_bytes = arms["silo"]["final_bytes"]
    for arm in arms.values():
        arm["factor_vs_silo"] = round(silo_bytes / arm["final_bytes"], 3)
        arm["tuple_factor_vs_silo"] = round(
            arms["silo"]["store_bytes"] / arm["store_bytes"], 3)
    blitz = arms["blitzcrank"]
    identical = blitz["reads_identical"]
    # ISSUE 10 throughput gate: blitz mix wall time vs silo's, through
    # the same prepared-plan + coalescing path both arms share.
    txn_ratio = round(blitz["mix_s"] / max(arms["silo"]["mix_s"], 1e-9), 3)
    ratio_gated = n_ops >= RATIO_MIN_OPS
    txn_ratio_ok = (not ratio_gated) or txn_ratio <= RATIO_BOUND * RATIO_SLACK
    return {
        "scale": {
            "n_warehouses": n_warehouses,
            "districts_per_wh": districts_per_wh,
            "customers_per_district": customers_per_district,
            "n_items": n_items, "orders_per_district": orders_per_district,
            "n_shards": n_shards, "n_ops": n_ops, "n_reads": n_reads,
        },
        "n_tables": len(population),
        "load_raw_bytes": raw_bytes,
        "arms": arms,
        # headline breakdown = the blitzcrank arm's mix (gated in CI:
        # coverage >= 0.9 with the kernel phases separately present)
        "phases": blitz["phases"],
        "acceptance": {
            "bound": ACCEPT_FACTOR,
            "factor_vs_silo": blitz["factor_vs_silo"],
            "reads_identical": identical,
            "mix_batch": MIX_BATCH,
            "txn_ratio_vs_silo": txn_ratio,
            "txn_ratio_bound": RATIO_BOUND,
            "txn_ratio_slack": RATIO_SLACK,
            "txn_ratio_gated": ratio_gated,
            "pass": bool(blitz["factor_vs_silo"] >= ACCEPT_FACTOR
                         and identical and txn_ratio_ok),
        },
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    # Smoke keeps CI honest at toy sizes (format-string columns mostly
    # escape below a few thousand rows, so factors there mean nothing);
    # quick halves the row counts, full is the acceptance scale.
    if smoke:
        report = run(n_warehouses=2, districts_per_wh=2,
                     customers_per_district=30, n_items=100,
                     orders_per_district=12, n_shards=2,
                     n_ops=80, n_reads=200)
    elif quick:
        report = run(n_warehouses=2, districts_per_wh=10,
                     customers_per_district=150, n_items=1000,
                     orders_per_district=50, n_ops=1000, n_reads=2000)
    else:
        report = run()
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("db_tpcc", report, schema="tpcc_multi")
    for name, arm in report["arms"].items():
        print(f"db_tpcc_{name},{arm['point_get_us']},"
              f"factor={arm['factor_vs_silo']};"
              f"tuple_factor={arm['tuple_factor_vs_silo']};"
              f"txn_us={arm['mix_us_per_txn']}")
    acc = report["acceptance"]
    print(f"db_tpcc_acceptance,{acc['factor_vs_silo']},"
          f"bound={acc['bound']};identical={acc['reads_identical']};"
          f"txn_ratio={acc['txn_ratio_vs_silo']};"
          f"pass={acc['pass']};artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
