"""Compiled execution engine: prepared plan/run split vs per-row verbs.

The ISSUE-10 engine lowers each (table, verb, batch bucket) once and
replays it; this bench measures what that buys on the TPC-C customer
table (blitzcrank backend, sharded):

* **prepared tps** — ``Table.prepare("get").run(batch)`` replaying one
  lowered entry per pow2 bucket (the group-commit execution path the
  mix uses);
* **unprepared tps** — the scalar ``table.get(key)`` loop, i.e. one
  plan lookup + one single-row decode per call (the pre-engine shape);
* **plan-cache hit rate** — ``PreparedOp.cache_info()`` after the
  replay loop: everything past the first lowering per bucket must hit;
* **write path** — prepared batched inserts vs scalar inserts into a
  fresh table, same rows.

Acceptance: prepared reads >= ``SPEEDUP_FLOOR`` x scalar reads, hit
rate >= ``HIT_RATE_FLOOR``, and the prepared batch returns rows
bit-identical to the scalar loop.  Emits ``BENCH_exec_engine.json``
and ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.artifact import write_bench_json
from repro.db.database import Database
from repro.oltp import tpcc

SPEEDUP_FLOOR = 2.0
HIT_RATE_FLOOR = 0.9
READ_BATCH = 256


def _build_customer_db(population, n_shards: int) -> Database:
    db, _ = tpcc.build_tpcc_database(backend="blitzcrank",
                                     n_shards=n_shards,
                                     population=population)
    db.merge_all()
    return db


def _read_arms(db: Database, n_reads: int, seed: int) -> Dict:
    customer = db["customer"]
    keys = [k for k, _ in customer.scan()]
    rng = np.random.default_rng(seed)
    picks = [keys[int(i)] for i in
             tpcc.zipf_keys(rng, len(keys), n_reads, 1.1)]

    op = customer.prepare("get")
    op.run(picks[:READ_BATCH])  # warm: lower the main bucket once
    tail = len(picks) % READ_BATCH
    if tail:
        op.run(picks[:tail])  # ...and the ragged last batch's bucket
    base = op.cache_info()

    t0 = time.perf_counter()
    prepared_rows: List = []
    for lo in range(0, len(picks), READ_BATCH):
        prepared_rows.extend(op.run(picks[lo:lo + READ_BATCH]))
    prepared_s = time.perf_counter() - t0
    info = op.cache_info()
    delta_hits = info["hits"] - base["hits"]
    delta_total = (info["hits"] + info["misses"]
                   - base["hits"] - base["misses"])
    hit_rate = delta_hits / max(1, delta_total)

    # Scalar loop on a slice, scaled: one row per call is the point.
    n_scalar = max(64, n_reads // 8)
    t0 = time.perf_counter()
    scalar_rows = [customer.get(k) for k in picks[:n_scalar]]
    scalar_s = (time.perf_counter() - t0) * (len(picks) / n_scalar)

    identical = prepared_rows[:n_scalar] == scalar_rows
    return {
        "n_reads": len(picks),
        "read_batch": READ_BATCH,
        "prepared_tps": round(len(picks) / prepared_s, 1),
        "unprepared_tps": round(len(picks) / scalar_s, 1),
        "prepared_us_per_row": round(1e6 * prepared_s / len(picks), 2),
        "unprepared_us_per_row": round(1e6 * scalar_s / len(picks), 2),
        "speedup": round(scalar_s / prepared_s, 2),
        "plan_cache": info,
        "hit_rate": round(hit_rate, 4),
        "identical": bool(identical),
    }


def _write_arms(db: Database, n_writes: int, seed: int) -> Dict:
    """Prepared batched inserts vs scalar inserts, same generated rows."""
    rows = tpcc.generate_tpcc(
        n_warehouses=1, districts_per_wh=1,
        customers_per_district=max(10, n_writes), n_items=10,
        orders_per_district=5, seed=seed)["customer"][:n_writes]

    schema = db["customer"].schema

    def fresh():
        # Same fit sample for both arms: the comparison is about the
        # execution path, so the codecs must quantize identically.
        d = Database(backend="blitzcrank", n_shards=2)
        return d.create_table(schema, sample_rows=rows)

    t_batch = fresh()
    op = t_batch.prepare("insert")
    t0 = time.perf_counter()
    for lo in range(0, len(rows), READ_BATCH):
        op.run(rows[lo:lo + READ_BATCH])
    prepared_s = time.perf_counter() - t0

    t_scalar = fresh()
    t0 = time.perf_counter()
    for r in rows:
        t_scalar.insert(r)
    scalar_s = time.perf_counter() - t0

    identical = (t_batch.get_many([t_batch.schema.key_of(r) for r in rows])
                 == t_scalar.get_many([t_scalar.schema.key_of(r)
                                       for r in rows]))
    return {
        "n_writes": len(rows),
        "prepared_tps": round(len(rows) / prepared_s, 1),
        "unprepared_tps": round(len(rows) / scalar_s, 1),
        "speedup": round(scalar_s / prepared_s, 2),
        "identical": bool(identical),
    }


def run(n_warehouses: int = 2, districts_per_wh: int = 10,
        customers_per_district: int = 200, n_items: int = 1000,
        orders_per_district: int = 50, n_shards: int = 2,
        n_reads: int = 4000, n_writes: int = 2000, seed: int = 7) -> Dict:
    population = tpcc.generate_tpcc(
        n_warehouses=n_warehouses, districts_per_wh=districts_per_wh,
        customers_per_district=customers_per_district, n_items=n_items,
        orders_per_district=orders_per_district, seed=seed)
    db = _build_customer_db(population, n_shards)
    reads = _read_arms(db, n_reads, seed)
    writes = _write_arms(db, n_writes, seed + 1)
    identical = reads["identical"] and writes["identical"]
    return {
        "scale": {
            "n_warehouses": n_warehouses,
            "districts_per_wh": districts_per_wh,
            "customers_per_district": customers_per_district,
            "n_shards": n_shards, "n_reads": n_reads, "n_writes": n_writes,
        },
        "reads": reads,
        "writes": writes,
        "acceptance": {
            "speedup_floor": SPEEDUP_FLOOR,
            "hit_rate_floor": HIT_RATE_FLOOR,
            "read_speedup": reads["speedup"],
            "hit_rate": reads["hit_rate"],
            "identical": identical,
            "pass": bool(reads["speedup"] >= SPEEDUP_FLOOR
                         and reads["hit_rate"] >= HIT_RATE_FLOOR
                         and identical),
        },
    }


def main(quick: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        report = run(n_warehouses=1, districts_per_wh=2,
                     customers_per_district=40, n_items=100,
                     orders_per_district=10, n_reads=600, n_writes=200)
    elif quick:
        report = run()
    else:
        report = run(n_warehouses=4, customers_per_district=300,
                     n_items=2000, n_reads=8000, n_writes=4000)
    report["mode"] = "smoke" if smoke else ("quick" if quick else "full")
    artifact = write_bench_json("exec_engine", report, schema="exec_engine")
    r, w = report["reads"], report["writes"]
    print(f"exec_engine_get_prepared,{r['prepared_us_per_row']},"
          f"tps={r['prepared_tps']};speedup={r['speedup']};"
          f"hit_rate={r['hit_rate']}")
    print(f"exec_engine_get_scalar,{r['unprepared_us_per_row']},"
          f"tps={r['unprepared_tps']}")
    print(f"exec_engine_insert,{round(1e6 / max(w['prepared_tps'], 1e-9), 2)},"
          f"tps={w['prepared_tps']};speedup={w['speedup']}")
    acc = report["acceptance"]
    print(f"exec_engine_acceptance,{acc['read_speedup']},"
          f"hit_rate={acc['hit_rate']};identical={acc['identical']};"
          f"pass={acc['pass']};artifact={artifact.name}")
    return report


if __name__ == "__main__":
    main(quick=False)
