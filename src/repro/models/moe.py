"""Mixture-of-Experts layer: top-k routing with group-wise capacity dispatch.

Tokens are dispatched *within their batch row* (group): ranks come from a
cumulative sum over the row's (token, slot) pairs only, so no cross-shard
prefix sums appear when the batch is data-parallel.  Pairs beyond the expert
capacity are dropped (the residual carries the token).  Expert compute is an
``[B, E, C, d] x [E, d, f]`` einsum; the E axis shards over the mesh 'model'
axis (expert parallelism) and the B axis over 'data', so GSPMD materializes
the token<->expert all-to-all at the dispatch/combine boundaries — the
standard EP schedule.

Supports DeepSeekMoE fine-grained experts (64 small experts, top-6, shared
experts that bypass routing) and Phi-3.5-MoE (16 experts, top-2).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax
from .config import ModelConfig, MoEConfig
from .layers import mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    de = mc.d_expert or cfg.d_ff
    keys = jax.random.split(key, 4)
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / de) ** 0.5
    E = mc.n_experts
    p = {
        "router": jax.random.normal(keys[0], (d, E), jnp.float32) * s_in,
        "wi": jax.random.normal(keys[1], (E, d, de), dtype) * s_in,
        "wo": jax.random.normal(keys[2], (E, de, d), dtype) * s_out,
    }
    if cfg.act == "swiglu":
        p["wg"] = jax.random.normal(keys[3], (E, d, de), dtype) * s_in
    if mc.n_shared:
        p["shared"] = mlp_init(keys[3], d, de * mc.n_shared, cfg.act, dtype)
    return p


def moe_apply(p, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss).  B is the dispatch group dimension."""
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape
    E, k = mc.n_experts, mc.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing loss (fraction routed vs mean prob)
    me = probs.mean(axis=(0, 1))                              # [E]
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # [B, S, k, E]
    ce = onehot.mean(axis=(0, 1, 2))
    aux = mc.aux_loss_weight * E * jnp.sum(me * ce)

    # per-group capacity
    C = int(max(1, round(S * k / E * mc.capacity_factor)))

    # rank of each (token, slot) pair within its expert, per group
    flat = onehot.reshape(B, S * k, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                   # [B, S*k, E]
    rank = (ranks * flat).sum(-1).astype(jnp.int32)           # [B, S*k]
    eid = gate_idx.reshape(B, S * k)
    keep = rank < C

    # scatter into [B, E, C, d]; dropped pairs land in a discard row.
    # Row-local (vmapped) scatter keeps B a *batch* dimension of the
    # scatter op, so GSPMD proves shard-locality; the expert resharding
    # then happens at ONE explicit boundary (a clean all-to-all) instead of
    # leaking collective-permute chains into the scatter (§Perf).
    slot = jnp.where(keep, eid * C + rank, E * C)             # [B, S*k]
    xk = jnp.repeat(x, k, axis=1)                             # [B, S*k, d]

    def row_scatter(xk_b, slot_b):
        return jnp.zeros((E * C + 1, d), x.dtype).at[slot_b].add(xk_b)

    buf = jax.vmap(row_scatter)(xk, slot)[:, :E * C]
    buf = buf.reshape(B, E, C, d)
    buf = ax(buf, "batch", None, None, None)      # stage 1: shard-local
    if mc.quantize_dispatch:
        # int8 semantic dispatch: halve the all-to-all wire bytes (§Perf)
        sc = jnp.max(jnp.abs(buf.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
        bq = jnp.clip(jnp.round(buf.astype(jnp.float32) / sc[..., None]),
                      -127, 127).astype(jnp.int8)
        bq = ax(bq, "batch", "expert", None, None)   # the a2a, in int8
        sc = ax(sc, "batch", "expert", None)
        buf = (bq.astype(jnp.bfloat16) *
               sc[..., None].astype(jnp.bfloat16)).astype(x.dtype)
    else:
        buf = ax(buf, "batch", "expert", None, None)  # stage 2: one a2a

    # expert FFN: einsums with a leading expert axis (EP shards this)
    if cfg.act == "swiglu":
        h = jax.nn.silu(
            jnp.einsum("becd,edf->becf", buf, p["wg"])
        ) * jnp.einsum("becd,edf->becf", buf, p["wi"])
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("becd,edf->becf", buf, p["wi"])))
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["wi"]))
    out = jnp.einsum("becf,efd->becd", h, p["wo"])            # [B, E, C, d]
    out = ax(out, "batch", "expert", None, None)

    # combine: reshard expert->d (one all-to-all; E unshards while d shards
    # over TP), row-local gather, weighted sum — only the final y (x-sized,
    # bf16) is gathered back to replicated, not the C-overprovisioned f32
    # buffer (§Perf: 327 GB -> ~x-sized collectives).
    out = ax(out, "batch", None, None, "model")
    flat_rows = out.reshape(B, E * C, d)

    def row_gather(rows_b, slot_b):
        return rows_b[jnp.minimum(slot_b, E * C - 1)]

    gathered = jax.vmap(row_gather)(flat_rows, slot)          # [B, S*k, d]
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    w = gate_vals.reshape(B, S * k, 1).astype(x.dtype)        # bf16 weights
    y = (gathered * w).reshape(B, S, k, d).sum(axis=2)
    y = ax(y, "batch", None, None)

    if mc.n_shared:
        y = y + mlp_apply(p["shared"], x, cfg.act)
    return y, aux
