"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and a mamba-style selective SSM.

All recurrences are expressed so that training uses parallel-friendly forms
(chunkwise scan for mLSTM, associative scan for mamba, lax.scan for sLSTM)
and decoding uses O(1) single-step updates with an explicit carried state —
the state plays the role of the KV cache for these families.

mLSTM (matrix memory, exponentially gated, arXiv:2405.04517):
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)
with the log-domain stabilizer m_t.  sLSTM keeps scalar cell states with
exponential gating and a per-head recurrent connection.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    s = (1.0 / d) ** 0.5
    return {
        "wq": jax.random.normal(ks[0], (d, H, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, H, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, H, hd), dtype) * s,
        "wi": jax.random.normal(ks[3], (d, H), jnp.float32) * s,  # input gate
        "wf": jax.random.normal(ks[4], (d, H), jnp.float32) * s,  # forget gate
        "wo": jax.random.normal(ks[5], (H, hd, d), dtype) * s,
        "f_bias": jnp.full((H,), 3.0, jnp.float32),  # forget-open init
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk: int):
    """Chunkwise-parallel mLSTM.

    q,k,v: [B, S, H, D]; log_f/log_i: [B, S, H] (log-domain gates).
    Returns h: [B, S, H, D].
    """
    B, S, H, D = q.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)

    def rs(x):  # [B, S, ...] -> [nc, B, chunk, ...]
        return jnp.moveaxis(x.reshape(B, nc, chunk, *x.shape[2:]), 1, 0)

    qc, kc_, vc, fc, ic = map(rs, (q, k, v, log_f, log_i))
    scale = D ** -0.5

    def step(carry, inp):
        C, n, m = carry                    # [B,H,D,D], [B,H,D], [B,H]
        qi, ki, vi, lf, li = inp           # [B,chunk,H,*]
        csum_f = jnp.cumsum(lf, axis=1)    # within-chunk cumulative log-forget
        total_f = csum_f[:, -1]            # [B,H]
        # log weight of intra-chunk contribution t<-s: csum_f[t]-csum_f[s]+li[s]
        log_D = (csum_f[:, :, None, :] - csum_f[:, None, :, :]
                 + li[:, None, :, :])                       # [B,t,s,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        log_D = jnp.where(tri[None, :, :, None], log_D, -1e30)
        # inter-chunk weight for state carried in: csum_f[t] + m
        log_carry = csum_f + m[:, None, :]                  # [B,t,H]
        m_new = jnp.maximum(log_D.max(axis=2), log_carry)   # [B,t,H]
        Dmat = jnp.exp(log_D - m_new[:, :, None, :])        # [B,t,s,H]
        wcar = jnp.exp(log_carry - m_new)                   # [B,t,H]

        s_qk = jnp.einsum("bthd,bshd->btsh", qi.astype(jnp.float32),
                          ki.astype(jnp.float32)) * scale
        intra = jnp.einsum("btsh,btsh,bshd->bthd", s_qk, Dmat,
                           vi.astype(jnp.float32))
        inter = jnp.einsum("bthd,bhde->bthe", qi.astype(jnp.float32),
                           C) * scale
        num = intra + inter * wcar[..., None]
        den_intra = jnp.einsum("btsh,btsh->bth", s_qk, Dmat)
        den_inter = jnp.einsum("bthd,bhd->bth", qi.astype(jnp.float32),
                               n) * scale
        den = den_intra + den_inter * wcar
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # update carried state to end of chunk
        m_next = jnp.maximum(total_f + m, (total_f[:, None] - csum_f
                                           + li).max(axis=1))
        w_old = jnp.exp(total_f + m - m_next)               # [B,H]
        wk = jnp.exp(total_f[:, None] - csum_f + li - m_next[:, None])  # [B,s,H]
        C = C * w_old[..., None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", ki.astype(jnp.float32),
            vi.astype(jnp.float32), wk)
        n = n * w_old[..., None] + jnp.einsum(
            "bshd,bsh->bhd", ki.astype(jnp.float32), wk)
        return (C, n, m_next), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc_, vc, fc, ic))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, nc * chunk, H, D)
    return h[:, :S], {"C": Cf, "n": nf, "m": mf}


def mlstm_apply(p, x: jax.Array, cfg: ModelConfig, return_state=False):
    """Training/prefill form. x: [B, S, d] -> [B, S, d] (+ final state)."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dhx->bshx", x, p["wk"])
    v = jnp.einsum("bsd,dhx->bshx", x, p["wv"])
    xf = x.astype(jnp.float32)
    log_i = jnp.einsum("bsd,dh->bsh", xf, p["wi"])
    log_f = jax.nn.log_sigmoid(jnp.einsum("bsd,dh->bsh", xf, p["wf"])
                               + p["f_bias"])
    h, state = _mlstm_chunk_scan(q, k, v, log_f, log_i, cfg.ssm.chunk)
    out = jnp.einsum("bshx,hxd->bsd", h.astype(x.dtype), p["wo"])
    if return_state:
        return out, state
    return out


def mlstm_decode_init(cfg: ModelConfig, B: int) -> dict:
    H, D = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((B, H, D, D), jnp.float32),
            "n": jnp.zeros((B, H, D), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


def mlstm_decode_step(p, x: jax.Array, state: dict, cfg: ModelConfig):
    """x: [B, 1, d] -> (y [B, 1, d], new state).  O(1) per token."""
    D = cfg.head_dim
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])[:, 0]
    k = jnp.einsum("bsd,dhx->bshx", x, p["wk"])[:, 0]
    v = jnp.einsum("bsd,dhx->bshx", x, p["wv"])[:, 0]
    xf = x.astype(jnp.float32)[:, 0]
    log_i = jnp.einsum("bd,dh->bh", xf, p["wi"])
    log_f = jax.nn.log_sigmoid(jnp.einsum("bd,dh->bh", xf, p["wf"])
                               + p["f_bias"])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    w_old = jnp.exp(log_f + state["m"] - m_new)
    w_in = jnp.exp(log_i - m_new)
    C = state["C"] * w_old[..., None, None] + jnp.einsum(
        "bhd,bhe,bh->bhde", k.astype(jnp.float32), v.astype(jnp.float32), w_in)
    n = state["n"] * w_old[..., None] + k.astype(jnp.float32) * w_in[..., None]
    qf = q.astype(jnp.float32) * (D ** -0.5)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = jnp.einsum("bhx,hxd->bd", h.astype(x.dtype), p["wo"])[:, None]
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    s = (1.0 / d) ** 0.5
    return {
        # fused [z, i, f, o] input projections
        "w_in": jax.random.normal(ks[0], (d, 4, H, hd), jnp.float32) * s,
        # per-head recurrent matrices (block-diagonal overall)
        "r": jax.random.normal(ks[1], (4, H, hd, hd), jnp.float32) * s,
        "f_bias": jnp.full((H, hd), 3.0, jnp.float32),
        "wo": jax.random.normal(ks[2], (d, d), dtype) * s,
    }


def slstm_state_init(cfg: ModelConfig, B: int) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    z = jnp.zeros((B, H, hd), jnp.float32)
    return {"c": z, "n": z, "m": z - 1e30, "h": z}


def _slstm_cell(p, zifo, state):
    """zifo: [B, 4, H, hd] pre-activations (input part only)."""
    rec = jnp.einsum("bhd,ghde->bghe", state["h"], p["r"])
    z_t, i_t, f_t, o_t = [zifo[:, g] + rec[:, g] for g in range(4)]
    f_t = f_t + p["f_bias"]
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + state["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    c = f_p * state["c"] + i_p * jnp.tanh(z_t)
    n = f_p * state["n"] + i_p
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_apply(p, x: jax.Array, cfg: ModelConfig, return_state=False):
    """x: [B, S, d]; sequential scan over time (no parallel form exists)."""
    B, S, d = x.shape
    zifo = jnp.einsum("bsd,dghe->bsghe", x.astype(jnp.float32), p["w_in"])

    def step(state, z_t):
        st = _slstm_cell(p, z_t, state)
        return st, st["h"]

    fin, hs = jax.lax.scan(step, slstm_state_init(cfg, B),
                           jnp.moveaxis(zifo, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
    out = jnp.einsum("bsd,de->bse", h.astype(x.dtype), p["wo"])
    if return_state:
        return out, fin
    return out


def slstm_decode_step(p, x: jax.Array, state: dict, cfg: ModelConfig):
    zifo = jnp.einsum("bd,dghe->bghe", x[:, 0].astype(jnp.float32), p["w_in"])
    st = _slstm_cell(p, zifo, state)
    B = x.shape[0]
    h = st["h"].reshape(B, 1, cfg.d_model)
    return jnp.einsum("bsd,de->bse", h.astype(x.dtype), p["wo"]), st


# ---------------------------------------------------------------------------
# Mamba-style selective SSM head group (hymba)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    N = cfg.ssm.d_state
    ks = jax.random.split(key, 5)
    s = (1.0 / d) ** 0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_dt": jax.random.normal(ks[1], (d,), jnp.float32) * s,
        "dt_bias": jnp.full((d,), -4.0, jnp.float32),
        "w_B": jax.random.normal(ks[2], (d, N), jnp.float32) * s,
        "w_C": jax.random.normal(ks[3], (d, N), jnp.float32) * s,
        "log_A": jnp.log(jnp.linspace(1.0, float(N), N, dtype=jnp.float32)),
        "w_out": jax.random.normal(ks[4], (d, d), dtype) * s,
    }


def mamba_apply(p, x: jax.Array, cfg: ModelConfig, return_state=False):
    """Selective SSM via associative scan. x: [B, S, d] -> [B, S, d]."""
    from repro.dist.sharding import ax
    xf = x.astype(jnp.float32)
    u = jnp.einsum("bsd,de->bse", x, p["w_x"]).astype(jnp.float32)
    dt = jax.nn.softplus(xf * p["w_dt"] + p["dt_bias"])      # [B,S,d]
    Bm = jnp.einsum("bsd,dn->bsn", xf, p["w_B"])             # [B,S,N]
    Cm = jnp.einsum("bsd,dn->bsn", xf, p["w_C"])             # [B,S,N]
    A = -jnp.exp(p["log_A"])                                  # [N]
    # h_t = a_t * h_{t-1} + b_t ;  a_t = exp(dt*A), b_t = dt*B*u
    # [B,S,d,N] intermediates shard d over 'model' (they dominate memory)
    a = ax(jnp.exp(dt[..., None] * A), "batch", "seq", "model", None)
    b = ax((dt * u)[..., None] * Bm[:, :, None, :],
           "batch", "seq", "model", None)

    def combine(x1, x2):
        a1, b1 = x1
        a2, b2 = x2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm)
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype), p["w_out"])
    if return_state:
        return out, h[:, -1]
    return out


def mamba_state_init(cfg: ModelConfig, B: int) -> jax.Array:
    return jnp.zeros((B, cfg.d_model, cfg.ssm.d_state), jnp.float32)


def mamba_decode_step(p, x: jax.Array, h: jax.Array, cfg: ModelConfig):
    xf = x.astype(jnp.float32)[:, 0]
    u = jnp.einsum("bd,de->be", x[:, 0], p["w_x"]).astype(jnp.float32)
    dt = jax.nn.softplus(xf * p["w_dt"] + p["dt_bias"])
    Bm = jnp.einsum("bd,dn->bn", xf, p["w_B"])
    Cm = jnp.einsum("bd,dn->bn", xf, p["w_C"])
    A = -jnp.exp(p["log_A"])
    a = jnp.exp(dt[..., None] * A)
    h = h * a + (dt * u)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm)
    y = jnp.einsum("bd,de->be", y.astype(x.dtype), p["w_out"])
    return y[:, None], h
