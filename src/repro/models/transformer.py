"""Model assembly: init / forward / loss / prefill / decode for all families.

One interpreter for the ``ModelConfig`` data: dense GQA decoders, MoE,
encoder-decoder (whisper), VLM (stub prefix embeddings), xLSTM stacks and
hybrid attention∥SSM blocks.  Layers are stacked and scanned
(``lax.scan``) so the compiled HLO is O(1) in depth; per-layer
heterogeneity (local/global windows, MoE-vs-dense) is data, not control
flow.  Sharding is expressed through logical-axis annotations
(:mod:`repro.dist.sharding`), so the same code traces for 1 CPU device or a
512-chip multi-pod mesh.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import ax
from . import ssm as ssm_lib
from .config import ModelConfig
from .layers import (AttnSpec, attn_init, attn_output, attn_project_qkv,
                     chunked_attention, decode_attention,
                     decode_attention_paged, decode_attention_paged_quant,
                     mlp_apply, mlp_init, rms_norm, softcap)
from .moe import moe_apply, moe_init

_BIG_WINDOW = 1 << 30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def window_array(cfg: ModelConfig) -> Optional[np.ndarray]:
    """Per-layer window sizes (traced data), or None for all-global."""
    if cfg.attn_pattern == "global":
        return None
    return np.array([cfg.window if cfg.layer_is_local(i) else _BIG_WINDOW
                     for i in range(cfg.n_layers)], dtype=np.int32)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dt = _dtype(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), dt) * 0.02,
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (d, cfg.vocab), dt) * (1.0 / d) ** 0.5
        )

    def dense_block(k):
        ks = jax.random.split(k, 2)
        return {"attn": attn_init(ks[0], cfg, dt),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dt),
                "ln1": jnp.zeros((d,), jnp.float32),
                "ln2": jnp.zeros((d,), jnp.float32)}

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack_init(keys[2], cfg.n_layers, dense_block)
    elif cfg.family == "moe":
        def moe_block(k):
            ks = jax.random.split(k, 2)
            return {"attn": attn_init(ks[0], cfg, dt),
                    "moe": moe_init(ks[1], cfg, dt),
                    "ln1": jnp.zeros((d,), jnp.float32),
                    "ln2": jnp.zeros((d,), jnp.float32)}
        nd = cfg.moe.first_k_dense
        if nd:
            params["dense_blocks"] = _stack_init(keys[3], nd, dense_block)
        params["blocks"] = _stack_init(keys[2], cfg.n_layers - nd, moe_block)
    elif cfg.family == "audio":
        enc_d = cfg.encoder.d_model or d

        def enc_block(k):
            ks = jax.random.split(k, 2)
            return {"attn": attn_init(ks[0], cfg, dt),
                    "mlp": mlp_init(ks[1], enc_d, cfg.d_ff, cfg.act, dt),
                    "ln1": jnp.zeros((enc_d,), jnp.float32),
                    "ln2": jnp.zeros((enc_d,), jnp.float32)}

        def dec_block(k):
            ks = jax.random.split(k, 3)
            return {"attn": attn_init(ks[0], cfg, dt),
                    "cross": attn_init(ks[1], cfg, dt),
                    "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.act, dt),
                    "ln1": jnp.zeros((d,), jnp.float32),
                    "ln_c": jnp.zeros((d,), jnp.float32),
                    "ln2": jnp.zeros((d,), jnp.float32)}
        params["encoder"] = _stack_init(keys[3], cfg.encoder.n_layers, enc_block)
        params["enc_norm"] = jnp.zeros((enc_d,), jnp.float32)
        params["enc_pos"] = jax.random.normal(
            keys[4], (cfg.encoder.n_ctx, enc_d), dt) * 0.01
        params["blocks"] = _stack_init(keys[2], cfg.n_layers, dec_block)
    elif cfg.family == "ssm":
        r = cfg.ssm.mlstm_per_slstm
        groups = cfg.n_layers // (r + 1)

        def group(k):
            ks = jax.random.split(k, 2)
            return {
                "mlstm": _stack_init(ks[0], r,
                                     lambda kk: ssm_lib.mlstm_init(kk, cfg, dt)),
                "mlstm_ln": jnp.zeros((r, d), jnp.float32),
                "slstm": ssm_lib.slstm_init(ks[1], cfg, dt),
                "slstm_ln": jnp.zeros((d,), jnp.float32),
            }
        params["blocks"] = _stack_init(keys[2], groups, group)
    elif cfg.family == "hybrid":
        def hy_block(k):
            ks = jax.random.split(k, 3)
            return {"attn": attn_init(ks[0], cfg, dt),
                    "mamba": ssm_lib.mamba_init(ks[1], cfg, dt),
                    "mlp": mlp_init(ks[2], d, cfg.d_ff, cfg.act, dt),
                    "ln1": jnp.zeros((d,), jnp.float32),
                    "ln_attn": jnp.zeros((d,), jnp.float32),
                    "ln_ssm": jnp.zeros((d,), jnp.float32),
                    "ln2": jnp.zeros((d,), jnp.float32)}
        params["blocks"] = _stack_init(keys[2], cfg.n_layers, hy_block)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Shared attention sub-block (train/prefill form)
# ---------------------------------------------------------------------------

def _attn_branch(p_attn, cfg: ModelConfig, h: jax.Array, positions,
                 window, causal=True, use_rope=True, kv_override=None):
    from repro.dist.sharding import get_rules
    q, k, v = attn_project_qkv(p_attn, h, positions, cfg.rope_theta, use_rope)
    if kv_override is not None:
        k, v = kv_override
    # TP strategy: shard heads when they divide the TP axis, otherwise go
    # context-parallel (shard the query sequence; K/V replicate over TP) —
    # exactly divisible for any head count (DESIGN.md §5).
    rules = get_rules()
    tp = rules.axis_sizes.get("model", 1) if rules else 1
    if cfg.n_heads % tp == 0:
        q = ax(q, "batch", None, "heads", None)
        k = ax(k, "batch", None, "kv_heads", None)
        v = ax(v, "batch", None, "kv_heads", None)
    else:
        q = ax(q, "batch", "seq_tp", None, None)
        k = ax(k, "batch", None, None, None)
        v = ax(v, "batch", None, None, None)
    spec = AttnSpec(causal=causal, logit_cap=cfg.attn_softcap,
                    f32_scores=cfg.attn_f32_scores,
                    q_block=cfg.attn_q_block, kv_chunk=cfg.attn_kv_chunk)
    o = chunked_attention(q, k, v, positions, spec, window=window)
    return attn_output(p_attn, o), (k, v)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward (teacher-forced / prefill logits over a full sequence)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array,
                 prefix_embeds: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return ax(x, "batch", "seq", "embed")


def _encoder_apply(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B, T, enc_d]."""
    x = frames + params["enc_pos"][None, :frames.shape[1]]
    positions = jnp.arange(frames.shape[1])

    def body(xc, p_l):
        h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        a, _ = _attn_branch(p_l["attn"], cfg, h, positions, None,
                            causal=False, use_rope=False)
        xc = xc + a
        h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        xc = xc + mlp_apply(p_l["mlp"], h, cfg.act)
        return xc, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            encoder_frames: Optional[jax.Array] = None,
            collect_cache: bool = False):
    """Full-sequence hidden states. Returns (h [B, S, d], aux) or, with
    ``collect_cache``, (h, aux, cache-dict of stacked per-layer k/v and SSM
    end states) — the real prefill path for the serving engine."""
    x = embed_tokens(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    windows = window_array(cfg)
    cache: Dict[str, Any] = {}

    if cfg.family in ("dense", "vlm"):
        def body(xc, scanned):
            p_l, win = scanned
            h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            a, kv = _attn_branch(p_l["attn"], cfg, h, positions, win)
            xc = xc + a
            h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            xc = xc + mlp_apply(p_l["mlp"], h, cfg.act)
            return (
                ax(xc, "batch", "act_seq", "embed"),
                (kv if collect_cache else None),
            )
        win = windows if windows is not None else np.full(
            cfg.n_layers, _BIG_WINDOW, np.int32)
        x, ys = jax.lax.scan(_remat(cfg, body), x, (params["blocks"], win))
        if collect_cache:
            cache["k"], cache["v"] = ys

    elif cfg.family == "moe":
        nd = cfg.moe.first_k_dense
        if nd:
            def dbody(xc, p_l):
                h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
                a, kv = _attn_branch(p_l["attn"], cfg, h, positions, None)
                xc = xc + a
                h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
                return (
                    xc + mlp_apply(p_l["mlp"], h, cfg.act),
                    (kv if collect_cache else None),
                )
            x, dys = jax.lax.scan(_remat(cfg, dbody), x,
                                  params["dense_blocks"])

        def body(carry, p_l):
            xc, aux_c = carry
            h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            at, kv = _attn_branch(p_l["attn"], cfg, h, positions, None)
            xc = xc + at
            h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            y, a = moe_apply(p_l["moe"], h, cfg)
            return (
                (ax(xc + y, "batch", "act_seq", "embed"), aux_c + a),
                (kv if collect_cache else None),
            )
        (x, aux), ys = jax.lax.scan(_remat(cfg, body), (x, aux),
                                    params["blocks"])
        if collect_cache:
            if nd:
                cache["k"] = jnp.concatenate([dys[0], ys[0]], axis=0)
                cache["v"] = jnp.concatenate([dys[1], ys[1]], axis=0)
            else:
                cache["k"], cache["v"] = ys

    elif cfg.family == "audio":
        enc_out = _encoder_apply(params, cfg, encoder_frames)

        def body(xc, p_l):
            h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            a, kv = _attn_branch(p_l["attn"], cfg, h, positions, None)
            xc = xc + a
            h = rms_norm(xc, p_l["ln_c"], cfg.norm_eps)
            ck = jnp.einsum("btd,dkx->btkx", enc_out, p_l["cross"]["wk"])
            cv = jnp.einsum("btd,dkx->btkx", enc_out, p_l["cross"]["wv"])
            q = jnp.einsum("bsd,dhx->bshx", h, p_l["cross"]["wq"])
            spec = AttnSpec(causal=False)
            o = chunked_attention(q, ck, cv, positions, spec, window=None)
            xc = xc + attn_output(p_l["cross"], o)
            h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            xc = xc + mlp_apply(p_l["mlp"], h, cfg.act)
            return (
                ax(xc, "batch", "act_seq", "embed"),
                ((kv, (ck, cv)) if collect_cache else None),
            )
        x, ys = jax.lax.scan(_remat(cfg, body), x, params["blocks"])
        if collect_cache:
            (cache["k"], cache["v"]), (cache["cross_k"], cache["cross_v"]) = ys

    elif cfg.family == "ssm":
        def body(xc, p_g):
            def mbody(xm, p_l):
                h = rms_norm(xm, p_l["ln"], cfg.norm_eps)
                y, st = ssm_lib.mlstm_apply(p_l["p"], h, cfg,
                                            return_state=True)
                return xm + y, (st if collect_cache else None)
            xc, msts = jax.lax.scan(
                mbody, xc, {"p": p_g["mlstm"], "ln": p_g["mlstm_ln"]})
            h = rms_norm(xc, p_g["slstm_ln"], cfg.norm_eps)
            y, sst = ssm_lib.slstm_apply(p_g["slstm"], h, cfg,
                                         return_state=True)
            xc = xc + y
            return (
                ax(xc, "batch", "act_seq", "embed"),
                ((msts, sst) if collect_cache else None),
            )
        x, ys = jax.lax.scan(_remat(cfg, body), x, params["blocks"])
        if collect_cache:
            cache["mlstm"], cache["slstm"] = ys

    elif cfg.family == "hybrid":
        def body(xc, scanned):
            p_l, win = scanned
            h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            a, kv = _attn_branch(p_l["attn"], cfg, h, positions, win)
            s, hT = ssm_lib.mamba_apply(p_l["mamba"], h, cfg,
                                        return_state=True)
            fused = 0.5 * (rms_norm(a, p_l["ln_attn"], cfg.norm_eps) +
                           rms_norm(s, p_l["ln_ssm"], cfg.norm_eps))
            xc = xc + fused
            h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            xc = xc + mlp_apply(p_l["mlp"], h, cfg.act)
            return (
                ax(xc, "batch", "act_seq", "embed"),
                ((kv, hT) if collect_cache else None),
            )
        x, ys = jax.lax.scan(_remat(cfg, body), x, (params["blocks"], windows))
        if collect_cache:
            (cache["k"], cache["v"]), cache["mamba"] = ys
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if collect_cache:
        return x, aux, cache
    return x, aux


def unembed(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return ax(logits, "batch", "seq", "vocab")


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            loss_chunks: int = 4) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked softmax-xent: never materializes full [B, S, V] at once."""
    h, aux = forward(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     encoder_frames=batch.get("encoder_frames"))
    labels = batch["labels"]
    if cfg.family == "vlm" and batch.get("prefix_embeds") is not None:
        npre = batch["prefix_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], npre), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    S = h.shape[1]
    nc = loss_chunks
    while S % nc:
        nc -= 1
    hs = h.reshape(h.shape[0], nc, S // nc, h.shape[2])
    ls = labels.reshape(labels.shape[0], nc, S // nc)

    def chunk(carry, inp):
        tot, cnt = carry
        hc, lc = inp
        logits = unembed(params, cfg, hc)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        tot = tot + ((lse - gold) * mask).sum()
        cnt = cnt + mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    xent = tot / jnp.maximum(cnt, 1.0)
    return xent + aux, {"xent": xent, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# Decode path (single-token steps over an explicit state)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, B: int, max_len: int) -> Dict[str, Any]:
    """Decode state: paged KV (sequence-shardable, immutable between
    flushes) + a small replicated write tail, so the per-token update never
    touches a sharded dimension (EXPERIMENTS.md §Perf hillclimb).  With
    ``cfg.kv_quant`` the pages are int8 with per-(token, head) semantic
    scales (paper §4.2 as a KV quantizer) at half the HBM footprint."""
    dt = _dtype(cfg)
    K, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    T = min(cfg.decode_tail, max_len)
    st: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe", "audio", "hybrid"):
        if cfg.kv_quant:
            st["k"] = jnp.zeros((L, B, max_len, K, hd), jnp.int8)
            st["v"] = jnp.zeros((L, B, max_len, K, hd), jnp.int8)
            st["k_scale"] = jnp.zeros((L, B, max_len, K), jnp.float32)
            st["v_scale"] = jnp.zeros((L, B, max_len, K), jnp.float32)
        else:
            st["k"] = jnp.zeros((L, B, max_len, K, hd), dt)
            st["v"] = jnp.zeros((L, B, max_len, K, hd), dt)
        st["k_tail"] = jnp.zeros((L, B, T, K, hd), dt)
        st["v_tail"] = jnp.zeros((L, B, T, K, hd), dt)
    if cfg.family == "audio":
        Tx = cfg.encoder.n_ctx
        st["cross_k"] = jnp.zeros((L, B, Tx, K, hd), dt)
        st["cross_v"] = jnp.zeros((L, B, Tx, K, hd), dt)
    if cfg.family == "hybrid":
        st["mamba"] = jnp.zeros((L, B, cfg.d_model, cfg.ssm.d_state),
                                jnp.float32)
    if cfg.family == "ssm":
        r = cfg.ssm.mlstm_per_slstm
        G = cfg.n_layers // (r + 1)
        H, D = cfg.n_heads, cfg.head_dim
        hd_s = cfg.d_model // cfg.n_heads
        st["mlstm"] = {"C": jnp.zeros((G, r, B, H, D, D), jnp.float32),
                       "n": jnp.zeros((G, r, B, H, D), jnp.float32),
                       "m": jnp.full((G, r, B, H), -1e30, jnp.float32)}
        st["slstm"] = {k: (jnp.full((G, B, H, hd_s), -1e30, jnp.float32)
                           if k == "m" else
                           jnp.zeros((G, B, H, hd_s), jnp.float32))
                       for k in ("c", "n", "m", "h")}
    return st


def shard_decode_state(st: Dict[str, Any]) -> Dict[str, Any]:
    """Annotate decode-state tensors with logical axes."""
    out = dict(st)
    for key in ("k", "v"):
        if key in out:
            out[key] = ax(out[key], "stack", "batch", "kv_seq", "kv_heads",
                          "head_dim")
    for key in ("cross_k", "cross_v"):
        if key in out:
            out[key] = ax(out[key], "stack", "batch", None, "kv_heads",
                          "head_dim")
    if "mamba" in out:
        out["mamba"] = ax(out["mamba"], "stack", "batch", "model", None)
    if "mlstm" in out:
        out["mlstm"] = {
            "C": ax(out["mlstm"]["C"], "stack", None, "batch", "heads",
                    None, "model"),
            "n": ax(out["mlstm"]["n"], "stack", None, "batch", "heads", None),
            "m": ax(out["mlstm"]["m"], "stack", None, "batch", "heads"),
        }
    return out


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            state: Dict[str, Any],
            prefix_embeds: Optional[jax.Array] = None,
            encoder_frames: Optional[jax.Array] = None):
    """Run the full prompt, fill caches, return last-position logits.

    For simplicity the cache-filling prefill recomputes projections; the
    serving engine uses it once per request batch.
    """
    h, _ = forward(params, cfg, tokens, prefix_embeds, encoder_frames)
    logits = unembed(params, cfg, h[:, -1:])
    # NOTE: cache filling for attention families happens in serve.engine via
    # per-layer k/v recomputation; the dry-run decode path starts from a
    # fully-populated cache shape, which is what matters for compilation.
    state = dict(state)
    state["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, state


def decode_step(params, cfg: ModelConfig, state: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch. tokens: [B, 1]."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = ax(x, "batch", None, "embed")
    pos = state["pos"]
    positions = pos[None]
    new_state = dict(state)
    windows = window_array(cfg)
    eps = cfg.norm_eps

    T_tail = state["k_tail"].shape[2] if "k_tail" in state else 0
    tail_ix = jnp.mod(pos, jnp.int32(max(T_tail, 1)))
    base = pos - tail_ix

    def attn_decode(p_l, h, pages, tail, win):
        q, k, v = attn_project_qkv(p_l, h, positions, cfg.rope_theta)
        k_tail = jax.lax.dynamic_update_slice_in_dim(
            tail[0], k, tail_ix, axis=1)
        v_tail = jax.lax.dynamic_update_slice_in_dim(
            tail[1], v, tail_ix, axis=1)
        spec = AttnSpec(causal=True, logit_cap=cfg.attn_softcap)
        if cfg.kv_quant:
            kq, ks, vq, vs = pages
            o = decode_attention_paged_quant(
                q, kq, ks, vq, vs, k_tail, v_tail, pos, base, spec,
                window=win)
        else:
            o = decode_attention_paged(
                q, pages[0], pages[1], k_tail, v_tail, pos, base, spec,
                window=win)
        return attn_output(p_l, o), (k_tail, v_tail)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        win = windows if windows is not None else np.full(
            cfg.n_layers, _BIG_WINDOW, np.int32)
        nd = cfg.moe.first_k_dense if cfg.family == "moe" else 0

        def pages_of(sl):
            if cfg.kv_quant:
                return (state["k"][sl], state["k_scale"][sl],
                        state["v"][sl], state["v_scale"][sl])
            return (state["k"][sl], state["v"][sl])

        def body(xc, scanned):
            if cfg.family == "audio":
                p_l, pages, tail, ck, cv, w = scanned
            else:
                p_l, pages, tail, w = scanned
            h = rms_norm(xc, p_l["ln1"], eps)
            a, tail = attn_decode(p_l["attn"], h, pages, tail, w)
            xc = xc + a
            if cfg.family == "audio":
                h = rms_norm(xc, p_l["ln_c"], eps)
                q = jnp.einsum("bsd,dhx->bshx", h, p_l["cross"]["wq"])
                spec = AttnSpec(causal=False)
                o = decode_attention(q, ck, cv, jnp.int32(_BIG_WINDOW), spec,
                                     window=None)
                xc = xc + attn_output(p_l["cross"], o)
            h = rms_norm(xc, p_l["ln2"], eps)
            if cfg.family == "moe":
                y, _ = moe_apply(p_l["moe"], h, cfg)
            else:
                y = mlp_apply(p_l["mlp"], h, cfg.act)
            xc = xc + y
            return xc, tail

        sl_d, sl_m = slice(0, nd), slice(nd, None)
        if nd:  # deepseek: leading dense layers, separate scanned stack
            def dbody(xc, scanned):
                p_l, pages, tail, w = scanned
                h = rms_norm(xc, p_l["ln1"], eps)
                a, tail = attn_decode(p_l["attn"], h, pages, tail, w)
                xc = xc + a
                h = rms_norm(xc, p_l["ln2"], eps)
                return xc + mlp_apply(p_l["mlp"], h, cfg.act), tail
            x, (ktd, vtd) = jax.lax.scan(
                dbody, x, (params["dense_blocks"], pages_of(sl_d),
                           (state["k_tail"][sl_d], state["v_tail"][sl_d]),
                           win[:nd]))
        if cfg.family == "audio":
            x, (ktn, vtn) = jax.lax.scan(
                body, x, (params["blocks"], pages_of(sl_m),
                          (state["k_tail"][sl_m], state["v_tail"][sl_m]),
                          state["cross_k"], state["cross_v"], win[nd:]))
        else:
            x, (ktn, vtn) = jax.lax.scan(
                body, x, (params["blocks"], pages_of(sl_m),
                          (state["k_tail"][sl_m], state["v_tail"][sl_m]),
                          win[nd:]))
        if nd:
            ktn = jnp.concatenate([ktd, ktn], axis=0)
            vtn = jnp.concatenate([vtd, vtn], axis=0)
        new_state["k_tail"], new_state["v_tail"] = ktn, vtn

    elif cfg.family == "hybrid":
        hpages = (
            (state["k"], state["k_scale"], state["v"], state["v_scale"])
            if cfg.kv_quant
            else (state["k"], state["v"])
        )

        def body(xc, scanned):
            p_l, pages, tail, hm, w = scanned
            h = rms_norm(xc, p_l["ln1"], eps)
            a, tail = attn_decode(p_l["attn"], h, pages, tail, w)
            s, hm = ssm_lib.mamba_decode_step(p_l["mamba"], h, hm, cfg)
            fused = 0.5 * (rms_norm(a, p_l["ln_attn"], eps) +
                           rms_norm(s, p_l["ln_ssm"], eps))
            xc = xc + fused
            h = rms_norm(xc, p_l["ln2"], eps)
            xc = xc + mlp_apply(p_l["mlp"], h, cfg.act)
            return xc, (tail[0], tail[1], hm)
        x, (ktn, vtn, hn) = jax.lax.scan(
            body, x, (params["blocks"], hpages,
                      (state["k_tail"], state["v_tail"]),
                      state["mamba"], windows))
        new_state.update(k_tail=ktn, v_tail=vtn, mamba=hn)

    elif cfg.family == "ssm":
        def gbody(xc, scanned):
            p_g, mst, sst = scanned

            def mbody(xm, sc):
                p_l, ln, st_l = sc
                h = rms_norm(xm, ln, eps)
                y, st_n = ssm_lib.mlstm_decode_step(p_l, h, st_l, cfg)
                return xm + y, st_n
            xc, mst_n = jax.lax.scan(
                mbody, xc, (p_g["mlstm"], p_g["mlstm_ln"], mst))
            h = rms_norm(xc, p_g["slstm_ln"], eps)
            y, sst_n = ssm_lib.slstm_decode_step(p_g["slstm"], h, sst, cfg)
            return xc + y, (mst_n, sst_n)
        x, (mn, sn) = jax.lax.scan(
            gbody, x, (params["blocks"], state["mlstm"], state["slstm"]))
        new_state.update(mlstm=mn, slstm=sn)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], eps)
    logits = unembed(params, cfg, x)
    new_state["pos"] = pos + 1
    return logits, new_state


def flush_tail(cfg: ModelConfig, state: Dict[str, Any]) -> Dict[str, Any]:
    """Commit the write tail into the (sharded, quantized) pages.

    Called every ``decode_tail`` steps by the engine — the only operation
    that touches the sequence-sharded pages, amortizing the resharding cost
    by T_tail (and quantizing the block with per-(token, head) scales when
    ``cfg.kv_quant``)."""
    if "k_tail" not in state:
        return state
    out = dict(state)
    pos = state["pos"]
    T = state["k_tail"].shape[2]
    n_tail = jnp.mod(pos, jnp.int32(T))
    n_tail = jnp.where(n_tail == 0, jnp.where(pos > 0, T, 0), n_tail)
    base = pos - n_tail
    kt, vt = state["k_tail"], state["v_tail"]
    if cfg.kv_quant:
        def q(x):
            xf = x.astype(jnp.float32)
            sc = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-8
            qx = jnp.clip(jnp.round(xf / sc[..., None]), -127,
                          127).astype(jnp.int8)
            return qx, sc
        kq, ks = q(kt)
        vq, vs = q(vt)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(
            state["k"], kq, base, axis=2)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(
            state["v"], vq, base, axis=2)
        out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            state["k_scale"], ks, base, axis=2)
        out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            state["v_scale"], vs, base, axis=2)
    else:
        out["k"] = jax.lax.dynamic_update_slice_in_dim(
            state["k"], kt, base, axis=2)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(
            state["v"], vt, base, axis=2)
    return out
