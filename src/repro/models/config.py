"""Model configuration: one dataclass describes every supported family.

Families: dense decoder LMs (GQA/RoPE), MoE, encoder-decoder (whisper),
VLM (stub frontend + dense LM), SSM (xLSTM) and hybrid (attention ∥ SSM).
A config is pure data; ``repro.models.transformer`` interprets it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int              # routed experts
    top_k: int
    n_shared: int = 0           # always-on shared experts (DeepSeekMoE)
    d_expert: int = 0           # expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25
    first_k_dense: int = 0      # leading dense layers (DeepSeekMoE uses 1)
    aux_loss_weight: float = 0.01
    quantize_dispatch: bool = False  # int8 expert all-to-all (§Perf)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"          # 'mamba' (hymba) | 'xlstm'
    d_state: int = 16
    conv_width: int = 4          # depthwise conv in mamba blocks (stub: 1x1)
    mlstm_per_slstm: int = 7     # xLSTM [7:1] block ratio
    chunk: int = 256             # chunkwise-parallel scan length


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_ctx: int                   # encoder positions (whisper-tiny: 1500)
    d_model: int = 0             # 0 -> same as decoder


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    act: str = "swiglu"          # swiglu | relu2 | gelu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale

    # attention pattern
    attn_pattern: str = "global"  # global | local_global | local_mostly
    window: int = 4096            # sliding-window size for local layers
    attn_softcap: float = 0.0     # gemma2 attention logit softcap
    final_softcap: float = 0.0    # gemma2 final logit softcap

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None   # 'audio' | 'vision' (stub embeddings)
    n_prefix: int = 0                # frontend embedding positions in the seq

    # substrate knobs (overridable per run)
    dtype: str = "bfloat16"
    remat: str = "block"             # none | block | full
    scan_layers: bool = True
    decode_tail: int = 256           # replicated KV write-tail length
    kv_quant: bool = False           # int8 semantic KV pages (§Perf)
    attn_f32_scores: bool = True     # f32 score chunks (False: bf16, §Perf)
    attn_q_block: int = 1024         # chunked-attention query tile
    attn_kv_chunk: int = 1024        # chunked-attention KV tile

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_is_local(self, i: int) -> bool:
        if self.attn_pattern == "local_global":
            return i % 2 == 0
        if self.attn_pattern == "local_mostly":
            # hymba: global attention only at first / middle / last layer
            return i not in (0, self.n_layers // 2, self.n_layers - 1)
        return False

    def sub_quadratic(self) -> bool:
        """Whether long-context decode (500k) is supported (DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        total = emb + L * per_layer
        if self.moe is not None:
            de = self.moe.d_expert or self.d_ff
            ff_mult = 3 if self.act == "swiglu" else 2
            expert = ff_mult * d * de
            n_moe = L - self.moe.first_k_dense
            total = (
                emb
                + L * (attn + 2 * d)
                + self.moe.first_k_dense * mlp
                + n_moe * (
                    (self.moe.n_experts + self.moe.n_shared) * expert
                    + d * self.moe.n_experts
                )
            )
        if self.family == "ssm":
            # xLSTM blocks replace attn+mlp with gated recurrent projections
            total = emb + L * (8 * d * d // 2 + 2 * d)
        if self.family == "hybrid" and self.ssm is not None:
            total += L * (2 * d * self.ssm.d_state + d)
        if self.encoder is not None:
            enc_d = self.encoder.d_model or d
            total += self.encoder.n_layers * (4 * enc_d * enc_d + 2 * enc_d * self.d_ff)
            total += L * (2 * d * hd * self.n_kv_heads + d * hd * self.n_heads)
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE uses top_k + shared experts."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        de = self.moe.d_expert or self.d_ff
        ff_mult = 3 if self.act == "swiglu" else 2
        active_ff = (self.moe.top_k + self.moe.n_shared) * ff_mult * d * de
        return int(emb + L * (attn + 2 * d + active_ff +
                              d * self.moe.n_experts))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applies(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "long_500k needs sub-quadratic attention (DESIGN.md §6)"
    return True, ""
