"""Core layers: RMSNorm, RoPE, chunked (flash-style) attention, MLPs.

Attention never materializes the [S, S] score matrix: queries are processed
in blocks and keys/values are scanned in chunks with an online softmax
(Rabe–Staats / FlashAttention schedule), which is also the natural TPU
formulation (VMEM-sized tiles).  Local (sliding-window), global, causal and
cross attention all share one code path, with masks computed from position
arithmetic per (q-block, kv-chunk) tile — never stored whole.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

_NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # [..,S,1,half]
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# ---------------------------------------------------------------------------
# Chunked attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    logit_cap: float = 0.0
    q_block: int = 1024
    kv_chunk: int = 1024
    f32_scores: bool = True   # False: bf16 score/prob chunks (§Perf — halves
    #                           the S²-sized HBM traffic; max/sum stay f32)


def _tile_mask(q_pos, k_pos, spec: AttnSpec, kv_len_valid,
               window) -> jax.Array:
    """[bq, bk] mask for one tile, from position arithmetic only.

    ``window`` may be a *traced* scalar (per-layer data inside a scanned
    stack: local layers pass their window, global layers a huge value), or
    None to skip window masking statically.
    """
    m = k_pos[None, :] < kv_len_valid
    if spec.causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_positions: jax.Array, spec: AttnSpec,
                      window=None,
                      kv_len_valid: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention.

    q: [B, Sq, H, D]; k/v: [B, Sk, K, D] with H = G*K (GQA).
    q_positions: [Sq] absolute positions of the queries (decode offsets).
    window: optional (possibly traced) sliding-window size.
    kv_len_valid: number of valid KV entries (decode caches), default Sk.
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = D ** -0.5
    qb = min(spec.q_block, Sq)
    kc = min(spec.kv_chunk, Sk)
    n_qb = -(-Sq // qb)
    n_kc = -(-Sk // kc)
    if kv_len_valid is None:
        kv_len_valid = jnp.int32(Sk)

    # pad Sq / Sk to multiples of the tiles
    q = jnp.pad(q, ((0, 0), (0, n_qb * qb - Sq), (0, 0), (0, 0)))
    qp = jnp.pad(q_positions, (0, n_qb * qb - Sq))
    k = jnp.pad(k, ((0, 0), (0, n_kc * kc - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, n_kc * kc - Sk), (0, 0), (0, 0)))

    # [B, n_qb, qb, K, G, D] query tiles grouped per kv head
    qt = q.reshape(B, n_qb, qb, K, G, D)
    qpt = qp.reshape(n_qb, qb)
    kt = k.reshape(B, n_kc, kc, K, D)
    vt = v.reshape(B, n_kc, kc, K, D)

    def q_tile(qi, q_pos_tile):
        """qi: [B, qb, K, G, D]; returns [B, qb, K, G, D]."""
        acc0 = jnp.zeros((B, qb, K, G, D), jnp.float32)
        m0 = jnp.full((B, qb, K, G), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb, K, G), jnp.float32)

        def kv_step(carry, inp):
            acc, m, lsum = carry
            kc_i, vc_i, kidx = inp
            k_pos = kidx * kc + jnp.arange(kc)
            mask = _tile_mask(q_pos_tile, k_pos, spec, kv_len_valid, window)
            if spec.f32_scores:
                s = jnp.einsum("bqkgd,bckd->bqkgc", qi.astype(jnp.float32),
                               kc_i.astype(jnp.float32)) * scale
                s = softcap(s, spec.logit_cap)
                s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                pv = jnp.einsum("bqkgc,bckd->bqkgd", p,
                                vc_i.astype(jnp.float32))
                l_add = p.sum(axis=-1)
            else:
                # bf16 score chunks end-to-end: the only S²-sized buffers
                # (s, p) are bf16; reductions accumulate f32 on the fly.
                s = jnp.einsum("bqkgd,bckd->bqkgc",
                               (qi.astype(jnp.float32) * scale
                                ).astype(jnp.bfloat16),
                               kc_i.astype(jnp.bfloat16),
                               preferred_element_type=jnp.bfloat16)
                s = softcap(s, spec.logit_cap)
                s = jnp.where(mask[None, :, None, None, :], s,
                              jnp.bfloat16(_NEG_INF))
                m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
                p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]
                            ).astype(jnp.bfloat16)
                pv = jnp.einsum("bqkgc,bckd->bqkgd", p,
                                vc_i.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
                l_add = jnp.sum(p, axis=-1, dtype=jnp.float32)
            corr = jnp.exp(m - m_new)
            lsum = lsum * corr + l_add
            acc = acc * corr[..., None] + pv
            return (acc, m_new, lsum), None

        (acc, m, lsum), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kt, 1, 0), jnp.moveaxis(vt, 1, 0),
             jnp.arange(n_kc)))
        return acc / jnp.maximum(lsum[..., None], 1e-30)

    out = jax.lax.map(lambda args: q_tile(*args),
                      (jnp.moveaxis(qt, 1, 0), qpt))   # [n_qb, B, qb, K, G, D]
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_qb * qb, H, D)
    return out[:, :Sq].astype(v.dtype)


def _partial_decode_attn(q4, k, v, k_pos, position, spec: AttnSpec,
                         window, valid_extra=None):
    """Unnormalized online-softmax piece over one KV buffer.

    q4: [B, K, G, D] (pre-scaled); k/v: [B, S, K, D] (any dtype; int8 KV is
    dequantized by the caller folding scales into q or p).
    Returns (m [B,K,G], l [B,K,G], acc [B,K,G,D]) in float32.
    """
    s = jnp.einsum("bkgd,bskd->bkgs", q4, k.astype(jnp.float32))
    s = softcap(s, spec.logit_cap)
    valid = k_pos <= position
    if window is not None:
        valid &= k_pos > position - window
    valid = valid[None, None, None, :]
    if valid_extra is not None:
        valid &= valid_extra[None, None, None, :]
    s = jnp.where(valid, s, _NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    lsum = p.sum(axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return m, lsum, acc


def merge_partial_attn(parts):
    """Combine (m, l, acc) pieces into the final [B, K, G, D] output."""
    m = parts[0][0]
    for p in parts[1:]:
        m = jnp.maximum(m, p[0])
    l_tot = 0.0
    acc_tot = 0.0
    for (mi, li, acci) in parts:
        c = jnp.exp(mi - m)
        l_tot = l_tot + li * c
        acc_tot = acc_tot + acci * c[..., None]
    return acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]


def decode_attention_paged(q: jax.Array, k_pages, v_pages, k_tail, v_tail,
                           position: jax.Array, base: jax.Array,
                           spec: AttnSpec, window=None) -> jax.Array:
    """Decode attention over (sequence-sharded pages, replicated tail).

    The single-token write lands in the small replicated tail; pages are
    immutable between flushes, so no sharded in-place update appears in the
    step (the GSPMD full-rematerialization trap, EXPERIMENTS.md §Perf).
    Pages hold positions [0, base); the tail holds [base, base+T).
    """
    B, _, H, D = q.shape
    K = k_pages.shape[2]
    G = H // K
    q4 = q.reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    S = k_pages.shape[1]
    page_pos = jnp.arange(S)
    in_pages = page_pos < base
    mp, lp, accp = _partial_decode_attn(
        q4, k_pages, v_pages, page_pos, position, spec, window, in_pages)
    T = k_tail.shape[1]
    tail_pos = base + jnp.arange(T)
    mt, lt, acct = _partial_decode_attn(
        q4, k_tail, v_tail, tail_pos, position, spec, window)
    o = merge_partial_attn([(mp, lp, accp), (mt, lt, acct)])
    return o.reshape(B, 1, H, D).astype(v_tail.dtype)


def _partial_decode_attn_quant(q4, kq, ks, vq, vs, k_pos, position,
                               spec: AttnSpec, window, valid_extra=None):
    """int8-KV variant: scales folded into scores/probabilities in-flight.

    Pages dequantize to bf16 (not f32 — halves the conversion-buffer HBM
    traffic, §Perf iteration 4); accumulation stays f32 via
    preferred_element_type.
    """
    s = jnp.einsum("bkgd,bskd->bkgs", q4.astype(jnp.bfloat16),
                   kq.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    s = s * jnp.moveaxis(ks, 1, 2)[:, :, None, :]      # [B,K,1,S]
    s = softcap(s, spec.logit_cap)
    valid = k_pos <= position
    if window is not None:
        valid &= k_pos > position - window
    valid = valid[None, None, None, :]
    if valid_extra is not None:
        valid &= valid_extra[None, None, None, :]
    s = jnp.where(valid, s, _NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    lsum = p.sum(axis=-1)
    pv = p * jnp.moveaxis(vs, 1, 2)[:, :, None, :]
    acc = jnp.einsum("bkgs,bskd->bkgd", pv.astype(jnp.bfloat16),
                     vq.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return m, lsum, acc


def decode_attention_paged_quant(q, kq_pages, ks_pages, vq_pages, vs_pages,
                                 k_tail, v_tail, position, base,
                                 spec: AttnSpec, window=None) -> jax.Array:
    """Paged decode attention with int8 semantically-quantized pages.

    Page HBM traffic halves (int8 + per-(token, head) scales vs bf16); the
    hot tail stays bf16 so the running write path is unchanged.
    """
    B, _, H, D = q.shape
    K = kq_pages.shape[2]
    G = H // K
    q4 = q.reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    S = kq_pages.shape[1]
    page_pos = jnp.arange(S)
    in_pages = page_pos < base
    mp, lp, accp = _partial_decode_attn_quant(
        q4, kq_pages, ks_pages, vq_pages, vs_pages, page_pos, position, spec,
        window, in_pages)
    T = k_tail.shape[1]
    tail_pos = base + jnp.arange(T)
    mt, lt, acct = _partial_decode_attn(
        q4, k_tail, v_tail, tail_pos, position, spec, window)
    o = merge_partial_attn([(mp, lp, accp), (mt, lt, acct)])
    return o.reshape(B, 1, H, D).astype(v_tail.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     position: jax.Array, spec: AttnSpec,
                     window=None) -> jax.Array:
    """Single-token attention over a KV cache.

    q: [B, 1, H, D]; caches: [B, S, K, D]; position: [] current index.
    ``window`` may be traced per-layer data (see chunked_attention).
    """
    B, _, H, D = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    qf = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32)) * (D ** -0.5)
    s = softcap(s, spec.logit_cap)
    k_pos = jnp.arange(S)
    valid = k_pos <= position
    if window is not None:
        valid &= k_pos > position - window
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_apply(p, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = jax.nn.silu(g) * h
    elif act == "relu2":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    p = {"wi": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
         "wo": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}
    if act == "swiglu":
        p["wg"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


# ---------------------------------------------------------------------------
# Attention parameter block
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = (1.0 / d) ** 0.5
    so = (1.0 / (H * hd)) ** 0.5
    return {
        "wq": jax.random.normal(ks[0], (d, H, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, K, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, K, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H, hd, d), dtype) * so,
    }


def attn_project_qkv(p, x: jax.Array, positions, theta: float,
                     use_rope: bool = True):
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def attn_output(p, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshx,hxd->bsd", o, p["wo"])
