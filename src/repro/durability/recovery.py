"""Crash recovery: checkpoint load + WAL tail replay (DESIGN.md §7).

:func:`open_database` rebuilds a durable :class:`~repro.db.Database` from
its on-disk root:

1. load the checkpoint (``None`` on missing/corrupt — full replay then);
2. restore every checkpointed table bit-identically from its snapshot
   (pickled codec versions, embedded spill payloads, pk directory) and
   replay only its WAL tail past the recorded LSN;
3. any ``*.wal`` the checkpoint doesn't know about is a table created
   after the last checkpoint: replay it from zero, starting with its
   ``create`` record (seeded model fits make the rebuild deterministic).

Replay drives the exact same batched verbs as live traffic, under
``wal.suspend()`` so nothing is re-logged.  Checkpoints are inhibited
until recovery completes — a mid-replay snapshot would pair a prefix
state with a full-tail LSN.
"""

from __future__ import annotations

import os
from typing import Any, Optional

from repro.core.arena import SpillCorruptionError
from repro.db.database import Database
from repro.db.table import Table

from .checkpoint import load_checkpoint
from .config import DurabilityConfig
from .wal import WriteAheadLog


def _replay(table: Table, wal: WriteAheadLog, from_lsn: int) -> int:
    """Re-apply every record past ``from_lsn``; returns records replayed."""
    n = 0
    key_of = table.schema.key_of
    with wal.suspend():
        for _lsn, op, payload in wal.scan(from_lsn):
            if op == "insert":
                table.insert_many(payload)
            elif op == "update":
                table.update_many([key_of(r) for r in payload], payload)
            elif op == "delete":
                table.delete_many(payload)
            elif op != "create":
                raise ValueError(f"{wal.path}: unknown WAL op {op!r}")
            n += 1
    return n


def _rebuild_from_log(db: Database, wal: WriteAheadLog) -> bool:
    """Rebuild one table from its WAL's full history, starting at the
    ``create`` record that heads every log.  Returns False when nothing
    durable ever reached the log (the create itself was lost)."""
    first = next(wal.scan(0), None)
    if first is None or first[1] != "create":
        wal.close()
        return False
    lsn, _op, meta = first
    kwargs = dict(meta["store_kwargs"])
    kwargs["spill_io"] = db._io
    table = Table(
        meta["schema"],
        backend=meta["backend"],
        n_shards=meta["n_shards"],
        sample_rows=meta["sample_rows"],
        store_kwargs=kwargs,
        memory_budget=meta["memory_budget"],
    )
    db.adopt_table(table, wal)
    _replay(table, wal, lsn)
    return True


def open_database(
    root: str,
    io: Optional[Any] = None,
    fsync_every: int = 1,
    checkpoint_every_ops: int = 0,
    checkpoint_on_maintenance: bool = True,
) -> Database:
    """Recover the durable database at ``root``.

    Safe on a fresh or empty root (returns an empty durable database) and
    idempotent: recovering twice yields the same state, because replay
    never appends to the log it reads.
    """
    cfg = DurabilityConfig(
        root=os.fspath(root),
        fsync_every=fsync_every,
        checkpoint_every_ops=checkpoint_every_ops,
        checkpoint_on_maintenance=checkpoint_on_maintenance,
        io=io,
    )
    ck = load_checkpoint(cfg.root)
    engine = (ck or {}).get("engine") or {}
    db = Database(
        backend=engine.get("backend") or "blitzcrank",
        n_shards=engine.get("n_shards", 1),
        store_kwargs=engine.get("store_kwargs") or {},
        memory_budget=engine.get("memory_budget"),
        durability=cfg,
    )
    with db.recovery_mode():
        if ck:
            for name, entry in ck["tables"].items():
                wal = WriteAheadLog(
                    os.path.join(cfg.root, f"{name}.wal"),
                    io=db._io,
                    fsync_every=fsync_every,
                )
                try:
                    table = Table.from_snapshot(entry["snapshot"], spill_io=db._io)
                    db.adopt_table(table, wal)
                    _replay(table, wal, entry["wal_lsn"])
                except SpillCorruptionError:
                    # An extent-mode checkpoint references spill-file
                    # ranges that a post-checkpoint disk compaction moved
                    # (or the crash tore).  The WAL keeps full history
                    # exactly for this: drop the snapshot and rebuild the
                    # table from its create record forward.
                    db.discard_table(name)
                    _rebuild_from_log(db, wal)
        for fn in sorted(os.listdir(cfg.root)):
            if not fn.endswith(".wal") or fn[:-4] in db:
                continue
            # a table created after the last checkpoint: nothing but its
            # log exists, so replay it from zero
            wal = WriteAheadLog(
                os.path.join(cfg.root, fn), io=db._io, fsync_every=fsync_every
            )
            _rebuild_from_log(db, wal)
    db.reset_checkpoint_clock()
    return db
