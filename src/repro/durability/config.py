"""Durability configuration shared by Database, WAL, and checkpointing."""

from __future__ import annotations

import dataclasses
from typing import Optional

from .io import DurableIO


@dataclasses.dataclass
class DurabilityConfig:
    """Knobs for a durable :class:`~repro.db.Database` (DESIGN.md §7).

    ``root`` is the directory holding one WAL per table plus the
    checkpoint file.  ``fsync_every`` is the group-commit cadence: fsync
    after every N-th WAL flush (1 = every batch verb, 0 = never — the OS
    decides).  ``checkpoint_every_ops`` > 0 auto-checkpoints after that
    many logged rows; ``checkpoint_on_maintenance`` piggybacks a
    checkpoint request on every adaptive maintenance step (the refit
    already paid for a full pass over the store, so snapshotting then is
    nearly free and keeps replay short).  ``io`` lets tests plug in a
    fault-injecting :class:`~repro.durability.io.DurableIO`.
    """

    root: str
    fsync_every: int = 1
    checkpoint_every_ops: int = 0
    checkpoint_on_maintenance: bool = True
    io: Optional[DurableIO] = None

    def make_io(self) -> DurableIO:
        return self.io if self.io is not None else DurableIO()
