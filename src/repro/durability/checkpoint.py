"""Atomic, checksummed whole-database checkpoints (DESIGN.md §7).

A checkpoint is one pickled state dict — per-table block indexes,
residency/extent tables (with spilled payloads materialized and
CRC-verified at snapshot time), pk directories, codec version lists, and
each WAL's LSN — framed as ``magic + len + crc32 + payload`` and written
tmp-file → fsync → atomic rename.  A crash at any point leaves either the
old checkpoint or the new one, never a torn hybrid; a corrupt or missing
checkpoint simply falls back to full WAL replay, trading recovery time
for zero data loss.
"""

from __future__ import annotations

import os
import pickle
import struct
import zlib
from typing import Any, Optional

from repro.core.arena import OS_IO

CHECKPOINT_MAGIC = b"BZCKPT01"
CHECKPOINT_HEADER = struct.Struct("<II")


def checkpoint_path(root: str) -> str:
    return os.path.join(root, "checkpoint.bin")


def write_checkpoint(root: str, state: Any, io: Optional[Any] = None) -> int:
    """Serialize ``state`` and atomically replace the checkpoint file.

    Returns the byte size written.  Crash points: ``checkpoint.before``
    (nothing written), ``checkpoint.mid`` (torn tmp file — harmless, the
    rename never happened), ``checkpoint.post`` (new checkpoint fully
    live).
    """
    io = io if io is not None else OS_IO
    payload = pickle.dumps(state, protocol=4)
    buf = (
        CHECKPOINT_MAGIC
        + CHECKPOINT_HEADER.pack(len(payload), zlib.crc32(payload))
        + payload
    )
    io.point("checkpoint.before")
    tmp = os.path.join(root, "checkpoint.tmp")
    fd = os.open(tmp, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o644)
    try:
        half = len(buf) // 2
        io.pwrite(fd, buf[:half], 0)
        io.point("checkpoint.mid")
        io.pwrite(fd, buf[half:], half)
        io.fsync(fd)
    finally:
        os.close(fd)
    os.replace(tmp, checkpoint_path(root))
    io.point("checkpoint.post")
    return len(buf)


def load_checkpoint(root: str) -> Optional[Any]:
    """Load and verify the checkpoint; ``None`` on missing/corrupt file.

    Any failure mode — absent file, bad magic, short payload, CRC
    mismatch, unpicklable body — degrades to "no checkpoint", which the
    recovery path answers with full WAL replay.
    """
    try:
        with open(checkpoint_path(root), "rb") as f:
            buf = f.read()
    except FileNotFoundError:
        return None
    head = len(CHECKPOINT_MAGIC)
    if len(buf) < head + CHECKPOINT_HEADER.size:
        return None
    if not buf.startswith(CHECKPOINT_MAGIC):
        return None
    ln, crc = CHECKPOINT_HEADER.unpack_from(buf, head)
    body = buf[head + CHECKPOINT_HEADER.size :]
    if len(body) != ln or zlib.crc32(body) != crc:
        return None
    try:
        return pickle.loads(body)
    except Exception:
        return None
