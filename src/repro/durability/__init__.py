"""Durability layer: WAL + checksummed spill + checkpoint + fault injection.

See DESIGN.md §7.  ``Database(durability=...)`` turns the layer on;
``Database.open(root)`` (or :func:`open_database`) recovers a database
from its checkpoint and WAL tail after a crash.
"""

from typing import TYPE_CHECKING, Any

from .checkpoint import load_checkpoint, write_checkpoint
from .config import DurabilityConfig
from .io import DurableIO, FaultInjector, SimulatedCrash
from .wal import WalError, WalPoisonedError, WriteAheadLog

if TYPE_CHECKING:
    from repro.db.database import Database

__all__ = [
    "DurabilityConfig",
    "DurableIO",
    "FaultInjector",
    "SimulatedCrash",
    "WalError",
    "WalPoisonedError",
    "WriteAheadLog",
    "load_checkpoint",
    "write_checkpoint",
    "open_database",
]


def open_database(root: str, **kwargs: Any) -> "Database":
    """Recover a durable database from ``root`` (lazy import of recovery)."""
    from .recovery import open_database as _open

    return _open(root, **kwargs)
