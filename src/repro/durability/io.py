"""Deterministic fault injection for durability I/O (DESIGN.md §7).

Every byte the durability layer moves — WAL appends, spill segments,
checkpoint files — flows through a :class:`DurableIO` shim implementing the
same four-method protocol ``DiskArena`` expects (``pwrite`` / ``pread`` /
``fsync`` / ``point``).  With no :class:`FaultInjector` attached the shim is
a transparent passthrough; with one, two deterministic mechanisms arm:

* **Named crash points** — ``crash_at("wal.before_flush")`` raises
  :class:`SimulatedCrash` the n-th time execution reaches that point,
  simulating a process kill at a precisely chosen instant.  The crash-point
  catalog lives in :data:`repro.durability.harness.CRASH_POINTS`.
* **Queued I/O faults** — ``add_fault("pread", "bitflip")`` corrupts the
  next read; short reads, torn writes, ENOSPC, and failed fsync are queued
  the same way.  Faults drain FIFO per operation, so a scenario is a pure
  function of (seed, schedule), replayable forever.
"""

from __future__ import annotations

import errno
import os
import random
from typing import Dict, List, Optional

FAULT_OPS = ("pwrite", "pread", "fsync")
FAULT_KINDS = ("enospc", "torn", "short", "bitflip", "eio")


class SimulatedCrash(BaseException):
    """Raised at an armed crash point to simulate a process kill.

    Derives from ``BaseException`` so ordinary ``except Exception``
    cleanup code cannot accidentally swallow the "kill" — only the
    crash-matrix harness (and tests) catch it.
    """

    def __init__(self, point: str) -> None:
        super().__init__(f"simulated crash at {point!r}")
        self.point = point


class FaultInjector:
    """Seedable source of crashes and I/O faults.

    ``crash_at(point, hit=n)`` arms a named crash point to fire on its
    n-th visit.  ``add_fault(op, kind)`` queues a fault for the next
    matching I/O call.  ``fired`` records everything that actually
    triggered, so tests can assert a fault was exercised rather than
    silently skipped.
    """

    def __init__(self, seed: int = 0) -> None:
        self.rng = random.Random(int(seed))
        self._crash: Dict[str, int] = {}
        self._faults: Dict[str, List[str]] = {op: [] for op in FAULT_OPS}
        self.fired: List[str] = []
        self.points_seen: List[str] = []

    def crash_at(self, point: str, hit: int = 1) -> None:
        if hit < 1:
            raise ValueError("hit must be >= 1")
        self._crash[point] = int(hit)

    def add_fault(self, op: str, kind: str, count: int = 1) -> None:
        if op not in FAULT_OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {FAULT_OPS}")
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault {kind!r}; expected one of {FAULT_KINDS}"
            )
        self._faults[op].extend([kind] * int(count))

    # -- hooks called by DurableIO ---------------------------------------
    def on_point(self, name: str) -> None:
        self.points_seen.append(name)
        left = self._crash.get(name)
        if left is None:
            return
        left -= 1
        if left <= 0:
            del self._crash[name]
            self.fired.append(f"crash:{name}")
            raise SimulatedCrash(name)
        self._crash[name] = left

    def take(self, op: str) -> Optional[str]:
        queue = self._faults[op]
        if not queue:
            return None
        kind = queue.pop(0)
        self.fired.append(f"{op}:{kind}")
        return kind


def _flip_byte(buf: bytes, pos: int) -> bytes:
    return buf[:pos] + bytes([buf[pos] ^ 0x40]) + buf[pos + 1 :]


class DurableIO:
    """The I/O provider durability code plugs into ``DiskArena``/WAL.

    Implements the four-method protocol of
    :class:`repro.core.arena._OsIO`; with an injector attached, queued
    faults and armed crash points fire deterministically.
    """

    def __init__(self, injector: Optional[FaultInjector] = None) -> None:
        self.injector = injector

    def point(self, name: str) -> None:
        if self.injector is not None:
            self.injector.on_point(name)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        fault = self.injector.take("pwrite") if self.injector else None
        data = bytes(data)
        if fault == "enospc":
            raise OSError(errno.ENOSPC, os.strerror(errno.ENOSPC))
        if fault == "eio":
            raise OSError(errno.EIO, os.strerror(errno.EIO))
        if fault == "torn":
            # A torn write is a crash mid-pwrite: the prefix lands, the
            # process dies.  The torn tail must be detected on reopen.
            os.pwrite(fd, data[: len(data) // 2], offset)
            raise SimulatedCrash("pwrite.torn")
        if fault == "bitflip" and data:
            data = _flip_byte(data, self.injector.rng.randrange(len(data)))
        return os.pwrite(fd, data, offset)

    def pread(self, fd: int, length: int, offset: int) -> bytes:
        fault = self.injector.take("pread") if self.injector else None
        if fault == "eio":
            raise OSError(errno.EIO, os.strerror(errno.EIO))
        buf = os.pread(fd, int(length), int(offset))
        if fault == "short" and buf:
            buf = buf[: len(buf) // 2]
        elif fault == "bitflip" and buf:
            buf = _flip_byte(buf, self.injector.rng.randrange(len(buf)))
        return buf

    def fsync(self, fd: int) -> None:
        fault = self.injector.take("fsync") if self.injector else None
        if fault == "eio":
            raise OSError(errno.EIO, os.strerror(errno.EIO))
        os.fsync(fd)
