"""Deterministic crash-matrix + corruption harness (DESIGN.md §7).

Every named crash point in :data:`CRASH_POINTS` is exercised the same way:
build a durable database, run a deterministic batch stream with periodic
checkpoints, arm the point, let :class:`SimulatedCrash` "kill" the
process, reopen via :func:`~repro.durability.recovery.open_database`, and
compare every key against a fresh in-memory reference database that
applied exactly the batches the durability contract says must survive:

* ``wal.before_flush`` fires before the verb's record hits the log, so
  the in-flight batch is **lost** — recovery must show the prior state;
* every other point fires after the record was pwritten, so the batch is
  **durable** — recovery must show it applied (fsync_every=1, and a
  simulated kill does not lose the page cache).

Verification reads run on both decode backends (numpy, and pallas when
jax is importable), so recovery correctness is checked against the
compiled kernel path too, not just the interpreter.

:func:`run_corruption_scenarios` covers the non-crash faults: spill-page
bit flips (repaired from the WAL, never served), WAL torn tails, a
corrupt checkpoint (degrades to full replay), ENOSPC, and a failed fsync
(poisoned log).  Run ``python -m repro.durability.harness --smoke`` for
the CI subset.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

from repro.db import Database, TableSchema
from repro.oltp import tpcc

from .config import DurabilityConfig
from .io import DurableIO, FaultInjector, SimulatedCrash
from .recovery import open_database
from .wal import WalPoisonedError

CRASH_POINTS = [
    "wal.before_flush",
    "wal.before_fsync",
    "wal.after_flush",
    "apply.before",
    "spill.mid_write",
    "checkpoint.before",
    "checkpoint.mid",
    "checkpoint.post",
]
# Crash here loses the in-flight batch; everywhere else it is durable.
BATCH_LOST = {"wal.before_flush"}

N_ROWS = 600
N_POP = 256  # initial population (doubles as the model-fit sample)


def _schema() -> TableSchema:
    return TableSchema("customer", tpcc.TABLES["customer"][0], "c_id")


def _batches(rows: List[Dict[str, Any]], schema: TableSchema,
             ) -> List[Tuple[str, Any]]:
    """A deterministic op stream over the tail rows: inserts of fresh
    keys, updates and deletes of populated ones."""
    out: List[Tuple[str, Any]] = []
    pop_keys = [schema.key_of(r) for r in rows[:N_POP]]
    nxt = N_POP
    for step in range(24):
        if step % 3 == 0 and nxt + 16 <= len(rows):
            out.append(("insert", rows[nxt:nxt + 16]))
            nxt += 16
        elif step % 3 == 1:
            # updates stay below 128: the delete batches drain [128, 192)
            lo = (step * 7) % 104
            ks = pop_keys[lo:lo + 24]
            out.append(("update",
                        [dict(rows[pop_keys.index(k)],
                              c_balance=1000.0 + step) for k in ks]))
        else:
            lo = 128 + (step * 3) % 64
            out.append(("delete", pop_keys[lo:lo + 4]))
    return out


def _apply(table, schema: TableSchema, op: str, payload: Any) -> None:
    if op == "insert":
        table.insert_many(payload)
    elif op == "update":
        table.update_many([schema.key_of(r) for r in payload], payload)
    else:
        table.delete_many(payload)


def _reference_state(backend: str, rows: List[Dict[str, Any]],
                     schema: TableSchema, n_batches: int,
                     store_kwargs: Optional[Dict[str, Any]],
                     memory_budget: Optional[int]) -> Database:
    """A fresh non-durable database that applied the expected prefix."""
    db = Database(backend=backend, store_kwargs=dict(store_kwargs or {}),
                  memory_budget=memory_budget)
    t = db.create_table(schema, sample_rows=rows[:N_POP])
    t.insert_many(rows[:N_POP])
    for op, payload in _batches(rows, schema)[:n_batches]:
        _apply(t, schema, op, payload)
    return db


def _compare(recovered: Database, reference: Database,
             schema: TableSchema, rows: List[Dict[str, Any]],
             backend: str) -> List[str]:
    """Bit-identity over every key, on every available decode backend."""
    keys = [schema.key_of(r) for r in rows]
    backends: List[Optional[str]] = [None]
    if backend == "blitzcrank":
        backends = ["numpy"]
        try:
            import jax  # noqa: F401
            backends.append("pallas")
        except ImportError:
            pass
    errs: List[str] = []
    for be in backends:
        got = recovered["customer"].get_many(keys, backend=be)
        want = reference["customer"].get_many(keys, backend=be)
        if got != want:
            bad = sum(1 for g, w in zip(got, want) if g != w)
            errs.append(f"backend={be}: {bad}/{len(keys)} rows differ")
    return errs


def run_crash_scenario(point: str, backend: str = "blitzcrank",
                       seed: int = 0, checkpoint_every: int = 7,
                       memory_budget: Optional[int] = None,
                       ) -> Dict[str, Any]:
    """Kill at ``point``, recover, verify.  Returns a result dict with
    ``ok`` (bit-identical), ``crashed`` (the point actually fired), and
    the batch counts."""
    if point not in CRASH_POINTS:
        raise ValueError(f"unknown crash point {point!r}")
    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    schema = _schema()
    store_kwargs: Dict[str, Any] = {}
    if memory_budget is None:
        # tight enough that the 256-row population spills under either
        # representation (~8 KB compressed arena, ~10x that raw)
        memory_budget = {"blitzcrank": 4 * 1024, "silo": 24 * 1024}.get(
            backend)
    budget = memory_budget if backend in ("blitzcrank", "silo") else None
    inj = FaultInjector(seed)
    root = tempfile.mkdtemp(prefix="blitz-crash-")
    try:
        cfg = DurabilityConfig(root=root, fsync_every=1, io=DurableIO(inj))
        db = Database(backend=backend, store_kwargs=dict(store_kwargs),
                      memory_budget=budget, durability=cfg)
        t = db.create_table(schema, sample_rows=rows[:N_POP])
        t.insert_many(rows[:N_POP])
        db.checkpoint()
        inj.crash_at(point)  # armed only now: the load must complete
        applied = 0
        crashed = False
        in_checkpoint = False
        try:
            for b, (op, payload) in enumerate(_batches(rows, schema)):
                _apply(t, schema, op, payload)
                applied += 1
                if (b + 1) % checkpoint_every == 0:
                    in_checkpoint = True
                    db.checkpoint()
                    in_checkpoint = False
        except SimulatedCrash as e:
            assert e.point == point
            crashed = True
        result: Dict[str, Any] = {"point": point, "backend": backend,
                                  "crashed": crashed, "applied": applied}
        if not crashed:
            # the workload never reached this point (e.g. no spill under a
            # large budget) — report it so the matrix can fail loudly
            result["ok"] = False
            result["errors"] = ["crash point never fired"]
            return result
        # the process is "dead": recover from disk only
        n_expected = applied
        if not in_checkpoint and point not in BATCH_LOST:
            n_expected += 1
        recovered = open_database(root)
        reference = _reference_state(backend, rows, schema, n_expected,
                                     store_kwargs, budget)
        errs = _compare(recovered, reference, schema, rows, backend)
        recovered.close()
        result["ok"] = not errs
        result["errors"] = errs
        result["expected_batches"] = n_expected
        return result
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run_crash_matrix(backends: Optional[List[str]] = None, seed: int = 0,
                     points: Optional[List[str]] = None,
                     verbose: bool = False) -> List[Dict[str, Any]]:
    backends = backends or ["blitzcrank", "silo"]
    points = points or CRASH_POINTS
    results = []
    for backend in backends:
        for point in points:
            if point == "spill.mid_write" and backend not in (
                    "blitzcrank", "silo"):
                continue
            r = run_crash_scenario(point, backend=backend, seed=seed)
            results.append(r)
            if verbose:
                status = "ok" if r["ok"] else f"FAIL {r['errors']}"
                print(f"  {backend:<10} {point:<22} {status}")
    return results


# -- non-crash fault scenarios -------------------------------------------

def run_corruption_scenarios(seed: int = 0,
                             verbose: bool = False) -> List[Dict[str, Any]]:
    """Checksum/fault coverage that doesn't fit the kill-reopen mold."""
    results = []
    for name, fn in [
        ("spill_bitflip_repair", _scenario_spill_bitflip),
        ("wal_torn_tail", _scenario_wal_torn_tail),
        ("checkpoint_corrupt_fallback", _scenario_checkpoint_corrupt),
        ("wal_enospc", _scenario_wal_enospc),
        ("fsync_eio_poisons", _scenario_fsync_eio),
    ]:
        errs = fn(seed)
        results.append({"scenario": name, "ok": not errs, "errors": errs})
        if verbose:
            print(f"  {name:<28} {'ok' if not errs else errs}")
    return results


def _durable_customer_db(
    root: str, rows: List[Dict[str, Any]], io: Optional[Any] = None
) -> Tuple[Database, Any, TableSchema]:
    schema = _schema()
    cfg = DurabilityConfig(root=root, fsync_every=1, io=io)
    db = Database(backend="blitzcrank", memory_budget=4 * 1024,
                  durability=cfg)
    t = db.create_table(schema, sample_rows=rows[:N_POP])
    t.insert_many(rows[:N_POP])
    return db, t, schema


def _scenario_spill_bitflip(seed: int) -> List[str]:
    """A flipped bit in a spilled extent is detected by its CRC, the rows
    rebuilt from the WAL, and reads stay bit-identical — never garbage."""
    import numpy as np

    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    root = tempfile.mkdtemp(prefix="blitz-corrupt-")
    try:
        db, t, schema = _durable_customer_db(root, rows)
        keys = [schema.key_of(r) for r in rows[:N_POP]]
        want = t.get_many(keys)  # captured pre-corruption (faults all in)
        tbl = t.shards[0].table
        spilled = np.flatnonzero(~tbl._resident[: tbl.n_blocks])
        if spilled.size < 4:
            return ["budget never spilled — scenario is vacuous"]
        errs = []
        # flip one payload byte in each of 4 spilled extents, on disk
        arena_fd = tbl._res.disk._fd
        for b in spilled[:4].tolist():
            off = int(tbl._disk_off[b]) + 12  # past the frame header
            byte = os.pread(arena_fd, 1, off)
            os.pwrite(arena_fd, bytes([byte[0] ^ 0x40]), off)
        got = t.get_many(keys)
        if got != want:
            errs.append("reads after corruption are not bit-identical")
        repairs = sum(s.repairs for s in t.shards)
        if not repairs:
            errs.append("corruption was never detected/repaired")
        if not tbl._res.quarantined:
            errs.append("corrupt extents were not quarantined")
        db.close()
        return errs
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _scenario_wal_torn_tail(seed: int) -> List[str]:
    """Garbage appended to the log (a torn final write) is truncated on
    reopen; every intact record replays."""
    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    root = tempfile.mkdtemp(prefix="blitz-torn-")
    try:
        db, t, schema = _durable_customer_db(root, rows)
        keys = [schema.key_of(r) for r in rows[:N_POP]]
        want = t.get_many(keys)
        db["customer"]._wal.close()
        wal_path = os.path.join(root, "customer.wal")
        with open(wal_path, "ab") as f:
            f.write(b"\x00\x01torn-frame-garbage")
        ck = os.path.join(root, "checkpoint.bin")
        if os.path.exists(ck):  # force the replay path through the tail
            os.unlink(ck)
        db2 = open_database(root)
        errs = []
        if db2["customer"]._wal.truncated_bytes == 0:
            errs.append("torn tail was not truncated")
        if db2["customer"].get_many(keys) != want:
            errs.append("replay after torn tail lost records")
        db2.close()
        return errs
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _scenario_checkpoint_corrupt(seed: int) -> List[str]:
    """A corrupt checkpoint is rejected by its CRC and recovery falls back
    to full WAL replay — same final state, just slower."""
    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    root = tempfile.mkdtemp(prefix="blitz-ckpt-")
    try:
        db, t, schema = _durable_customer_db(root, rows)
        keys = [schema.key_of(r) for r in rows[:N_POP]]
        want = t.get_many(keys)
        db.close()  # writes a checkpoint
        ck = os.path.join(root, "checkpoint.bin")
        buf = bytearray(open(ck, "rb").read())
        buf[len(buf) // 2] ^= 0x40
        open(ck, "wb").write(bytes(buf))
        db2 = open_database(root)
        errs = []
        if db2["customer"].get_many(keys) != want:
            errs.append("full-replay fallback lost records")
        db2.close()
        return errs
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _scenario_wal_enospc(seed: int) -> List[str]:
    """ENOSPC on a WAL write surfaces as an error on the verb, poisons
    the log, and recovery serves the pre-verb state."""
    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    inj = FaultInjector(seed)
    root = tempfile.mkdtemp(prefix="blitz-enospc-")
    try:
        db, t, schema = _durable_customer_db(root, rows, io=DurableIO(inj))
        keys = [schema.key_of(r) for r in rows[:N_POP]]
        want = t.get_many(keys)
        inj.add_fault("pwrite", "enospc")
        errs = []
        try:
            t.update_many(keys[:4], [dict(rows[i], c_balance=1.0)
                                     for i in range(4)])
            errs.append("ENOSPC did not surface on the verb")
        except OSError:
            pass
        try:
            t.insert_many(rows[N_POP:N_POP + 4])
            errs.append("poisoned log accepted another append")
        except WalPoisonedError:
            pass
        db2 = open_database(root)
        if db2["customer"].get_many(keys) != want:
            errs.append("recovery after ENOSPC lost pre-verb state")
        db2.close()
        return errs
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _scenario_fsync_eio(seed: int) -> List[str]:
    """A failed fsync leaves the durable tail unknowable: the log poisons
    itself (no later append may succeed), and recovery is allowed to
    surface the pwritten record — ambiguous ack, never silent loss."""
    rows = tpcc.gen_customer(N_ROWS, seed=seed)
    inj = FaultInjector(seed)
    root = tempfile.mkdtemp(prefix="blitz-fsync-")
    try:
        db, t, schema = _durable_customer_db(root, rows, io=DurableIO(inj))
        keys = [schema.key_of(r) for r in rows[:N_POP]]
        inj.add_fault("fsync", "eio")
        errs = []
        try:
            t.update_many(keys[:4], [dict(rows[i], c_balance=2.0)
                                     for i in range(4)])
            errs.append("fsync EIO did not surface")
        except OSError:
            pass
        if not db["customer"]._wal.poisoned:
            errs.append("log not poisoned after failed fsync")
        db2 = open_database(root)
        got = db2["customer"].get_many(keys[:4])
        # the record was pwritten before the fsync failed: recovery
        # applies it (the ambiguous-ack side of the contract)
        if any(r["c_balance"] != 2.0 for r in got):
            errs.append("pwritten record did not survive recovery")
        db2.close()
        return errs
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: every crash point on blitzcrank + "
                         "silo, plus all corruption scenarios")
    ap.add_argument("--backend", action="append", default=None)
    ap.add_argument("--point", action="append", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    print("crash matrix:")
    results = run_crash_matrix(backends=args.backend, seed=args.seed,
                               points=args.point, verbose=True)
    print("corruption scenarios:")
    results += run_corruption_scenarios(seed=args.seed, verbose=True)
    bad = [r for r in results if not r["ok"]]
    print(f"{len(results) - len(bad)}/{len(results)} scenarios passed")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
