"""Per-table redo WAL: CRC32-framed records, group flush, torn-tail scan.

One :class:`WriteAheadLog` per table, fed by the table's batch verbs
(DESIGN.md §7).  Records are *logical* redo — ``("insert", rows)``,
``("update", rows)``, ``("delete", keys)``, plus a ``("create", meta)``
header — so replay goes through exactly the same batched code paths as
live traffic and reproduces bit-identical state (model fits are seeded,
shard routing is a pure hash).

Framing is ``[magic u32][len u32][crc32 u32][pickle body]``.  The log is
append-only and never truncated by a checkpoint — a checkpoint records the
LSN (byte offset) replay should start from, and the retained prefix is
what lets runtime corruption repair rebuild *any* row's latest value by a
full scan.  On open, a torn tail (short frame, bad magic, CRC mismatch)
is detected and the file truncated back to the last valid record.

A failed append or fsync leaves the on-disk tail unknowable, so the log
*poisons* itself: every later append raises :class:`WalPoisonedError`
until the database is closed and recovered — the same contract real
engines adopted after fsync-gate.
"""

from __future__ import annotations

import contextlib
import os
import pickle
import struct
import zlib
from typing import Any, Iterator, Optional, Tuple

from repro import sanitize, telemetry
from repro.core.arena import OS_IO

RECORD_MAGIC = 0x57414C31  # "WAL1"
RECORD_HEADER = struct.Struct("<III")

_H_APPEND = telemetry.histogram("repro.wal.append")
_H_FSYNC = telemetry.histogram("repro.wal.fsync")
_C_RECORDS = telemetry.counter("repro.wal.records")
_C_BYTES = telemetry.counter("repro.wal.bytes")
_C_FSYNCS = telemetry.counter("repro.wal.fsyncs")


class WalError(RuntimeError):
    pass


class WalPoisonedError(WalError):
    """The log hit an append/fsync failure; close and recover the DB."""


class WriteAheadLog:
    def __init__(self, path: str, io: Optional[Any] = None,
                 fsync_every: int = 1):
        self.path = path
        self.io = io if io is not None else OS_IO
        self.fsync_every = max(0, int(fsync_every))
        self._fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
        self.closed = False
        self.poisoned = False
        self.suspended = False
        self._pending: list = []
        self._flushes = 0
        self.records = 0
        self.truncated_bytes = 0
        self._tail = self._recover_tail()

    # -- open-time torn-tail scan ----------------------------------------
    def _recover_tail(self) -> int:
        end = 0
        for end, _op, _payload in self.scan(0):
            pass
        size = os.fstat(self._fd).st_size
        if end < size:
            self.truncated_bytes = size - end
            os.ftruncate(self._fd, end)
        return end

    # -- append path ------------------------------------------------------
    @property
    def lsn(self) -> int:
        """Byte offset of the durable tail; doubles as the log's LSN."""
        return self._tail

    def append(self, op: str, payload: Any) -> None:
        """Stage one logical record (framed, not yet written)."""
        if self.poisoned:
            raise WalPoisonedError(f"{self.path}: log is poisoned")
        if self.suspended:
            return
        body = pickle.dumps((op, payload), protocol=4)
        self._pending.append(
            RECORD_HEADER.pack(RECORD_MAGIC, len(body), zlib.crc32(body))
        )
        self._pending.append(body)

    def flush(self) -> None:
        """Group-write staged records; fsync on the configured cadence."""
        if self.poisoned:
            raise WalPoisonedError(f"{self.path}: log is poisoned")
        if not self._pending:
            return
        buf = b"".join(self._pending)
        self.io.point("wal.before_flush")
        try:
            t0 = telemetry.clock()
            self.io.pwrite(self._fd, buf, self._tail)
            _H_APPEND.observe_since(t0)
            self._flushes += 1
            if self.fsync_every and self._flushes % self.fsync_every == 0:
                self.io.point("wal.before_fsync")
                t0 = telemetry.clock()
                self.io.fsync(self._fd)
                _H_FSYNC.observe_since(t0)
                _C_FSYNCS.inc()
        except OSError:
            self.poisoned = True
            raise
        self._pending.clear()
        self._tail += len(buf)
        _C_BYTES.add(len(buf))
        if sanitize.ENABLED:
            # The LSN is the durable byte tail: after a group write it must
            # equal the physical file length (shorter = torn/lost write).
            sanitize.check_wal_lsn(
                self._tail, os.fstat(self._fd).st_size, where=self.path
            )
        self.io.point("wal.after_flush")

    def log(self, op: str, payload: Any) -> None:
        """Append + flush one record: the per-batch-verb group commit."""
        if self.suspended:
            return
        self.append(op, payload)
        self.flush()
        self.records += 1
        _C_RECORDS.inc()

    @contextlib.contextmanager
    def suspend(self) -> Iterator["WriteAheadLog"]:
        """No-op appends inside the block (used during recovery replay)."""
        prev = self.suspended
        self.suspended = True
        try:
            yield self
        finally:
            self.suspended = prev

    # -- scan / replay ----------------------------------------------------
    def scan(self, from_lsn: int = 0) -> Iterator[Tuple[int, str, Any]]:
        """Yield ``(end_lsn, op, payload)`` per valid record.

        Stops at the first torn or corrupt frame — everything before it
        is intact (CRC-verified), everything after is unreachable.
        """
        size = os.fstat(self._fd).st_size
        pos = int(from_lsn)
        while pos + RECORD_HEADER.size <= size:
            head = os.pread(self._fd, RECORD_HEADER.size, pos)
            if len(head) < RECORD_HEADER.size:
                return
            magic, ln, crc = RECORD_HEADER.unpack(head)
            body_at = pos + RECORD_HEADER.size
            if magic != RECORD_MAGIC or body_at + ln > size:
                return
            body = os.pread(self._fd, ln, body_at)
            if len(body) != ln or zlib.crc32(body) != crc:
                return
            pos = body_at + ln
            op, payload = pickle.loads(body)
            yield pos, op, payload

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        try:
            os.close(self._fd)
        except OSError:
            pass

    def unlink(self) -> None:
        self.close()
        try:
            os.unlink(self.path)
        except FileNotFoundError:
            pass

    def __del__(self) -> None:  # pragma: no cover - GC timing dependent
        self.close()
