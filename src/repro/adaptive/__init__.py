"""Adaptive model maintenance (DESIGN.md §4): drift detection, background
refit, and versioned plan migration — the paper's §5 "dynamic value sets"
claim made operational for a long-running drifting workload.

Public API:
  * monitor:   DriftConfig, DriftMonitor, DriftReport
  * refit:     ReservoirSample, refit_codec
  * scheduler: MaintenanceConfig, MaintenanceScheduler
"""

from .monitor import DriftConfig, DriftMonitor, DriftReport
from .refit import ReservoirSample, refit_codec
from .scheduler import MaintenanceConfig, MaintenanceScheduler

__all__ = [
    "DriftConfig",
    "DriftMonitor",
    "DriftReport",
    "ReservoirSample",
    "refit_codec",
    "MaintenanceConfig",
    "MaintenanceScheduler",
]
