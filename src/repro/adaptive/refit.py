"""Background per-column refit on a reservoir of recent writes (§4.2).

A drifted column gets a *new model fitted to recent data*, not a full-table
refit: the refitter re-runs the Semantic Learner's per-column model
generation (:func:`repro.core.blitzcrank.fit_column_model` — the same
machinery ``TableCodec.fit`` uses, so plan-ability rules cannot diverge) on
a reservoir sample of recently written rows, shares every non-drifted
model with the outgoing codec, and compiles the result into a fresh
:class:`~repro.core.plan.TablePlan` version.

Vocabulary preservation: the outgoing model's value dictionary (categorical)
or range endpoints (numeric) are appended to the training column, so every
value the old model encoded without escaping stays conforming under the new
model.  That keeps opportunistic migration monotone — re-encoding an old
block under the new plan never *creates* escapes for values the old plan
handled.  String models are refit purely on the reservoir (their word
dictionaries are rebuilt from recent data; old off-template rows simply
stay on their old plan version, which remains decodable forever).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.blitzcrank import TableCodec, fit_column_model
from repro.core.models import (
    CategoricalModel,
    ConditionalCategoricalModel,
    NumericModel,
)


class ReservoirSample:
    """Uniform reservoir (Vitter's algorithm R) over a stream of rows.

    The refitter trains on *recently written* rows; the reservoir gives an
    unbiased sample of the write stream in O(capacity) memory without
    stalling the write path.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0):
        self.capacity = int(capacity)
        self.rows: List[Dict[str, Any]] = []
        self.seen = 0
        self._rng = np.random.default_rng(seed)

    def add_many(self, rows: Sequence[Dict[str, Any]]) -> None:
        for r in rows:
            self.seen += 1
            if len(self.rows) < self.capacity:
                self.rows.append(dict(r))
            else:
                j = int(self._rng.integers(0, self.seen))
                if j < self.capacity:
                    self.rows[j] = dict(r)

    def __len__(self) -> int:
        return len(self.rows)


def _vocab_extras(
    model: Any, name: str, rows: Sequence[Dict[str, Any]], headroom: float
) -> Optional[List[Any]]:
    """Training extras that keep the old model's value set conforming.

    Numeric columns additionally get *range headroom*: the refit range is
    the union of the old range and the sample's, widened by ``headroom`` of
    its span on both ends.  Without it a monotonically growing column (a
    dense primary key, a running total) re-escapes on the first insert
    after every refit and the scheduler thrashes; with it each refit buys a
    proportional amount of future growth.
    """
    if isinstance(model, ConditionalCategoricalModel):
        return list(model.marginal.id2value)
    if isinstance(model, CategoricalModel):
        return list(model.id2value)
    if isinstance(model, NumericModel):
        lo = model.vmin
        hi = model.vmin + (model.total_steps - 1) * model.p
        for r in rows:
            try:
                v = float(r[name])
            except (TypeError, ValueError, KeyError):
                continue
            if np.isfinite(v):
                lo, hi = min(lo, v), max(hi, v)
        pad = headroom * max(hi - lo, model.p)
        lo, hi = lo - pad, hi + pad
        if model.integer:
            return [int(np.floor(lo)), int(np.ceil(hi))]
        return [lo, hi]
    return None


def refit_codec(
    codec: TableCodec,
    rows: Sequence[Dict[str, Any]],
    columns: Sequence[str],
    preserve_vocab: bool = True,
    numeric_headroom: float = 0.5,
) -> TableCodec:
    """New codec version: drifted ``columns`` refit on ``rows``, rest shared.

    The returned codec reuses the outgoing codec's schema, column order,
    structure (parents) and fit stats — only the named column models are
    replaced.  Sharing unchanged model objects is safe: models are
    immutable after fit (the string model's per-block queue is reset per
    block) and the old plan keeps its own references.
    """
    if not columns:
        raise ValueError("refit_codec: no columns to refit")
    missing = [c for c in columns if c not in codec.models]
    if missing:
        raise KeyError(f"refit_codec: unknown columns {missing}")
    models = dict(codec.models)
    for name in columns:
        spec = codec.by_name[name]
        parent = codec.stats.parents.get(name)
        old = models[name]
        extras = pairs = None
        if preserve_vocab:
            extras = _vocab_extras(old, name, rows, numeric_headroom)
            if isinstance(old, ConditionalCategoricalModel):
                # Encode-side conformance is judged per parent group, so
                # each group's child vocabulary must carry over too.
                pairs = [
                    (pv, v) for pv, sub in old.cond.items() for v in sub.id2value
                ]
        new = fit_column_model(
            spec,
            list(rows),
            parent,
            codec.block_tuples,
            extra_values=extras,
            extra_pairs=pairs,
        )
        if (
            preserve_vocab
            and isinstance(old, NumericModel)
            and not isinstance(new, NumericModel)
        ):
            # An int column that drifted down to few distinct reservoir
            # values would flip to categorical, dropping the preserved
            # range (every old in-range value absent from the reservoir
            # would escape).  Keep the model kind stable instead.
            new = NumericModel(
                [r[name] for r in rows] + list(extras or []),
                precision=old.p,
                T=spec.buckets,
                integer=old.integer,
            )
        models[name] = new
    return TableCodec(
        codec.schema,
        models,
        list(codec.order),
        codec.stats,
        codec.block_tuples,
        codec.lam,
    )
