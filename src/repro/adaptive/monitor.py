"""Drift detection over the plan's escape counters (DESIGN.md §4.1).

The compiled :class:`~repro.core.plan.TablePlan` charges every encode-time
model miss to its column (both the batch masks and the scalar conformance
probe — unified per-column semantics).  The monitor turns those counters
into *windowed rates*: each :meth:`check` call reads the current window
(escapes and rows since the last ``reset_escapes``) and reports the columns
whose models have drifted past the configured thresholds.

Two thresholds must both trip (Fehér & Lucani's adaptive-compression rule
of thumb, arXiv:2209.02334): a *rate* (escapes per encoded row, so a busy
store isn't refit just for being busy) and an *absolute floor* (so a quiet
store isn't refit over three unlucky rows).  Windows shorter than
``min_window_rows`` are never judged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class DriftConfig:
    """Trigger thresholds for per-column drift detection.

    A column is *drifted* when, over the current escape window,

        window_escapes[col] >= min_escapes                 (absolute floor)
        window_escapes[col] / window_rows >= rate_threshold  (rate trigger)

    and the window itself holds at least ``min_window_rows`` encoded rows.
    """

    rate_threshold: float = 0.02
    min_escapes: int = 50
    min_window_rows: int = 512


@dataclasses.dataclass
class DriftReport:
    """One :meth:`DriftMonitor.check` observation (kept for stats/tests)."""

    window_rows: int
    rates: Dict[str, float]
    drifted: List[str]


class DriftMonitor:
    """Watches a plan's escape window and names the drifted columns."""

    def __init__(self, config: Optional[DriftConfig] = None):
        self.config = config or DriftConfig()
        self.last_report: Optional[DriftReport] = None
        self.checks = 0

    def check(self, plan) -> List[str]:
        """Judge the plan's current window; returns drifted column names.

        Does not reset the window — the scheduler resets it after acting
        (refit or explicit dismissal), so an undersized window keeps
        accumulating until it is judgeable.
        """
        self.checks += 1
        if plan is None:
            return []
        cfg = self.config
        n = plan.window_rows
        rates = plan.escape_rates()
        if n < cfg.min_window_rows:
            drifted: List[str] = []
        else:
            drifted = sorted(
                (
                    name
                    for name, esc in plan.window_escapes.items()
                    if esc >= cfg.min_escapes and esc / n >= cfg.rate_threshold
                ),
                key=lambda name: -rates[name],
            )
        self.last_report = DriftReport(window_rows=n, rates=rates, drifted=drifted)
        return drifted
