"""Maintenance scheduler: the fit→encode→drift→refit loop closed (§4.3).

Wires the :class:`~repro.adaptive.monitor.DriftMonitor` and the reservoir
refitter to a store that speaks three verbs:

* ``codec``                — the current (newest) :class:`TableCodec`;
* ``install_codec(codec)`` — make a refit codec the new current version;
* ``migrate(limit, resident_only=...)`` — re-encode up to ``limit`` stale
                             escaped rows under the newest plan (returns
                             rows migrated); ``resident_only`` keeps the
                             background work off any spilled cold tier.

``BlitzStore`` provides all three and drives :meth:`maybe_step` from its
write path (piggybacking on the same cadence as ``_maybe_merge``), so a
long-running workload gets drift detection, background refit, and
opportunistic migration without any extra thread; tests call :meth:`step`
directly for determinism.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from .monitor import DriftConfig, DriftMonitor
from .refit import ReservoirSample, refit_codec


@dataclasses.dataclass
class MaintenanceConfig:
    drift: DriftConfig = dataclasses.field(default_factory=DriftConfig)
    check_every: int = 2048  # writes between automatic steps
    reservoir_size: int = 4096  # recent-write sample the refitter trains on
    min_refit_rows: int = 256  # don't refit on a thinner sample
    migrate_rows_per_step: int = 1024  # opportunistic migration budget
    # Under a memory budget (DESIGN.md §6), migration only touches
    # *resident* stale blocks: faulting cold blocks in for a background
    # re-encode would evict the workload's hot set — maintenance must
    # never thrash the cache.  Spilled stale rows migrate when the
    # workload itself faults them back.
    migrate_resident_only: bool = True
    max_versions: int = 16  # hard cap on installed plan versions
    numeric_headroom: float = 0.5  # range padding on numeric refits
    # Futility freeze: after a refit, the column's escape rate in the next
    # full window is compared against the rate that triggered the refit.
    # Still >= futility_frac of it means the refit didn't take (e.g. a
    # column of effectively random strings no dictionary can cover);
    # futility_patience consecutive such refits freeze the column so it
    # stops churning out a plan version per window.  Trigger-time rates are
    # self-normalizing (checks fire right when the threshold is crossed),
    # so only the *post*-refit window is a reliable verdict.
    futility_frac: float = 0.7
    futility_patience: int = 2


class MaintenanceScheduler:
    """Drift-detect → refit → migrate, one bounded unit of work per step."""

    def __init__(
        self,
        store,
        config: Optional[MaintenanceConfig] = None,
        seed: int = 0,
        label: str = "",
    ):
        self.store = store
        self.config = config or MaintenanceConfig()
        # Which store this scheduler maintains, e.g. "customer/shard3" —
        # set by the db engine (repro.db.Table) so aggregated maintenance
        # stats stay attributable to a shard.
        self.label = label
        self.monitor = DriftMonitor(self.config.drift)
        self.reservoir = ReservoirSample(self.config.reservoir_size, seed)
        self.refits = 0
        self.refit_failures = 0
        self.migrated_rows = 0
        self.steps = 0
        self.last_drifted: List[str] = []
        self.frozen: set = set()
        self._rate_at_refit: Dict[str, float] = {}
        self._futile_count: Dict[str, int] = {}
        self._pending_eval: List[str] = []
        self._writes_since_check = 0
        # Post-step hooks (durability: a refit/migration invalidates the
        # last checkpoint's codec list, so the db engine requests a fresh
        # one — deferred to the end of the verb, never taken mid-step).
        self.on_step: List[Callable[[Dict[str, Any]], None]] = []

    # -- write-path hooks (called by the store) --------------------------
    def observe_writes(self, rows: Sequence[Dict[str, Any]]) -> None:
        """Feed written rows to the reservoir; cheap enough for the hot path."""
        self.reservoir.add_many(rows)
        self._writes_since_check += len(rows)

    def maybe_step(self) -> Optional[Dict[str, Any]]:
        """Run one step when enough writes accumulated since the last one."""
        if self._writes_since_check < self.config.check_every:
            return None
        return self.step()

    # -- the deterministic unit of work ----------------------------------
    def step(self) -> Dict[str, Any]:
        """One maintenance step: check drift, maybe refit, maybe migrate.

        Refit rules: the drifted column set must be non-empty, the reservoir
        must hold at least ``min_refit_rows`` rows, and the version cap must
        not be reached.  A refit whose plan fails to compile is discarded
        (the store keeps encoding under the old plan) and its window is
        dismissed so the same escapes don't re-trigger every step.
        Migration runs every step with a fixed row budget, so old escaped
        blocks drain gradually — never a stop-the-world re-encode.
        """
        self.steps += 1
        self._writes_since_check = 0
        cfg = self.config
        plan = self.store.codec.compile()
        raw_drifted = self.monitor.check(plan)
        rates = self.monitor.last_report.rates if self.monitor.last_report else {}
        window_rows = (
            self.monitor.last_report.window_rows if self.monitor.last_report else 0
        )
        # Verdict on the previous refit, once a full window has accrued:
        # a column still escaping near its trigger rate was refit in vain.
        if self._pending_eval and window_rows >= cfg.drift.min_window_rows:
            for c in self._pending_eval:
                prev = self._rate_at_refit.get(c, 0.0)
                if prev > 0.0 and rates.get(c, 0.0) >= cfg.futility_frac * prev:
                    n = self._futile_count.get(c, 0) + 1
                    self._futile_count[c] = n
                    if n >= cfg.futility_patience:
                        self.frozen.add(c)
                else:
                    self._futile_count[c] = 0
            self._pending_eval = []
        drifted = [c for c in raw_drifted if c not in self.frozen]
        self.last_drifted = drifted
        refit_cols: List[str] = []
        if raw_drifted and not drifted:
            plan.reset_escapes()  # all frozen/futile: dismiss the window
        elif drifted and len(self.reservoir) >= cfg.min_refit_rows:
            if self.store.n_versions >= cfg.max_versions:
                plan.reset_escapes()  # at cap: dismiss, don't thrash
            else:
                new_codec = refit_codec(
                    self.store.codec,
                    self.reservoir.rows,
                    drifted,
                    numeric_headroom=cfg.numeric_headroom,
                )
                if new_codec.compile() is None:
                    self.refit_failures += 1
                    plan.reset_escapes()
                else:
                    self.store.install_codec(new_codec)
                    plan.reset_escapes()  # new plan opens a fresh window
                    self.refits += 1
                    refit_cols = drifted
                    self._pending_eval = list(drifted)
                    for c in drifted:
                        self._rate_at_refit[c] = rates.get(c, 0.0)
        migrated = self.store.migrate(
            cfg.migrate_rows_per_step, resident_only=cfg.migrate_resident_only
        )
        self.migrated_rows += migrated
        result = {
            "step": self.steps,
            "window_rows": (
                self.monitor.last_report.window_rows if self.monitor.last_report else 0
            ),
            "drifted": drifted,
            "refit_columns": refit_cols,
            "refits": self.refits,
            "migrated_rows": migrated,
            "versions": self.store.n_versions,
        }
        for fn in self.on_step:
            fn(result)
        return result

    # -- durability (DESIGN.md §7) ---------------------------------------
    def snapshot_state(self) -> Dict[str, Any]:
        """Adaptive state for a checkpoint: config, monitor, reservoir
        (the Generator pickles, so reservoir sampling stays deterministic
        across a crash), counters, and the futility bookkeeping."""
        st = {k: v for k, v in self.__dict__.items() if k not in ("store", "on_step")}
        st["frozen"] = sorted(self.frozen)
        return st

    @classmethod
    def from_state(cls, store, state: Dict[str, Any]) -> "MaintenanceScheduler":
        self = cls.__new__(cls)
        self.store = store
        self.on_step = []
        self.__dict__.update(state)
        self.frozen = set(state["frozen"])
        return self

    def stats(self) -> Dict[str, Any]:
        return {
            **({"label": self.label} if self.label else {}),
            "steps": self.steps,
            "refits": self.refits,
            "refit_failures": self.refit_failures,
            "migrated_rows": self.migrated_rows,
            "reservoir_rows": len(self.reservoir),
            "reservoir_seen": self.reservoir.seen,
            "last_drifted": list(self.last_drifted),
            "frozen_columns": sorted(self.frozen),
        }
