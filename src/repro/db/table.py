"""Hash-partitioned table: primary-key routing over RowStore shards.

A :class:`Table` owns N shards, each an independent
:class:`~repro.oltp.store.RowStore` (``BlitzStore`` by default — any
backend in ``STORE_KINDS`` or a user factory plugs in).  Rows are placed
by ``stable_key_hash(pk) % n_shards``; a directory maps each live primary
key to its ``(shard, local row id)`` slot.  The batched verbs group keys
per shard and issue **one** batched RowStore call per shard, so the
compiled Pallas ``decode_select`` fast path (DESIGN.md §2) is preserved:
a ``get_many`` over K keys costs at most ``n_shards`` vectorized decodes,
never K scalar ones.

Routing invariants (DESIGN.md §5):

* placement is a pure function of the key — the same key always routes to
  the same shard, across runs and processes;
* batched results come back in *request order*, exactly as an unsharded
  store would return them;
* local row ids are never reused (RowStore contract), so a delete + fresh
  insert of the same key occupies a new slot but the directory always
  points at the live one.

Key-level semantics mirror the RowStore protocol with keys in place of
dense ids: ``get_many`` returns ``None`` for unknown/deleted keys, scalar
``get`` raises ``KeyError``, ``update_many`` of a missing key raises
``KeyError``, ``insert_many`` of a live key raises ``ValueError``
(re-inserting a *deleted* key is allowed and revives it in a new slot).
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from repro import telemetry
from repro.oltp.store import STORE_KINDS, RowStore
from .schema import Key, TableSchema, stable_key_hash

# Batched-verb telemetry (DESIGN.md §9): one span per Table verb call
# (the transaction hot path's outermost engine region) plus row and
# shard fan-out counters — `shard_calls / verb count` is the fan-out the
# 7.5x gap hunt watches.
_C_SHARD_CALLS = telemetry.counter("repro.db.shard_calls")
_C_INSERT_ROWS = telemetry.counter("repro.db.insert_many.rows")
_C_GET_ROWS = telemetry.counter("repro.db.get_many.rows")
_C_UPDATE_ROWS = telemetry.counter("repro.db.update_many.rows")
_C_DELETE_ROWS = telemetry.counter("repro.db.delete_many.rows")

# Per-entry directory charge: 8 B key hash + 8 B packed (shard, slot)
# pointer, the footprint of an open-addressed C hash index.  Key payload
# bytes are NOT charged: the primary-key columns are stored (compressed)
# in the rows themselves, and a hash index verifies the key against the
# decoded row rather than duplicating it.
INDEX_ENTRY_OVERHEAD = 16

StoreFactory = Callable[..., RowStore]


class Table:
    """One catalog table: schema + N hash-partitioned RowStore shards.

    Shards are built lazily on the first non-empty ``insert_many`` (that
    batch doubles as the model-fit sample) unless ``sample_rows`` is given,
    in which case they are built eagerly — the TPC-C loader passes its
    generated population so models are fit before any traffic.  All shards
    fit on the *same* sample: per-shard slices would give each shard a
    different model for the same column, which breaks nothing but wastes
    model bytes and makes shard stats incomparable.
    """

    def __init__(
        self,
        schema: TableSchema,
        backend: str | StoreFactory = "blitzcrank",
        n_shards: int = 1,
        sample_rows: Optional[Sequence[Dict[str, Any]]] = None,
        store_kwargs: Optional[Dict[str, Any]] = None,
        memory_budget: Optional[int] = None,
    ):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.schema = schema
        self.name = schema.name
        self.n_shards = int(n_shards)
        self.backend = backend
        self.store_kwargs = dict(store_kwargs or {})
        # Out-of-core budget (DESIGN.md §6): a table-level budget is split
        # evenly across the hash-partitioned shards — placement is a
        # uniform hash of the key, so each shard carries ~1/N of the data
        # and deserves ~1/N of the memory.  An explicit per-shard
        # ``memory_budget`` in store_kwargs wins over the split.
        self.memory_budget = int(memory_budget) if memory_budget is not None else None
        if self.memory_budget is not None and "memory_budget" not in self.store_kwargs:
            self.store_kwargs["memory_budget"] = max(
                1, self.memory_budget // self.n_shards
            )
        self._shards: List[RowStore] = []
        self._dir: Dict[Key, Tuple[int, int]] = {}
        self._prepared: Dict[str, Any] = {}
        # Durability hooks (DESIGN.md §7), wired by a durable Database via
        # attach_wal(): the WAL gets every batch verb *before* it applies,
        # _on_ops drives the checkpoint cadence at verb end, and _io
        # carries the apply.before crash point.
        self._wal = None
        self._io = None
        self._on_ops: Optional[Callable[[int], None]] = None
        self._on_shards_built: Optional[Callable[["Table"], None]] = None
        if sample_rows:
            self._build_shards(sample_rows)

    # -- shard lifecycle -------------------------------------------------
    def _build_shards(self, sample_rows: Sequence[Dict[str, Any]]) -> None:
        factory: StoreFactory
        if callable(self.backend):
            factory = self.backend
        else:
            try:
                factory = STORE_KINDS[self.backend]
            except KeyError:
                raise ValueError(
                    f"unknown backend {self.backend!r}; expected one of "
                    f"{sorted(STORE_KINDS)} or a factory"
                ) from None
        try:  # probe, don't catch build errors: those must propagate
            can_share = "codec" in inspect.signature(factory).parameters
        except (TypeError, ValueError):  # e.g. builtins without signatures
            can_share = False
        kwargs = dict(self.store_kwargs)
        spill_base = kwargs.get("spill_path")
        for j in range(self.n_shards):
            if spill_base is not None and self.n_shards > 1:
                # each shard owns its spill file — one shared append-only
                # file under two arenas would interleave their extents
                kwargs["spill_path"] = f"{spill_base}.s{j}"
            shard = factory(self.schema, sample_rows, **kwargs)
            if (
                j == 0
                and self.n_shards > 1
                and can_share
                and "codec" not in kwargs
                and not kwargs.get("adaptive")
                and getattr(shard, "codec", None) is not None
            ):
                # Every shard fits on the same sample, so fit once and
                # share the codec (BlitzStore accepts a pre-fitted one):
                # N identical model sets would multiply both fit time and
                # model bytes by the shard count for nothing.  Shards
                # still version/refit independently from v0.  Not shared
                # under adaptive maintenance — each shard's drift monitor
                # owns its plan's escape window, and a shared plan would
                # let one shard's step reset every other shard's window.
                try:
                    kwargs["codec"] = shard.codec
                except Exception:
                    pass
            maint = getattr(shard, "maintenance", None)
            if maint is not None:
                maint.label = f"{self.name}/shard{j}"
            self._shards.append(shard)
        if self._wal is not None:
            self._install_repair_fns()
        if self._on_shards_built is not None:
            self._on_shards_built(self)

    def on_shards_built(
        self, callback: Optional[Callable[["Table"], None]]
    ) -> None:
        """Designated entry point for the owning engine to (re)wire the
        shards-built hook (maintenance wiring on build/rebuild).  Foreign
        writes to ``_on_shards_built`` are confined here (BL004)."""
        self._on_shards_built = callback

    @property
    def shards(self) -> List[RowStore]:
        return list(self._shards)

    def shard_of(self, key: Key) -> int:
        return stable_key_hash(key) % self.n_shards

    @property
    def plan_epoch(self) -> Tuple[int, ...]:
        """Per-shard plan versions — the epoch component of the
        prepared-op cache key (DESIGN.md §11).  A refit/migrate
        ``install_codec`` bumps a shard's version and so the epoch;
        merges that keep the plan leave it unchanged."""
        return tuple(getattr(s, "plan_epoch", 0) for s in self._shards)

    def prepare(self, verb: str, schema: Optional[TableSchema] = None) -> Any:
        """Prepared handle for a batched verb (DESIGN.md §11).

        ``verb`` is one of ``insert / get / update / delete``; the
        returned :class:`~repro.exec.PreparedOp` lowers the verb once per
        (plan epoch, batch bucket) and replays it via ``.run(...)``.
        ``schema``, when given, must be this table's schema (the arg
        exists so callers can assert the table they prepared against).
        """
        if schema is not None and schema is not self.schema:
            raise ValueError(
                f"table {self.name!r}: prepare() schema mismatch "
                f"(got {getattr(schema, 'name', schema)!r})"
            )
        op = self._prepared.get(verb)
        if op is None:
            from repro.exec.prepared import PreparedOp  # deferred: no cycle

            op = self._prepared[verb] = PreparedOp(self, verb)
        return op

    def _route(self, key: Key) -> Tuple[int, int]:
        """(shard, local id) of a live key, or raise KeyError."""
        try:
            return self._dir[key]
        except KeyError:
            raise KeyError(
                f"table {self.name!r}: no live row for key {key!r}"
            ) from None

    # -- batched verbs: compatibility shims over the prepared path -------
    # One execution path (DESIGN.md §11): each legacy verb routes through
    # ``prepare(verb).run(...)``, which resolves the lowered plan entry
    # and calls the matching ``_exec_*`` body below.

    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> List[Key]:
        """Insert rows, returning their primary keys in request order.

        Raises ``ValueError`` on a key that is already live (in the table
        or earlier in the same batch) — TPC-C inserts are always fresh
        keys, and silent upsert would hide routing bugs.
        """
        return self.prepare("insert").run(rows)

    def get_many(
        self, keys: Sequence[Key], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        """Batched point reads in request order; ``None`` for missing keys.

        ``backend`` forces the decode backend ("numpy"/"pallas"); every
        RowStore accepts it (non-blitz backends ignore it).
        """
        return self.prepare("get").run(keys, backend=backend)

    def update_many(self, keys: Sequence[Key], rows: Sequence[Dict[str, Any]]) -> None:
        """In-place updates (last write wins on duplicate keys); the primary
        key of each row must match its key — keys are immutable."""
        return self.prepare("update").run(keys, rows)

    def delete_many(self, keys: Sequence[Key]) -> int:
        """Delete live keys, returning how many were actually deleted
        (missing/repeated keys are no-ops, matching RowStore)."""
        return self.prepare("delete").run(keys)

    # -- verb bodies (one RowStore call per touched shard) ---------------
    def _exec_insert(
        self, rows: Sequence[Dict[str, Any]], keys: Sequence[Key], shards: Any
    ) -> List[Key]:
        """Apply a routed insert batch (keys/shards from the prepared op)."""
        t0 = telemetry.clock()
        if not self._shards:
            self._build_shards(rows)
        batch_seen: set = set()
        per_shard: List[List[Dict[str, Any]]] = [[] for _ in self._shards]
        per_shard_keys: List[List[Key]] = [[] for _ in self._shards]
        for r, k, s in zip(rows, keys, shards):
            self.schema.validate_row(r)
            if k in self._dir or k in batch_seen:
                raise ValueError(
                    f"table {self.name!r}: duplicate insert of key {k!r}"
                )
            batch_seen.add(k)
            per_shard[s].append(r)
            per_shard_keys[s].append(k)
        self._log("insert", rows)
        for s, (grp, gkeys) in enumerate(zip(per_shard, per_shard_keys)):
            if not grp:
                continue
            _C_SHARD_CALLS.inc()
            ids = self._shards[s].insert_many(grp)
            for i, k in zip(ids, gkeys):
                self._dir[k] = (s, int(i))
        self._note_ops(len(rows))
        _C_INSERT_ROWS.add(len(rows))
        telemetry.record("repro.db.insert_many", t0)
        return list(keys)

    def _exec_get(
        self, keys: Sequence[Key], backend: Optional[str]
    ) -> List[Optional[Dict[str, Any]]]:
        out: List[Optional[Dict[str, Any]]] = [None] * len(keys)
        if not self._shards:
            return out
        t0 = telemetry.clock()
        per_shard_pos: List[List[int]] = [[] for _ in self._shards]
        per_shard_ids: List[List[int]] = [[] for _ in self._shards]
        for pos, k in enumerate(keys):
            slot = self._dir.get(k)
            if slot is None:
                continue
            s, i = slot
            per_shard_pos[s].append(pos)
            per_shard_ids[s].append(i)
        for s, (poss, ids) in enumerate(zip(per_shard_pos, per_shard_ids)):
            if not ids:
                continue
            _C_SHARD_CALLS.inc()
            got = self._shards[s].get_many(ids, backend=backend)
            for pos, row in zip(poss, got):
                out[pos] = row
        _C_GET_ROWS.add(len(keys))
        telemetry.record("repro.db.get_many", t0)
        return out

    def _exec_update(
        self, keys: Sequence[Key], rows: Sequence[Dict[str, Any]]
    ) -> None:
        t0 = telemetry.clock()
        merged: Dict[Key, Dict[str, Any]] = {}
        for k, r in zip(keys, rows):
            self.schema.validate_row(r)  # fail here, not in a later merge
            if self.schema.key_of(r) != k:
                raise ValueError(
                    f"table {self.name!r}: update changes primary key "
                    f"{k!r} -> {self.schema.key_of(r)!r}"
                )
            merged[k] = r
        per_shard_ids: List[List[int]] = [[] for _ in self._shards]
        per_shard_rows: List[List[Dict[str, Any]]] = [[] for _ in self._shards]
        for k, r in merged.items():
            s, i = self._route(k)
            per_shard_ids[s].append(i)
            per_shard_rows[s].append(r)
        self._log("update", list(merged.values()))
        for s, (ids, grp) in enumerate(zip(per_shard_ids, per_shard_rows)):
            if ids:
                _C_SHARD_CALLS.inc()
                self._shards[s].update_many(ids, grp)
        self._note_ops(len(merged))
        _C_UPDATE_ROWS.add(len(merged))
        telemetry.record("repro.db.update_many", t0)

    def _exec_delete(self, keys: Sequence[Key]) -> int:
        t0 = telemetry.clock()
        per_shard_ids: List[List[int]] = [[] for _ in self._shards]
        dropped: List[Key] = []
        for k in dict.fromkeys(keys):  # dedup, keep order
            slot = self._dir.get(k)
            if slot is None:
                continue
            s, i = slot
            per_shard_ids[s].append(i)
            dropped.append(k)
        if dropped:
            self._log("delete", dropped)
        n = 0
        for s, ids in enumerate(per_shard_ids):
            if ids:
                _C_SHARD_CALLS.inc()
                n += self._shards[s].delete_many(ids)
        for k in dropped:
            del self._dir[k]
        self._note_ops(len(dropped))
        _C_DELETE_ROWS.add(len(dropped))
        telemetry.record("repro.db.delete_many", t0)
        return n

    # -- scalar wrappers -------------------------------------------------
    def insert(self, row: Dict[str, Any]) -> Key:
        return self.insert_many([row])[0]

    def get(self, key: Key) -> Dict[str, Any]:
        # One execution path: scalar reads replay the same prepared plan
        # as batched reads (missing keys keep the KeyError contract).
        row = self.get_many([key])[0]
        if row is None:
            raise KeyError(key)
        return row

    def update(self, key: Key, row: Dict[str, Any]) -> None:
        self.update_many([key], [row])

    def delete(self, key: Key) -> bool:
        return self.delete_many([key]) == 1

    def __contains__(self, key: Key) -> bool:
        return key in self._dir

    def scan(self, batch: int = 1024) -> Iterator[Tuple[Key, Dict[str, Any]]]:
        """Yield ``(key, row)`` for every live row, shard by shard, one
        batched ``get_many`` per chunk.

        Keys are recovered from the decoded rows themselves (the primary
        key lives in the row's columns), so no reverse id→key map is
        needed; the directory check skips stale slots of keys that were
        deleted and revived elsewhere.
        """
        key_of = self.schema.key_of
        for s, shard in enumerate(self._shards):
            span = len(shard)
            for lo in range(0, span, batch):
                ids = range(lo, min(lo + batch, span))
                for i, row in zip(ids, shard.get_many(ids)):
                    if row is None:  # tombstoned slot
                        continue
                    k = key_of(row)
                    if self._dir.get(k) == (s, i):
                        yield k, row

    # -- analytics scans (DESIGN.md §8) ----------------------------------
    def _shard_scan(
        self,
        predicates: Sequence[Any],
        columns: Optional[Sequence[str]],
        pushdown: bool,
        backend: Optional[str],
    ) -> Iterator[Tuple[int, Key, Dict[str, Any], Any]]:
        """Fan a filtered scan across shards, yielding live
        ``(shard, key, row, shard_stats)`` tuples.

        The shard-level projection is augmented with the primary-key
        columns so each hit can be checked against the directory — a slot
        whose key was deleted and revived elsewhere is stale and must be
        skipped (same rule as :meth:`scan`).  ``shard_stats`` is yielded
        once per shard (with the first row) for aggregation by callers.
        """
        key_of = self.schema.key_of
        need = columns
        if columns is not None:
            need = list(dict.fromkeys(list(columns) + list(self.schema.primary_key)))
        for s, shard in enumerate(self._shards):
            res = shard.scan_where(
                predicates, columns=need, pushdown=pushdown, backend=backend
            )
            first = res.stats
            for i, row in zip(res.ids, res.rows):
                k = key_of(row)
                if self._dir.get(k) == (s, i):
                    yield s, k, row, first
                    first = None
            if first is not None:  # no rows matched: still surface stats
                yield s, None, None, first

    def scan_where(
        self,
        predicates: Sequence[Any],
        columns: Optional[Sequence[str]] = None,
        pushdown: bool = True,
        backend: Optional[str] = None,
        with_stats: bool = False,
    ):
        """Filtered scan -> ``(key, projected row)`` pairs across shards.

        One :meth:`RowStore.scan_where` call per shard (predicate pushdown
        with zone-map pruning on blitz shards; ``pushdown=False`` forces
        the decode-everything reference).  Results carry exactly the
        requested ``columns`` and are merged into global primary-key
        order, so the pushdown and reference paths agree as *lists*, not
        merely as sets.  ``with_stats=True`` returns ``(hits, merged
        ScanStats)``.
        """
        from repro.scan import ScanStats

        hits: List[Tuple[Key, Dict[str, Any]]] = []
        total = ScanStats()
        cols = list(columns) if columns is not None else None
        for _s, k, row, st in self._shard_scan(predicates, cols, pushdown, backend):
            if st is not None:
                total.merge(st)
            if k is None:
                continue
            if cols is not None:
                row = {c: row[c] for c in cols}
            hits.append((k, row))
        # pk values are homogeneous within a table, so the sort is total;
        # per-shard results arrive id-ordered already, making this a
        # nearly-sorted merge for timsort.
        hits.sort(key=lambda kv: kv[0])
        total.rows_matched = len(hits)
        return (hits, total) if with_stats else hits

    def aggregate(
        self,
        predicates: Sequence[Any],
        group_by: Sequence[str] = (),
        aggs: Optional[Dict[str, Tuple[str, Optional[str]]]] = None,
        pushdown: bool = True,
        backend: Optional[str] = None,
        with_stats: bool = False,
    ) -> Any:
        """Filtered group-by aggregation: ``{group key: {name: value}}``.

        ``aggs`` maps output names to ``(op, column)`` with op one of
        ``count`` (column ignored, may be None), ``sum``, ``avg``, ``min``,
        ``max``.  Partial aggregates accumulate per shard as rows stream
        out of the pushdown scan — only the group table is materialized,
        never the matching row set — and merge trivially because every
        op is decomposable (avg is carried as sum+count until finalize).
        ``with_stats=True`` returns ``(groups, merged ScanStats)`` — the
        same stats shape :meth:`scan_where` reports (DESIGN.md §8).
        """
        from repro.scan import ScanStats

        aggs = dict(aggs or {"count": ("count", None)})
        group_by = list(group_by)
        need_cols = list(
            dict.fromkeys(group_by + [c for _, c in aggs.values() if c is not None])
        )
        total = ScanStats()
        matched = 0
        # state per group: [count, {name: accumulator}]
        groups: Dict[Tuple[Any, ...], List[Any]] = {}
        for _s, k, row, st in self._shard_scan(
            predicates, need_cols, pushdown, backend
        ):
            if st is not None:
                total.merge(st)
            if k is None:
                continue
            matched += 1
            g = tuple(row[c] for c in group_by)
            st = groups.get(g)
            if st is None:
                st = groups[g] = [0, {}]
            st[0] += 1
            acc = st[1]
            for name, (op, col) in aggs.items():
                if op == "count":
                    continue
                v = row[col]
                cur = acc.get(name)
                if op in ("sum", "avg"):
                    acc[name] = v if cur is None else cur + v
                elif op == "min":
                    acc[name] = v if cur is None or v < cur else cur
                elif op == "max":
                    acc[name] = v if cur is None or v > cur else cur
                else:
                    raise ValueError(f"unknown aggregate op {op!r}")
        out: Dict[Tuple[Any, ...], Dict[str, Any]] = {}
        for g, (n, acc) in groups.items():
            row_out: Dict[str, Any] = {}
            for name, (op, _col) in aggs.items():
                if op == "count":
                    row_out[name] = n
                elif op == "avg":
                    row_out[name] = acc[name] / n
                else:
                    row_out[name] = acc[name]
            out[g] = row_out
        if with_stats:
            total.rows_matched = matched
            return out, total
        return out

    # -- maintenance (DESIGN.md §3/§4, fanned across shards) -------------
    def merge(self) -> None:
        for shard in self._shards:
            if hasattr(shard, "merge"):
                shard.merge()

    def migrate(self, limit: int = 1 << 12) -> int:
        moved = 0
        for shard in self._shards:
            if hasattr(shard, "migrate"):
                moved += shard.migrate(limit)
        return moved

    def maintenance_step(self) -> List[Dict[str, Any]]:
        """Run one deterministic maintenance step on every adaptive shard."""
        out = []
        for shard in self._shards:
            maint = getattr(shard, "maintenance", None)
            if maint is not None:
                out.append(maint.step())
        return out

    # -- durability (DESIGN.md §7) ---------------------------------------
    def attach_wal(
        self,
        wal,
        io: Optional[Any] = None,
        on_ops: Optional[Callable[[int], None]] = None,
    ) -> None:
        """Wire this table to its redo log (one WAL per table).

        From here on every batch verb logs its logical record *before*
        touching any shard (log-before-apply), and ``on_ops`` fires with
        the row count at the end of each verb — never mid-apply, so a
        checkpoint can only observe verb boundaries."""
        self._wal = wal
        self._io = io
        self._on_ops = on_ops
        if self._shards:
            self._install_repair_fns()

    def _log(self, op: str, payload: Any) -> None:
        if self._wal is not None:
            self._wal.log(op, payload)
            if self._io is not None:
                self._io.point("apply.before")

    def _note_ops(self, n: int) -> None:
        if self._on_ops is not None:
            self._on_ops(n)

    def _install_repair_fns(self) -> None:
        for j, shard in enumerate(self._shards):
            if hasattr(shard, "repair_fn"):
                shard.repair_fn = self._make_repair_fn(j)

    def _make_repair_fn(self, s: int) -> Callable:
        """Row rebuilder for shard ``s``: local row ids -> latest logical
        rows, reconstructed from the retained WAL history.

        A corrupt spilled extent names only local slot ids; the directory
        maps live slots back to primary keys, and one full WAL scan
        (insert/update set the key's latest row, delete clears it) yields
        each key's current value.  Slots no key points at — deleted, or
        revived elsewhere — resolve to ``None`` and get tombstoned by the
        caller.  Garbage is never served."""

        def repair(row_ids: Sequence[int]) -> List[Optional[Dict[str, Any]]]:
            wanted = {int(i) for i in row_ids}
            slot2key: Dict[int, Key] = {}
            for k, (sh, i) in self._dir.items():
                if sh == s and i in wanted:
                    slot2key[i] = k
            need = set(slot2key.values())
            latest: Dict[Key, Dict[str, Any]] = {}
            if need and self._wal is not None:
                key_of = self.schema.key_of
                for _lsn, op, payload in self._wal.scan(0):
                    if op in ("insert", "update"):
                        for r in payload:
                            k = key_of(r)
                            if k in need:
                                latest[k] = r
                    elif op == "delete":
                        for k in payload:
                            if k in need:
                                latest.pop(k, None)
            return [latest.get(slot2key.get(int(i))) for i in row_ids]

        return repair

    def close(self, unlink: bool = False) -> None:
        """Release shard spill files and the WAL; ``unlink=True`` deletes
        them (drop_table) instead of keeping them for reopen."""
        for shard in self._shards:
            if hasattr(shard, "close"):
                shard.close(unlink=unlink)
        if self._wal is not None:
            if unlink:
                self._wal.unlink()
            else:
                self._wal.close()

    def clean_store_kwargs(self) -> Dict[str, Any]:
        """store_kwargs safe to persist: live objects (a shared codec, an
        injected io) are reconstructed, never pickled."""
        return {
            k: v for k, v in self.store_kwargs.items() if k not in ("codec", "spill_io")
        }

    def snapshot_state(self) -> Dict[str, Any]:
        if not isinstance(self.backend, str):
            raise ValueError(
                f"table {self.name!r}: factory backends cannot be "
                f"checkpointed (pass a STORE_KINDS name)"
            )
        return {
            "schema": self.schema,
            "backend": self.backend,
            "n_shards": self.n_shards,
            "store_kwargs": self.clean_store_kwargs(),
            "memory_budget": self.memory_budget,
            "dir": dict(self._dir),
            "shards": (
                [s.snapshot_state() for s in self._shards] if self._shards else None
            ),
        }

    @classmethod
    def from_snapshot(
        cls, state: Dict[str, Any], spill_io: Optional[Any] = None
    ) -> "Table":
        self = cls.__new__(cls)
        self.schema = state["schema"]
        self.name = self.schema.name
        self.n_shards = state["n_shards"]
        self.backend = state["backend"]
        self.store_kwargs = dict(state["store_kwargs"])
        if spill_io is not None:
            self.store_kwargs["spill_io"] = spill_io
        self.memory_budget = state["memory_budget"]
        self._dir = dict(state["dir"])
        self._shards = []
        self._prepared = {}
        self._wal = None
        self._io = None
        self._on_ops = None
        self._on_shards_built = None
        if state["shards"] is not None:
            store_cls = STORE_KINDS[self.backend]
            spill_base = self.store_kwargs.get("spill_path")
            for j, st in enumerate(state["shards"]):
                # same per-shard suffixing as _build_shards, so a durable
                # named spill file (extent-mode checkpoints) survives the
                # reopen instead of degrading to an anonymous temp file
                sp = spill_base
                if sp is not None and self.n_shards > 1:
                    sp = f"{spill_base}.s{j}"
                self._shards.append(
                    store_cls.from_state(
                        self.schema, st, spill_path=sp, spill_io=spill_io
                    )
                )
            for j, shard in enumerate(self._shards):
                maint = getattr(shard, "maintenance", None)
                if maint is not None:
                    maint.label = f"{self.name}/shard{j}"
        return self

    # -- accounting ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._dir)

    @property
    def n_live(self) -> int:
        return len(self._dir)

    @property
    def index_bytes(self) -> int:
        return INDEX_ENTRY_OVERHEAD * len(self._dir)

    @property
    def nbytes(self) -> int:
        """Total footprint: every shard's bytes plus the key directory."""
        return sum(s.nbytes for s in self._shards) + self.index_bytes

    @property
    def model_bytes(self) -> int:
        """Model bytes with cross-shard dedup: shards share their v0 fit
        (see :meth:`_build_shards`), so identical model objects count once."""
        seen: set = set()
        total = 0
        for s in self._shards:
            objs = getattr(s, "model_objects", None)
            if objs is None:
                total += getattr(s, "model_bytes", 0)
                continue
            for m in objs():
                if id(m) not in seen:
                    seen.add(id(m))
                    total += m.model_bytes()
        return total

    def stats(self) -> Dict[str, Any]:
        shard_stats = [s.stats() for s in self._shards]
        out: Dict[str, Any] = {
            "table": self.name,
            "backend": (
                self.backend
                if isinstance(self.backend, str)
                else getattr(self.backend, "__name__", "factory")
            ),
            "n_shards": self.n_shards,
            "n_live": self.n_live,
            "n_ids": sum(s["n_ids"] for s in shard_stats),
            "nbytes": self.nbytes,
            "store_bytes": sum(s["nbytes"] for s in shard_stats),
            "index_bytes": self.index_bytes,
            "model_bytes": self.model_bytes,
            "shards": shard_stats,
        }
        res = [s["residency"] for s in shard_stats if "residency" in s]
        if res:
            # nbytes/store_bytes above are *resident* memory; the on-disk
            # cold tier is aggregated separately (DESIGN.md §6).
            out["spilled_bytes"] = sum(s.get("spilled_bytes", 0) for s in shard_stats)
            out["residency"] = {
                "budget_bytes": sum(r["budget_bytes"] for r in res),
                "spilled_bytes": out["spilled_bytes"],
                "spills": sum(r["spills"] for r in res),
                "faults": sum(r["faults"] for r in res),
                "fault_batches": sum(r["fault_batches"] for r in res),
                "disk_file_bytes": sum(r["disk_file_bytes"] for r in res),
            }
        maint = [s["maintenance"] for s in shard_stats if "maintenance" in s]
        if maint:
            out["maintenance"] = {
                "refits": sum(m["refits"] for m in maint),
                "migrated_rows": sum(m["migrated_rows"] for m in maint),
                "steps": sum(m["steps"] for m in maint),
                "frozen_columns": sorted(
                    {c for m in maint for c in m["frozen_columns"]}
                ),
            }
        out["telemetry"] = telemetry.snapshot(prefix=("repro.db.", "repro.scan."))
        return out
