"""The `repro.db` catalog: named tables, one engine-wide view (DESIGN.md §5).

A :class:`Database` registers :class:`~repro.db.TableSchema` s and owns the
resulting :class:`~repro.db.Table` s.  It carries engine-wide defaults
(backend, shard count, store kwargs) that individual ``create_table`` calls
can override, and aggregates ``stats()`` / ``nbytes`` across every table
and shard — the number the paper's §6 "whole-database memory reduction"
claim is about, and the one ``benchmarks/bench_db_tpcc.py`` reports.
"""

from __future__ import annotations

import contextlib
import os
from typing import TYPE_CHECKING, Any, Dict, Iterator, List, Optional, Sequence

if TYPE_CHECKING:
    from repro.durability.wal import WriteAheadLog

from repro import telemetry

from .schema import TableSchema
from .table import StoreFactory, Table


class Database:
    """Catalog of tables sharing engine-wide defaults.

    >>> db = Database(backend="blitzcrank", n_shards=4)
    >>> db.create_table(schema, sample_rows=rows)
    >>> db["customer"].get_many(keys)
    """

    def __init__(
        self,
        backend: str | StoreFactory = "blitzcrank",
        n_shards: int = 1,
        store_kwargs: Optional[Dict[str, Any]] = None,
        memory_budget: Optional[int] = None,
        durability: Optional[Any] = None,
    ):
        self.backend = backend
        self.n_shards = int(n_shards)
        self.store_kwargs = dict(store_kwargs or {})
        # Engine-wide default *per-table* memory budget (DESIGN.md §6);
        # each table splits its budget across its shards.  Table sizes
        # are not knowable at catalog time, so a proportional split is
        # the loader's job (see bench_out_of_core's per-table budgets).
        self.memory_budget = int(memory_budget) if memory_budget is not None else None
        self._tables: Dict[str, Table] = {}
        # Durability (DESIGN.md §7): a DurabilityConfig (or just its root
        # path) turns on one WAL per table + checkpoints; ``None`` keeps
        # the engine purely in-memory with zero overhead.
        self._dur = None
        self._io = None
        self._ops_since_ckpt = 0
        self._ckpt_requested = False
        self._recovering = False
        if durability is not None:
            from repro.durability.config import DurabilityConfig

            if not isinstance(durability, DurabilityConfig):
                durability = DurabilityConfig(root=os.fspath(durability))
            self._dur = durability
            os.makedirs(durability.root, exist_ok=True)
            self._io = durability.make_io()

    @property
    def durable(self) -> bool:
        return self._dur is not None

    # -- catalog ---------------------------------------------------------
    def create_table(
        self,
        schema: TableSchema,
        *,
        backend: str | StoreFactory | None = None,
        n_shards: Optional[int] = None,
        sample_rows: Optional[Sequence[Dict[str, Any]]] = None,
        store_kwargs: Optional[Dict[str, Any]] = None,
        memory_budget: Optional[int] = None,
    ) -> Table:
        """Register ``schema`` and build its table (engine defaults apply
        unless overridden).  Re-registering a name raises ``ValueError``."""
        if schema.name in self._tables:
            raise ValueError(f"table {schema.name!r} already registered")
        kwargs = dict(self.store_kwargs)
        kwargs.update(store_kwargs or {})
        if self._dur is not None:
            # fault injection (and crash points) must cover spill I/O too
            kwargs.setdefault("spill_io", self._io)
        table = Table(
            schema,
            backend=self.backend if backend is None else backend,
            n_shards=self.n_shards if n_shards is None else n_shards,
            sample_rows=sample_rows,
            store_kwargs=kwargs,
            memory_budget=(
                self.memory_budget if memory_budget is None else memory_budget
            ),
        )
        self._tables[schema.name] = table
        if self._dur is not None:
            self._attach_durability(table, sample_rows)
        return table

    def drop_table(self, name: str) -> None:
        """Unregister a table, releasing its spill files and (durable) WAL;
        a durable drop checkpoints so recovery won't resurrect it."""
        if name not in self._tables:
            raise KeyError(f"no table {name!r}")
        table = self._tables.pop(name)
        table.close(unlink=True)
        if self._dur is not None:
            self.checkpoint()

    def table(self, name: str) -> Table:
        try:
            return self._tables[name]
        except KeyError:
            raise KeyError(
                f"no table {name!r}; registered: {sorted(self._tables)}"
            ) from None

    def __getitem__(self, name: str) -> Table:
        return self.table(name)

    def __contains__(self, name: str) -> bool:
        return name in self._tables

    def __iter__(self) -> Iterator[Table]:
        return iter(self._tables.values())

    def __len__(self) -> int:
        return len(self._tables)

    @property
    def table_names(self) -> List[str]:
        return sorted(self._tables)

    @property
    def schemas(self) -> Dict[str, TableSchema]:
        return {n: t.schema for n, t in self._tables.items()}

    # -- compiled execution surface (DESIGN.md §11) ----------------------
    def session(self) -> Any:
        """Open an execution session: prepared-handle cache per
        (table, verb) plus batched verb conveniences —
        ``ses.insert("orders", rows)``, ``ses.get("customer", keys,
        backend="pallas")``.  Sessions are cheap; open one per worker or
        transaction loop.  See :class:`repro.exec.Session`."""
        from repro.exec.prepared import Session  # deferred: no cycle

        return Session(self)

    # -- analytics entry point (DESIGN.md §8) ----------------------------
    def query(
        self,
        table: str,
        predicates: Sequence[Any] = (),
        columns: Optional[Sequence[str]] = None,
        group_by: Sequence[str] = (),
        aggs: Optional[Dict[str, Any]] = None,
        pushdown: bool = True,
        backend: Optional[str] = None,
        with_stats: bool = False,
    ) -> Any:
        """One-stop OLAP entry point over a registered table.

        Without ``aggs`` this is a filtered projection —
        ``Table.scan_where`` returning ``(key, row)`` pairs.  With
        ``aggs`` (``{name: (op, column)}``, op in count/sum/avg/min/max)
        it runs the streaming group-by aggregation instead and returns
        ``{group key tuple: {name: value}}``.  ``pushdown=False`` forces
        the decode-everything reference path on every shard (the
        correctness oracle the scan tests diff against).  Both paths take
        the same ``backend=`` / ``with_stats=`` keywords and report the
        same ``ScanStats`` shape (DESIGN.md §8): ``with_stats=True``
        returns ``(result, stats)``.
        """
        t = self.table(table)
        if aggs is not None or group_by:
            return t.aggregate(
                predicates,
                group_by=group_by,
                aggs=aggs,
                pushdown=pushdown,
                backend=backend,
                with_stats=with_stats,
            )
        return t.scan_where(
            predicates,
            columns=columns,
            pushdown=pushdown,
            backend=backend,
            with_stats=with_stats,
        )

    # -- engine-wide maintenance -----------------------------------------
    def merge_all(self) -> None:
        """Fold every table's delta overlay back into its arenas."""
        for t in self._tables.values():
            t.merge()

    def migrate_all(self, limit_per_table: int = 1 << 12) -> int:
        return sum(t.migrate(limit_per_table) for t in self._tables.values())

    def maintenance_step(self) -> Dict[str, List[Dict[str, Any]]]:
        out = {n: t.maintenance_step() for n, t in self._tables.items()}
        self._note_ops(0)  # honor a checkpoint request from the steps
        return out

    # -- recovery entry points (DESIGN.md §7) -----------------------------
    # The recovery module drives engine-private catalog and checkpoint
    # state through these instead of reaching into ``_tables`` /
    # ``_recovering`` directly (blitzlint BL004).

    def adopt_table(self, table: Table, wal: "WriteAheadLog") -> None:
        """Register an externally rebuilt table and wire its durability
        hooks — the recovery-path counterpart of :meth:`create_table`."""
        self._tables[table.name] = table
        table.attach_wal(wal, io=self._io, on_ops=self._note_ops)
        table.on_shards_built(self._wire_maintenance)
        if table.shards:
            self._wire_maintenance(table)

    def discard_table(self, name: str) -> None:
        """Drop ``name`` from the catalog without closing its files
        (recovery replaces a corrupt snapshot with a from-log rebuild)."""
        self._tables.pop(name, None)

    @contextlib.contextmanager
    def recovery_mode(self) -> Iterator[None]:
        """Inhibit checkpoints while replay re-drives the batched verbs —
        a mid-replay snapshot would pair a prefix state with a full-tail
        LSN."""
        self._recovering = True
        try:
            yield
        finally:
            self._recovering = False

    def reset_checkpoint_clock(self) -> None:
        """Zero the ops-since-checkpoint cadence after recovery: replayed
        traffic must not count toward the next checkpoint trigger."""
        self._ops_since_ckpt = 0
        self._ckpt_requested = False

    # -- durability (DESIGN.md §7) ---------------------------------------
    def _attach_durability(
        self, table: Table, sample_rows: Optional[Sequence[Dict[str, Any]]]
    ) -> None:
        from repro.durability.wal import WriteAheadLog

        wal = WriteAheadLog(
            os.path.join(self._dur.root, f"{table.name}.wal"),
            io=self._io,
            fsync_every=self._dur.fsync_every,
        )
        table.attach_wal(wal, io=self._io, on_ops=self._note_ops)
        table.on_shards_built(self._wire_maintenance)
        if table.shards:
            self._wire_maintenance(table)
        if wal.lsn == 0:
            # Fresh log: the catalog event heads it, so a from-zero replay
            # can rebuild the table (same sample => same seeded model fit
            # => bit-identical codecs).  On reopen the record is already
            # there (lsn > 0) and must not be duplicated.
            wal.log(
                "create",
                {
                    "schema": table.schema,
                    "backend": table.backend,
                    "n_shards": table.n_shards,
                    "store_kwargs": table.clean_store_kwargs(),
                    "memory_budget": table.memory_budget,
                    "sample_rows": (
                        [dict(r) for r in sample_rows] if sample_rows else None
                    ),
                },
            )

    def _wire_maintenance(self, table: Table) -> None:
        """A refit/migration step invalidates the checkpointed codec list;
        request a fresh checkpoint, taken at the *end* of the current verb
        (``_note_ops``), never mid-step."""
        if self._dur is None or not self._dur.checkpoint_on_maintenance:
            return

        def request(_result: Dict[str, Any]) -> None:
            self._ckpt_requested = True

        for shard in table.shards:
            maint = getattr(shard, "maintenance", None)
            if maint is not None:
                maint.on_step.append(request)

    def _note_ops(self, n: int) -> None:
        if self._dur is None or self._recovering:
            return
        self._ops_since_ckpt += int(n)
        every = self._dur.checkpoint_every_ops
        if self._ckpt_requested or (every > 0 and self._ops_since_ckpt >= every):
            self.checkpoint()

    def checkpoint(self) -> int:
        """Snapshot the whole catalog (atomic replace); returns byte size.

        Each table entry carries its WAL's current LSN, so recovery is
        checkpoint-load + replay of only the log tail past that offset."""
        if self._dur is None:
            raise RuntimeError("checkpoint() requires durability=")
        from repro.durability.checkpoint import write_checkpoint

        tables: Dict[str, Any] = {}
        for name, t in self._tables.items():
            tables[name] = {
                "snapshot": t.snapshot_state(),
                "wal_lsn": t._wal.lsn if t._wal is not None else 0,
            }
        state = {
            "format": 1,
            "engine": {
                "backend": (self.backend if isinstance(self.backend, str) else None),
                "n_shards": self.n_shards,
                "store_kwargs": {
                    k: v
                    for k, v in self.store_kwargs.items()
                    if k not in ("codec", "spill_io")
                },
                "memory_budget": self.memory_budget,
            },
            "tables": tables,
        }
        size = write_checkpoint(self._dur.root, state, io=self._io)
        self._ops_since_ckpt = 0
        self._ckpt_requested = False
        return size

    def close(self) -> None:
        """Checkpoint (durable) and release every table's files."""
        if self._dur is not None and self._tables:
            self.checkpoint()
        for t in self._tables.values():
            t.close()

    @classmethod
    def open(cls, root: str, **kwargs: Any) -> "Database":
        """Recover a durable database from its checkpoint + WAL tails."""
        from repro.durability.recovery import open_database

        return open_database(root, **kwargs)

    # -- aggregated accounting -------------------------------------------
    @property
    def nbytes(self) -> int:
        return sum(t.nbytes for t in self._tables.values())

    @property
    def n_live(self) -> int:
        return sum(t.n_live for t in self._tables.values())

    def stats(self) -> Dict[str, Any]:
        per_table = {n: t.stats() for n, t in sorted(self._tables.items())}
        out = {
            "n_tables": len(self._tables),
            "n_live": self.n_live,
            "nbytes": self.nbytes,
            "store_bytes": sum(s["store_bytes"] for s in per_table.values()),
            "index_bytes": sum(s["index_bytes"] for s in per_table.values()),
            "model_bytes": sum(s["model_bytes"] for s in per_table.values()),
            "tables": per_table,
        }
        res = [s["residency"] for s in per_table.values() if "residency" in s]
        if res:
            # whole-database view of the cold tier: nbytes stays resident
            # memory, spilled bytes live on disk and are summed separately
            out["spilled_bytes"] = sum(r["spilled_bytes"] for r in res)
            out["residency"] = {
                "budget_bytes": sum(r["budget_bytes"] for r in res),
                "spilled_bytes": out["spilled_bytes"],
                "spills": sum(r["spills"] for r in res),
                "faults": sum(r["faults"] for r in res),
                "fault_batches": sum(r["fault_batches"] for r in res),
                "disk_file_bytes": sum(r["disk_file_bytes"] for r in res),
            }
        # whole-engine view: the registry is global, so no prefix filter
        out["telemetry"] = telemetry.snapshot()
        return out
