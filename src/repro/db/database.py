"""The `repro.db` catalog: named tables, one engine-wide view (DESIGN.md §5).

A :class:`Database` registers :class:`~repro.db.TableSchema` s and owns the
resulting :class:`~repro.db.Table` s.  It carries engine-wide defaults
(backend, shard count, store kwargs) that individual ``create_table`` calls
can override, and aggregates ``stats()`` / ``nbytes`` across every table
and shard — the number the paper's §6 "whole-database memory reduction"
claim is about, and the one ``benchmarks/bench_db_tpcc.py`` reports.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Sequence

from .schema import TableSchema
from .table import StoreFactory, Table


class Database:
    """Catalog of tables sharing engine-wide defaults.

    >>> db = Database(backend="blitzcrank", n_shards=4)
    >>> db.create_table(schema, sample_rows=rows)
    >>> db["customer"].get_many(keys)
    """

    def __init__(self, backend: str | StoreFactory = "blitzcrank",
                 n_shards: int = 1,
                 store_kwargs: Optional[Dict[str, Any]] = None,
                 memory_budget: Optional[int] = None):
        self.backend = backend
        self.n_shards = int(n_shards)
        self.store_kwargs = dict(store_kwargs or {})
        # Engine-wide default *per-table* memory budget (DESIGN.md §6);
        # each table splits its budget across its shards.  Table sizes
        # are not knowable at catalog time, so a proportional split is
        # the loader's job (see bench_out_of_core's per-table budgets).
        self.memory_budget = (int(memory_budget)
                              if memory_budget is not None else None)
        self._tables: Dict[str, Table] = {}

    # -- catalog ---------------------------------------------------------
    def create_table(self, schema: TableSchema, *,
                     backend: str | StoreFactory | None = None,
                     n_shards: Optional[int] = None,
                     sample_rows: Optional[Sequence[Dict[str, Any]]] = None,
                     store_kwargs: Optional[Dict[str, Any]] = None,
                     memory_budget: Optional[int] = None) -> Table:
        """Register ``schema`` and build its table (engine defaults apply
        unless overridden).  Re-registering a name raises ``ValueError``."""
        if schema.name in self._tables:
            raise ValueError(f"table {schema.name!r} already registered")
        kwargs = dict(self.store_kwargs)
        kwargs.update(store_kwargs or {})
        table = Table(schema,
                      backend=self.backend if backend is None else backend,
                      n_shards=self.n_shards if n_shards is None
                      else n_shards,
                      sample_rows=sample_rows, store_kwargs=kwargs,
                      memory_budget=self.memory_budget
                      if memory_budget is None else memory_budget)
        self._tables[schema.name] = table
        return table

    def drop_table(self, name: str) -> None:
        if name not in self._tables:
            raise KeyError(f"no table {name!r}")
        del self._tables[name]

    def table(self, name: str) -> Table:
        try:
            return self._tables[name]
        except KeyError:
            raise KeyError(
                f"no table {name!r}; registered: "
                f"{sorted(self._tables)}") from None

    def __getitem__(self, name: str) -> Table:
        return self.table(name)

    def __contains__(self, name: str) -> bool:
        return name in self._tables

    def __iter__(self) -> Iterator[Table]:
        return iter(self._tables.values())

    def __len__(self) -> int:
        return len(self._tables)

    @property
    def table_names(self) -> List[str]:
        return sorted(self._tables)

    @property
    def schemas(self) -> Dict[str, TableSchema]:
        return {n: t.schema for n, t in self._tables.items()}

    # -- engine-wide maintenance -----------------------------------------
    def merge_all(self) -> None:
        """Fold every table's delta overlay back into its arenas."""
        for t in self._tables.values():
            t.merge()

    def migrate_all(self, limit_per_table: int = 1 << 12) -> int:
        return sum(t.migrate(limit_per_table) for t in self._tables.values())

    def maintenance_step(self) -> Dict[str, List[Dict[str, Any]]]:
        return {n: t.maintenance_step() for n, t in self._tables.items()}

    # -- aggregated accounting -------------------------------------------
    @property
    def nbytes(self) -> int:
        return sum(t.nbytes for t in self._tables.values())

    @property
    def n_live(self) -> int:
        return sum(t.n_live for t in self._tables.values())

    def stats(self) -> Dict[str, Any]:
        per_table = {n: t.stats() for n, t in sorted(self._tables.items())}
        out = {
            "n_tables": len(self._tables),
            "n_live": self.n_live,
            "nbytes": self.nbytes,
            "store_bytes": sum(s["store_bytes"] for s in per_table.values()),
            "index_bytes": sum(s["index_bytes"] for s in per_table.values()),
            "model_bytes": sum(s["model_bytes"] for s in per_table.values()),
            "tables": per_table,
        }
        res = [s["residency"] for s in per_table.values()
               if "residency" in s]
        if res:
            # whole-database view of the cold tier: nbytes stays resident
            # memory, spilled bytes live on disk and are summed separately
            out["spilled_bytes"] = sum(r["spilled_bytes"] for r in res)
            out["residency"] = {
                "budget_bytes": sum(r["budget_bytes"] for r in res),
                "spilled_bytes": out["spilled_bytes"],
                "spills": sum(r["spills"] for r in res),
                "faults": sum(r["faults"] for r in res),
                "fault_batches": sum(r["fault_batches"] for r in res),
                "disk_file_bytes": sum(r["disk_file_bytes"] for r in res),
            }
        return out
