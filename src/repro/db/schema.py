"""Declarative table schemas for the `repro.db` engine (DESIGN.md §5).

A :class:`TableSchema` is the unit the :class:`~repro.db.Database` catalog
registers: named :class:`~repro.core.ColumnSpec` columns plus a *typed*
primary key — an ordered subset of hashable columns whose values identify a
row.  The schema owns key extraction (:meth:`TableSchema.key_of`) and the
engine owns key→shard routing via :func:`stable_key_hash`, a deterministic
FNV-1a over the key's components.  Python's builtin ``hash`` is per-process
randomized for strings, so it would scatter the same table differently on
every run; shard layout must instead be a pure function of the key so that
reloading a table (or comparing two stores) reproduces the same placement.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Sequence, Tuple, Union

from repro.core.blitzcrank import ColumnSpec, column_specs

# Primary-key columns must hold hashable, routable values.  Floats are
# excluded on purpose: their quantized decode (precision p, §4.2) means a
# value can change representation across an encode round-trip, which would
# silently re-route the row to a different shard.
KEYABLE_KINDS = ("int", "cat", "str")

Key = Union[int, str, Tuple[Any, ...]]

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK = (1 << 64) - 1


def stable_key_hash(key: Key) -> int:
    """64-bit FNV-1a of a primary key, stable across processes and runs.

    Components are domain-separated by type tag + byte length so that
    ``(1, "2")`` and ``("1", 2)`` land differently; ints hash their
    little-endian two's-complement bytes, strings their UTF-8.
    """
    parts = key if isinstance(key, tuple) else (key,)
    h = _FNV_OFFSET
    for part in parts:
        if isinstance(part, bool):  # bool is an int subclass: tag it apart
            data, tag = bytes([int(part)]), 0x62
        elif isinstance(part, int):
            n = max(1, (part.bit_length() + 8) // 8)
            data, tag = part.to_bytes(n, "little", signed=True), 0x69
        elif isinstance(part, str):
            data, tag = part.encode("utf-8"), 0x73
        else:
            raise TypeError(
                f"unroutable key component {part!r} ({type(part).__name__})"
            )
        for b in (tag, len(data) & 0xFF):
            h = ((h ^ b) * _FNV_PRIME) & _MASK
        for b in data:
            h = ((h ^ b) * _FNV_PRIME) & _MASK
    return h


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """Named columns + typed primary key: what the catalog registers.

    ``primary_key`` is an ordered tuple of column names (a single name is
    accepted and normalized); each must name a declared column of a
    hashable kind (:data:`KEYABLE_KINDS`).  Keys extracted by
    :meth:`key_of` are scalars for single-column keys and tuples for
    composite keys — e.g. TPC-C's ``customer`` is keyed by
    ``(c_w_id, c_d_id, c_id)``.
    """

    name: str
    columns: Tuple[ColumnSpec, ...]
    primary_key: Tuple[str, ...]

    def __init__(
        self,
        name: str,
        columns: Sequence[ColumnSpec],
        primary_key: Union[str, Sequence[str]],
    ):
        cols = tuple(column_specs(columns))
        pk = (primary_key,) if isinstance(primary_key, str) else tuple(primary_key)
        object.__setattr__(self, "name", str(name))
        object.__setattr__(self, "columns", cols)
        object.__setattr__(self, "primary_key", pk)
        self._validate()

    def _validate(self) -> None:
        by_name: Dict[str, ColumnSpec] = {}
        for c in self.columns:
            if c.name in by_name:
                raise ValueError(
                    f"table {self.name!r}: duplicate column {c.name!r}"
                )
            by_name[c.name] = c
        if not self.primary_key:
            raise ValueError(f"table {self.name!r}: empty primary key")
        if len(set(self.primary_key)) != len(self.primary_key):
            raise ValueError(f"table {self.name!r}: repeated primary-key column")
        for k in self.primary_key:
            spec = by_name.get(k)
            if spec is None:
                raise ValueError(
                    f"table {self.name!r}: primary-key column {k!r} is not declared"
                )
            if spec.kind not in KEYABLE_KINDS:
                raise ValueError(
                    f"table {self.name!r}: primary-key column {k!r} has "
                    f"kind {spec.kind!r}; keys must be one of "
                    f"{KEYABLE_KINDS} (floats re-quantize on decode and "
                    "would re-route)"
                )
        object.__setattr__(self, "_by_name", by_name)

    # -- lookups ---------------------------------------------------------
    @property
    def column_names(self) -> List[str]:
        return [c.name for c in self.columns]

    def column(self, name: str) -> ColumnSpec:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"table {self.name!r} has no column {name!r}") from None

    # -- key handling ----------------------------------------------------
    def key_of(self, row: Dict[str, Any]) -> Key:
        """Extract the primary key (scalar for 1 column, tuple otherwise)."""
        if len(self.primary_key) == 1:
            return row[self.primary_key[0]]
        return tuple(row[k] for k in self.primary_key)

    def keys_of(self, rows: Iterable[Dict[str, Any]]) -> List[Key]:
        return [self.key_of(r) for r in rows]

    def key_hash(self, key: Key) -> int:
        return stable_key_hash(key)

    def validate_row(self, row: Dict[str, Any]) -> None:
        """Cheap shape check: every declared column present (used on the
        insert path of :class:`~repro.db.Table`)."""
        for c in self.columns:
            if c.name not in row:
                raise KeyError(
                    f"table {self.name!r}: row missing column {c.name!r}"
                )
