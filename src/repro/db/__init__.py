"""`repro.db`: the schema-first database engine (DESIGN.md §5).

Public API — import everything from here, never from the private modules:

* :class:`TableSchema` / :class:`ColumnSpec` — declarative table shape
  with a typed primary key;
* :class:`Database` — the catalog: registers schemas, owns tables,
  aggregates whole-database stats;
* :class:`Table` — N hash-partitioned shards with primary-key routing
  and one batched RowStore call per shard;
* the store backends (:class:`BlitzStore`, :class:`UncompressedStore`,
  :class:`RamanStore`, :class:`ZstdStore`, :data:`STORE_KINDS`) re-exported
  so a backend choice never needs a second import.
"""

from repro.core.blitzcrank import ColumnSpec
from repro.oltp.store import (
    STORE_KINDS,
    BlitzStore,
    RamanStore,
    RowStore,
    UncompressedStore,
    ZstdStore,
)

from .database import Database
from .schema import KEYABLE_KINDS, Key, TableSchema, stable_key_hash
from .table import INDEX_ENTRY_OVERHEAD, StoreFactory, Table

__all__ = [
    "Database",
    "Table",
    "TableSchema",
    "ColumnSpec",
    "Key",
    "KEYABLE_KINDS",
    "stable_key_hash",
    "StoreFactory",
    "INDEX_ENTRY_OVERHEAD",
    "RowStore",
    "BlitzStore",
    "UncompressedStore",
    "RamanStore",
    "ZstdStore",
    "STORE_KINDS",
]
