"""Opt-in boundary sanitizer (DESIGN.md §10): ``REPRO_SANITIZE=1``.

Structural invariants of the compressed-table machinery are cheap to
state and expensive to debug when silently violated — a wrapped plan
version tag or a torn CSR offset corrupts *decoded values*, far from
the write that broke it.  This module centralizes those invariants as
typed check functions that the hot paths call at their boundaries
(append/flush, fault-in/spill, WAL append, overlay merge, scan entry).

Cost model: every check site guards on :data:`ENABLED` first, so the
sanitize-off hot path pays one module-attribute load and a falsy branch
— see ``benchmarks/bench_sanitize.py`` for the measurement.  Enabled,
each check is vectorized (numpy reductions, no per-row Python) and
counts into ``repro.sanitize.checks`` / ``repro.sanitize.failures``.

Failures raise a :class:`SanitizeError` subclass naming the broken
invariant, the boundary that caught it, and the offending values; they
are programming-error assertions, not recoverable I/O conditions, so
they deliberately do NOT derive from the recoverable corruption errors
in :mod:`repro.core.arena`.

Enable by exporting ``REPRO_SANITIZE=1`` before import (CI runs the
tier-1 suite that way), or per-test with :func:`override`.
"""

from __future__ import annotations

import contextlib
import os
from typing import Any, Iterator, Optional

import numpy as np

from repro import telemetry


def _env_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").strip() not in ("", "0")


#: Read by every check site; flipped only by :func:`override` (tests).
ENABLED: bool = _env_enabled()


def enabled() -> bool:
    """True when boundary checks are active."""
    return ENABLED


@contextlib.contextmanager
def override(flag: bool) -> Iterator[None]:
    """Force the sanitizer on/off within a block (test harness hook)."""
    global ENABLED
    prev = ENABLED
    ENABLED = bool(flag)
    try:
        yield
    finally:
        ENABLED = prev


# -- typed invariant errors --------------------------------------------------


class SanitizeError(AssertionError):
    """Base of every sanitizer failure (an invariant, not an I/O error)."""


class CsrInvariantError(SanitizeError):
    """CSR arena structure broken: non-monotone offsets, out-of-range
    extents, or per-slot codes outside the coder's alphabet."""


class ResidencyInvariantError(SanitizeError):
    """Residency accounting disagrees with ground truth (resident mask,
    spilled-code totals, or disk extents of non-resident blocks)."""


class PlanVersionInvariantError(SanitizeError):
    """A row's plan-version tag does not name a live codec version."""


class ZoneMapInvariantError(SanitizeError):
    """Block zone map fails to contain the codes actually stored."""


class OverlayInvariantError(SanitizeError):
    """Overlay/tombstone inconsistency: a key both deleted and live, or
    an overlay row shadowing nothing."""


class WalInvariantError(SanitizeError):
    """WAL LSN regression: the log tail moved backwards."""


# -- accounting --------------------------------------------------------------

_C_CHECKS = telemetry.counter("repro.sanitize.checks")
_C_FAILURES = telemetry.counter("repro.sanitize.failures")


def _fail(exc_type: type, message: str) -> None:
    _C_FAILURES.add(1)
    raise exc_type(message)


# -- check functions ----------------------------------------------------------
# All take plain arrays/scalars so the callers (core/db/oltp/scan/
# durability) stay the only modules that know their own layouts.


def check_csr_offsets(
    offsets: np.ndarray, arena_size: int, *, where: str
) -> None:
    """Offsets must start >= 0, be non-decreasing, and end within the
    arena: every block's extent ``[offsets[i], offsets[i+1])`` is then a
    valid slice."""
    _C_CHECKS.add(1)
    offs = np.asarray(offsets)
    if offs.size == 0:
        return
    if int(offs[0]) < 0:
        _fail(
            CsrInvariantError,
            f"{where}: CSR offsets start at {int(offs[0])} (< 0)",
        )
    if offs.size > 1:
        deltas = np.diff(offs.astype(np.int64))
        if deltas.size and int(deltas.min()) < 0:
            i = int(np.argmax(deltas < 0))
            _fail(
                CsrInvariantError,
                f"{where}: CSR offsets decrease at block {i} "
                f"({int(offs[i])} -> {int(offs[i + 1])})",
            )
    if int(offs[-1]) > int(arena_size):
        _fail(
            CsrInvariantError,
            f"{where}: CSR tail offset {int(offs[-1])} exceeds arena "
            f"size {int(arena_size)}",
        )


def check_code_range(
    codes: np.ndarray, total: int, *, where: str, slot: Optional[int] = None
) -> None:
    """Every stored code must lie in ``[0, total)`` — the coder's
    alphabet; a wider value means a torn write or a wrong-plan decode."""
    _C_CHECKS.add(1)
    arr = np.asarray(codes)
    if arr.size == 0:
        return
    hi = int(arr.max())
    if hi >= int(total):
        what = f"slot {slot}" if slot is not None else "codes"
        _fail(
            CsrInvariantError,
            f"{where}: {what} contain {hi} >= alphabet size {int(total)}",
        )


def check_residency(
    claimed_spilled_codes: int,
    actual_spilled_codes: int,
    resident: np.ndarray,
    disk_off: np.ndarray,
    *,
    where: str,
) -> None:
    """Residency accounting vs ground truth: the spilled-code counter
    must match the recomputed total, and every non-resident block must
    have a disk extent to fault back in from."""
    _C_CHECKS.add(1)
    if int(claimed_spilled_codes) != int(actual_spilled_codes):
        _fail(
            ResidencyInvariantError,
            f"{where}: spilled-code counter {int(claimed_spilled_codes)} "
            f"!= ground truth {int(actual_spilled_codes)}",
        )
    res = np.asarray(resident, dtype=bool)
    offs = np.asarray(disk_off)
    n = min(res.size, offs.size)
    lost = np.nonzero(~res[:n] & (offs[:n] < 0))[0]
    if lost.size:
        _fail(
            ResidencyInvariantError,
            f"{where}: {int(lost.size)} non-resident block(s) have no "
            f"disk extent (first: block {int(lost[0])})",
        )


def check_plan_versions(
    tags: np.ndarray, n_versions: int, *, where: str
) -> None:
    """Every row's plan-version tag must name a live codec version
    (tags are uint16 — a wrapped or stale tag decodes garbage)."""
    _C_CHECKS.add(1)
    arr = np.asarray(tags)
    if arr.size == 0:
        return
    hi = int(arr.max())
    if hi >= int(n_versions):
        _fail(
            PlanVersionInvariantError,
            f"{where}: plan-version tag {hi} out of range "
            f"(live versions: {int(n_versions)})",
        )


def check_zone_maps(zmin: np.ndarray, zmax: np.ndarray, *, where: str) -> None:
    """Zone maps must be well-formed: a finite per-chunk min must not
    exceed its max.  (Untouched chunks are ``(+inf, -inf)`` by
    construction and are skipped.)  An inverted pair silently prunes
    blocks whose values actually match."""
    _C_CHECKS.add(1)
    lo = np.asarray(zmin, dtype=np.float64)
    hi = np.asarray(zmax, dtype=np.float64)
    if lo.size == 0:
        return
    bad = np.isfinite(lo) & np.isfinite(hi) & (lo > hi)
    if bad.any():
        i = int(np.argmax(bad.reshape(-1)))
        _fail(
            ZoneMapInvariantError,
            f"{where}: inverted zone map entry at flat index {i} "
            f"({lo.reshape(-1)[i]} > {hi.reshape(-1)[i]})",
        )


def check_overlay(
    overlay_keys: Any, tombstones: Any, *, where: str
) -> None:
    """A key must not be both tombstoned and carrying an overlay row."""
    _C_CHECKS.add(1)
    both = set(overlay_keys) & set(tombstones)
    if both:
        k = next(iter(both))
        _fail(
            OverlayInvariantError,
            f"{where}: {len(both)} key(s) both tombstoned and live in "
            f"the overlay (e.g. {k!r})",
        )


def check_wal_lsn(prev_lsn: int, new_lsn: int, *, where: str) -> None:
    """The log tail only moves forward; a regression means a torn or
    reordered append."""
    _C_CHECKS.add(1)
    if int(new_lsn) < int(prev_lsn):
        _fail(
            WalInvariantError,
            f"{where}: WAL LSN moved backwards ({int(prev_lsn)} -> "
            f"{int(new_lsn)})",
        )
