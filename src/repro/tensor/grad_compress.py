"""Cross-pod gradient compression with error feedback (DESIGN.md §3.3).

On the multi-pod mesh the 'pod' axis crosses DCN, the slowest fabric; the
per-step gradient synchronisation across pods is the collective-roofline
term this module attacks.  Scheme (paper §4.2's skew-aware quantizer in
int8 clothing, plus standard error feedback):

    g_local  = in-pod reduced gradients (implicit from batch sharding)
    q        = int8_quantize(g_local + err)          per-block scales
    exchange = all_gather(q, axis='pod')             int8 on the wire (4x
                                                     fewer bytes than f32)
    g_synced = mean(dequant(exchange))
    err      = (g_local + err) - dequant(q)          error feedback

Used through ``shard_map`` over the 'pod' axis so the wire dtype is
explicit; the in-pod reduction stays GSPMD-implicit.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_block(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 quantization with per-block scales (skew-aware via max-abs)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_block(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_exchange(g: jax.Array, err: jax.Array, axis_name: str
                      ) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: returns (synced grads, new error feedback)."""
    target = g.astype(jnp.float32) + err
    q, scale = _quant_block(target)
    # int8 + f32-scales on the wire (4x fewer bytes than f32 grads)
    q_all = jax.lax.all_gather(q, axis_name)          # [n_pods, ...]
    s_all = jax.lax.all_gather(scale, axis_name)
    deq = jax.vmap(lambda qq, ss: _dequant_block(qq, ss, g.shape))(q_all, s_all)
    synced = deq.mean(axis=0)
    new_err = target - _dequant_block(q, scale, g.shape)
    return synced.astype(g.dtype), new_err


def make_podwise_sync(mesh, param_specs):
    """Build a shard_map'd tree sync over the 'pod' axis.

    ``param_specs``: pytree of PartitionSpecs for the gradient tree with the
    'pod' axis absent (grads are pod-replicated after in-pod reduction).
    """
    if "pod" not in mesh.axis_names:
        return None  # single-pod: nothing to compress

    def sync(grads, errs):
        def one(g, e):
            return compress_exchange(g, e, "pod")
        return jax.tree.map(one, grads, errs)

    from jax.experimental.shard_map import shard_map
    return shard_map(
        sync, mesh=mesh,
        in_specs=(param_specs, param_specs),
        out_specs=(param_specs, param_specs),
        check_rep=False)


def wire_bytes(tree: Any) -> Tuple[int, int]:
    """(uncompressed f32 bytes, compressed int8+scale bytes) per pod hop."""
    raw = comp = 0
    for x in jax.tree.leaves(tree):
        n = int(x.size)
        raw += 4 * n
        comp += n + 4 * (-(-n // BLOCK))
    return raw, comp
