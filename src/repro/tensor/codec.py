"""Semantic tensor codec: Blitzcrank's models applied to model state.

Two modes (DESIGN.md §3):

* ``lossless16`` — bf16/fp16 tensors viewed as u16 bit patterns, one
  categorical semantic model per channel group; exactly lossless.  The TPU
  adaptation of the paper's categorical model (bf16 values cluster heavily:
  exponent/high-mantissa patterns are low-entropy).
* ``twolevel`` — the paper's §4.2 numeric model: per-group equi-width
  histogram (skew-aware level 1) + uniform precision grid (level 2);
  |err| <= p/2.

Both encode groups of values as Blitzcrank *tuples* (fixed slot schemas) via
vectorized delayed coding; decode paths exist in numpy (host), pure-jnp ref,
and the Pallas kernel (``repro.kernels.delayed_decode``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.coders import TOTAL, DiscreteCoder, UniformCoder, quantize_freqs
from repro.core.vectorized import decode_batch, encode_batch


@dataclasses.dataclass
class CompressedTensor:
    codes: np.ndarray            # uint16 arena
    offsets: np.ndarray          # int64 per-tuple CSR offsets
    shape: Tuple[int, ...]
    dtype: str
    group_rows: int              # tuples per group (model index stride)
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return int(self.codes.size * 2 + self.offsets.size * 8)

    def ratio(self) -> float:
        raw = int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize
        return raw / max(self.nbytes, 1)


class Lossless16Codec:
    """Per-group categorical model over 16-bit patterns; exactly lossless."""

    def __init__(self, sample: np.ndarray, group_size: int = 256,
                 max_syms: int = 4096):
        assert sample.dtype.itemsize == 2, "lossless16 expects 16-bit dtypes"
        self.group_size = group_size
        bits = sample.reshape(-1).view(np.uint16)
        # one global model (per-tensor); per-channel variants cost model size
        counts = np.bincount(bits, minlength=65536).astype(np.float64)
        nz = np.flatnonzero(counts)
        if nz.size > max_syms:
            top = nz[np.argsort(-counts[nz])[:max_syms]]
        else:
            top = nz
        self.sym_of = np.full(65536, -1, np.int32)
        self.sym_of[top] = np.arange(top.size)
        self.pattern_of = top.astype(np.uint16)
        esc = max(1.0, counts.sum() - counts[top].sum())
        self.coder = DiscreteCoder(quantize_freqs(
            np.append(counts[top], esc)))
        self.esc = top.size
        self.raw = UniformCoder(TOTAL)

    def encode(self, x: np.ndarray) -> CompressedTensor:
        bits = np.ascontiguousarray(x).reshape(-1).view(np.uint16)
        n = bits.size
        g = self.group_size
        pad = (-n) % g
        bits_p = np.pad(bits, (0, pad))
        syms = self.sym_of[bits_p].astype(np.int64)
        escaped = syms < 0
        # escape: symbol ESC followed by a raw 16-bit slot. Fixed-slot trick:
        # every value uses two slots (sym, raw); raw is 0 for non-escapes
        # and is assigned interval [0, 2**16) -> contributes just its code
        # options, so non-escape raws cost ~0 bits... but a uniform raw slot
        # always costs 0 bits of entropy yet still consumes options - encode
        # escapes out-of-band instead (simpler and tighter):
        s2 = np.where(escaped, self.esc, syms).reshape(-1, g)
        codes, offsets = encode_batch(s2, [self.coder] * g)
        esc_vals = bits_p[escaped.reshape(-1)]
        return CompressedTensor(
            codes=codes, offsets=offsets, shape=tuple(x.shape),
            dtype=str(x.dtype), group_rows=g,
            meta={"esc_vals": esc_vals, "pad": pad, "mode": "lossless16"})

    def decode(self, ct: CompressedTensor) -> np.ndarray:
        syms = decode_batch(ct.codes, ct.offsets, [self.coder] * ct.group_rows)
        flat = syms.reshape(-1)
        out = self.pattern_of[np.minimum(flat, self.esc - 1)].astype(np.uint16)
        esc_idx = np.flatnonzero(flat == self.esc)
        out[esc_idx] = ct.meta["esc_vals"]
        if ct.meta["pad"]:
            out = out[:-ct.meta["pad"]]
        return out.view(np.dtype(ct.dtype)).reshape(ct.shape)

    def model_bytes(self) -> int:
        return int(self.pattern_of.nbytes + 65536 * 4 + 7 * 4 *
                   self.coder.tables.n_buckets)


class TwoLevelCodec:
    """Paper §4.2 two-level numeric model over value groups (lossy, |e|<=p/2)."""

    def __init__(self, sample: np.ndarray, precision: float,
                 T: int = 512, group_size: int = 256):
        v = np.asarray(sample, np.float64).reshape(-1)
        self.p = float(precision)
        self.group_size = group_size
        self.vmin = float(v.min())
        vmax = float(v.max())
        total_steps = int(math.floor((vmax - self.vmin) / self.p + 1e-9)) + 1
        self.G = max(1, -(-total_steps // T))
        self.T = -(-total_steps // self.G)
        q = self._q(v)
        buckets = np.clip(q // self.G, 0, self.T - 1)
        counts = np.bincount(buckets, minlength=self.T).astype(np.float64)
        counts = np.append(counts, max(1.0, 1e-4 * v.size))  # escape
        self.esc = self.T
        self.l1 = DiscreteCoder(quantize_freqs(counts))
        self.l2: List[UniformCoder] = []
        g = self.G
        digits = []
        while g > 1:
            digits.append(min(g, TOTAL))
            g = -(-g // TOTAL)
        self.l2 = [UniformCoder(a) for a in reversed(digits)]
        self.radix = []
        w = 1
        for c in reversed(self.l2):
            self.radix.insert(0, w)
            w *= c.G

    def _q(self, v):
        return np.floor((v - self.vmin) / self.p + 1e-9).astype(np.int64)

    def _slots(self):
        return [self.l1] + self.l2

    def encode(self, x: np.ndarray) -> CompressedTensor:
        v = np.asarray(x, np.float64).reshape(-1)
        q = self._q(v)
        oob = (q < 0) | (q >= self.T * self.G)
        q = np.clip(q, 0, self.T * self.G - 1)
        n = v.size
        g = self.group_size
        pad = (-n) % g
        qp = np.pad(q, (0, pad))
        oobp = np.pad(oob, (0, pad))
        bucket = qp // self.G
        bucket = np.where(oobp, self.esc, bucket)
        cols = [bucket]
        rem = qp % self.G
        for w in self.radix:
            cols.append(rem // w)
            rem = rem % w
        S = len(cols)
        syms = np.stack(cols, 1).reshape(-1, g * S)
        # interleaved fixed-slot schema: one tuple = g values x S slots
        coders = self._slots() * g
        # reorder so slots of one value are adjacent
        syms = syms.reshape(-1, g, S).reshape(-1, g * S)
        codes, offsets = encode_batch(syms, coders)
        esc_vals = np.asarray(v[oob], np.float64)
        return CompressedTensor(
            codes=codes, offsets=offsets, shape=tuple(np.shape(x)),
            dtype=str(np.asarray(x).dtype), group_rows=g,
            meta={"esc_vals": esc_vals, "pad": pad, "mode": "twolevel",
                  "S": S})

    def decode(self, ct: CompressedTensor) -> np.ndarray:
        g, S = ct.group_rows, ct.meta["S"]
        coders = self._slots() * g
        syms = decode_batch(ct.codes, ct.offsets, coders)
        syms = syms.reshape(-1, g, S)
        bucket = syms[..., 0].reshape(-1)
        j = np.zeros_like(bucket)
        for i, w in enumerate(self.radix):
            j = j + syms[..., 1 + i].reshape(-1) * w
        oob = bucket == self.esc
        q = np.clip(bucket, 0, self.T - 1) * self.G + j
        v = self.vmin + (q + 0.5) * self.p
        v[oob] = ct.meta["esc_vals"]
        if ct.meta["pad"]:
            v = v[:-ct.meta["pad"]]
        return v.astype(np.dtype(ct.dtype)).reshape(ct.shape)

    def model_bytes(self) -> int:
        return int(7 * 4 * self.l1.tables.n_buckets + 64)


def fit_codec(sample: np.ndarray, mode: str = "auto",
              precision: Optional[float] = None, **kw):
    """Pick/fit a codec: 16-bit dtypes -> lossless16, floats -> twolevel."""
    sample = np.asarray(sample)
    if mode == "auto":
        mode = "lossless16" if sample.dtype.itemsize == 2 else "twolevel"
    if mode == "lossless16":
        return Lossless16Codec(sample, **kw)
    if precision is None:
        scale = float(np.std(sample)) or 1.0
        precision = scale / 256.0  # ~int8-grade default
    return TwoLevelCodec(sample, precision, **kw)
