"""Compressed KV cache (DESIGN.md §3.2): decompress-on-access pages.

Two tiers, mirroring the paper's hot/cold split (§6.5's cache + storage):

* **Hot (in-jit)**: int8 semantic quantization with per-(token, kv-head)
  scales; attention reads tiles through ``kernels.kv_attention_int8``
  (dequantize in VMEM).  2x memory vs bf16, jit/SPMD-native.
* **Cold (host pages)**: full Blitzcrank — per-layer two-level numeric
  models + delayed coding at page granularity; pages are the "tuples",
  random access decompresses one page (the paper's point-query flow).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .codec import CompressedTensor, TwoLevelCodec


# ---------------------------------------------------------------------------
# Hot tier: int8 + scales (jit-native)
# ---------------------------------------------------------------------------

def quantize_kv(k: jax.Array, v: jax.Array):
    """[B, S, K, D] bf16 -> int8 + f32 scales per (token, head)."""
    def q(x):
        xf = x.astype(jnp.float32)
        s = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-8
        qx = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
        return qx, s
    kq, ks = q(k)
    vq, vs = q(v)
    return kq, ks, vq, vs


def dequantize_kv(kq, ks, vq, vs, dtype=jnp.bfloat16):
    k = (kq.astype(jnp.float32) * ks[..., None]).astype(dtype)
    v = (vq.astype(jnp.float32) * vs[..., None]).astype(dtype)
    return k, v


@dataclasses.dataclass
class QuantKVCache:
    """Stacked per-layer int8 caches: kq/vq [L, B, S, K, D], scales [L,B,S,K]."""
    kq: jax.Array
    ks: jax.Array
    vq: jax.Array
    vs: jax.Array

    @classmethod
    def create(cls, L, B, S, K, D):
        return cls(kq=jnp.zeros((L, B, S, K, D), jnp.int8),
                   ks=jnp.zeros((L, B, S, K), jnp.float32),
                   vq=jnp.zeros((L, B, S, K, D), jnp.int8),
                   vs=jnp.zeros((L, B, S, K), jnp.float32))

    def update(self, layer_slice, pos, k_new, v_new):
        """Insert one token (decode step) at ``pos`` for every layer slice."""
        kq, ks, vq, vs = quantize_kv(k_new, v_new)
        def upd(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(buf, val, pos,
                                                       axis=1)
        return dataclasses.replace(
            self,
            kq=upd(self.kq[layer_slice], kq),
            ks=upd(self.ks[layer_slice], ks),
            vq=upd(self.vq[layer_slice], vq),
            vs=upd(self.vs[layer_slice], vs))

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in (self.kq, self.ks, self.vq, self.vs))


# ---------------------------------------------------------------------------
# Cold tier: Blitzcrank pages on host
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Page:
    layer: int
    start: int                  # first token position
    tokens: int
    k_ct: CompressedTensor
    v_ct: CompressedTensor


class CompressedKVStore:
    """Host-side paged store; one two-level model pair per layer.

    The serving engine offloads cold pages here and fetches them back on
    access (decompress-per-page = the paper's per-tuple random access).
    """

    def __init__(self, page_tokens: int = 128, precision_frac: float = 1 / 256):
        self.page_tokens = page_tokens
        self.precision_frac = precision_frac
        self.codecs: Dict[int, Tuple[TwoLevelCodec, TwoLevelCodec]] = {}
        self.pages: Dict[Tuple[int, int], Page] = {}

    def _codec_for(self, layer: int, k: np.ndarray, v: np.ndarray):
        if layer not in self.codecs:
            pk = max(float(np.std(k)), 1e-6) * self.precision_frac * 8
            pv = max(float(np.std(v)), 1e-6) * self.precision_frac * 8
            self.codecs[layer] = (TwoLevelCodec(k, pk, group_size=128),
                                  TwoLevelCodec(v, pv, group_size=128))
        return self.codecs[layer]

    def put(self, layer: int, start: int, k: np.ndarray, v: np.ndarray):
        """k/v: [tokens, K, D] float arrays for one page."""
        ck, cv = self._codec_for(layer, k, v)
        page = Page(layer=layer, start=start, tokens=k.shape[0],
                    k_ct=ck.encode(k.astype(np.float32)),
                    v_ct=cv.encode(v.astype(np.float32)))
        self.pages[(layer, start)] = page
        return page

    def get(self, layer: int, start: int) -> Tuple[np.ndarray, np.ndarray]:
        page = self.pages[(layer, start)]
        ck, cv = self.codecs[layer]
        return ck.decode(page.k_ct), cv.decode(page.v_ct)

    @property
    def nbytes(self) -> int:
        return sum(p.k_ct.nbytes + p.v_ct.nbytes for p in self.pages.values())

    def raw_nbytes(self, itemsize: int = 2) -> int:
        return sum(2 * p.tokens * int(np.prod(p.k_ct.shape[1:])) * itemsize
                   for p in self.pages.values())
