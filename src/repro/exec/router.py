"""Vectorized primary-key routing (FNV-1a), bit-identical to
:func:`repro.db.schema.stable_key_hash`.

The scalar reference hashes each key with a per-byte Python loop; on the
insert hot path that loop is the router's whole cost.  This module folds
a *batch* of integer keys through the same byte sequence with numpy
masks — byte widths vary per key, so each byte position applies only
where that key still has data — and falls back to the scalar reference
for any batch holding non-``int`` parts (strings, bools) or magnitudes
near the int64 edge.  Identity against the reference is property-tested
in ``tests/test_exec_engine.py``.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.db.schema import Key, stable_key_hash

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)
_BYTE_MASK = np.uint64(0xFF)
_INT_TAG = np.uint64(0x69)

# Keys with |part| at or above this use the scalar reference: the width
# loop below covers <= 8 data bytes (int64 two's complement).
_VEC_LIMIT = 1 << 62


def _is_plain_int(v: object) -> bool:
    return type(v) is int and -_VEC_LIMIT < v < _VEC_LIMIT


def _fnv_byte(h: np.ndarray, b: np.ndarray | np.uint64) -> np.ndarray:
    return (h ^ b) * _FNV_PRIME


def _byte_widths(v: np.ndarray) -> np.ndarray:
    """Per-value signed little-endian byte count, matching the scalar
    reference's ``max(1, (abs(v).bit_length() + 8) // 8)``."""
    av = np.abs(v).astype(np.uint64)
    bl = np.zeros(v.shape, np.int64)
    tmp = av.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        m = tmp >= np.uint64(1 << shift)
        bl[m] += shift
        tmp[m] >>= np.uint64(shift)
    bl += (tmp > 0).astype(np.int64)
    return np.maximum(1, (bl + 8) // 8)


def stable_key_hash_batch(keys: Sequence[Key], n_parts: int) -> np.ndarray:
    """uint64 FNV-1a of each key, bit-identical to ``stable_key_hash``.

    ``n_parts`` is the schema's primary-key arity (1 => scalar keys).
    Vectorizes batches of plain-``int`` parts; any other part type drops
    the whole batch to the scalar reference (correct, just slower).
    """
    n = len(keys)
    if n == 0:
        return np.zeros(0, np.uint64)
    if n_parts == 1:
        cols: List[Sequence] = [keys]
    else:
        cols = [[k[p] for k in keys] for p in range(n_parts)]  # type: ignore[index]
    for col in cols:
        if not all(map(_is_plain_int, col)):
            return np.array([stable_key_hash(k) for k in keys], np.uint64)
    h = np.full(n, _FNV_OFFSET, np.uint64)
    for col in cols:
        v = np.asarray(col, np.int64)
        widths = _byte_widths(v)
        h = _fnv_byte(h, _INT_TAG)
        h = _fnv_byte(h, widths.astype(np.uint64) & _BYTE_MASK)
        u = v.astype(np.uint64)  # two's-complement bit pattern
        for i in range(int(widths.max())):
            active = widths > i
            b = (u >> np.uint64(8 * i)) & _BYTE_MASK
            h = np.where(active, _fnv_byte(h, b), h)
    return h


def shard_keys(keys: Sequence[Key], n_parts: int, n_shards: int) -> np.ndarray:
    """Shard index per key: ``stable_key_hash(k) % n_shards``, batched."""
    if n_shards == 1:
        return np.zeros(len(keys), np.int64)
    return (
        stable_key_hash_batch(keys, n_parts) % np.uint64(n_shards)
    ).astype(np.int64)
