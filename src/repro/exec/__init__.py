"""Compiled transaction execution: plan/run split over db verbs.

The engine lowers each batched table verb ONCE per (plan epoch, batch
bucket) into a :class:`PreparedOp` entry — vectorized key router, warmed
codec plan, packed Pallas tables — and replays it with no per-call
re-lowering (DESIGN.md §11).  :class:`Session` is the public execution
surface: prepared handles per (table, verb) plus convenience verbs.

The legacy ``Table.insert_many / get_many / update_many / delete_many``
signatures remain as thin compatibility shims that route through
``Table.prepare(verb).run(...)`` — one execution path.
"""

from .prepared import PreparedOp, Session
from .router import shard_keys, stable_key_hash_batch

__all__ = [
    "PreparedOp",
    "Session",
    "shard_keys",
    "stable_key_hash_batch",
]
