"""PreparedOp / Session: lower a table verb once, replay it many times.

A :class:`PreparedOp` is the plan/run split for ONE (table, verb) pair.
``run(...)`` looks up a lowered entry keyed by ``(plan epoch, batch
bucket, backend)``:

* **plan epoch** — the tuple of per-shard plan versions (a refit/migrate
  ``install_codec`` bumps a shard's version, changing the epoch and
  invalidating exactly that table's entries; merges that keep the plan
  leave the epoch unchanged, so their entries stay valid);
* **batch bucket** — the pow2-padded batch size, aligning the entry with
  the jit/trace cache of the Pallas decode kernel underneath;
* **backend** — the requested decode backend, because lowering for
  ``"pallas"`` additionally packs the plan's slot tables.

A hit replays cached artifacts — warmed codec plans, the vectorized key
router, packed kernel tables — with no per-call re-lowering.  A miss
re-lowers under the ``repro.exec.lower`` histogram (folded into the
``jit_compile`` phase; the nested ``codec.compile()`` work keeps its own
``repro.plan.compile`` leaf timer and is excluded from the lower span to
preserve leaf-disjoint phase sums).

One execution path: the legacy ``Table.insert_many/get_many/...`` verbs
are shims over ``Table.prepare(verb).run(...)``, and :class:`Session`
(from ``Database.session()``) caches prepared handles across tables.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

from repro import telemetry

from .router import shard_keys

if TYPE_CHECKING:
    from repro.db.database import Database
    from repro.db.schema import Key
    from repro.db.table import Table

_C_HIT = telemetry.counter("repro.exec.plan.hit")
_C_MISS = telemetry.counter("repro.exec.plan.miss")
_C_REPLAY = telemetry.counter("repro.exec.replay")
_C_REPLAY_ROWS = telemetry.counter("repro.exec.replay.rows")
_H_LOWER = telemetry.histogram("repro.exec.lower")

VERBS = ("insert", "get", "update", "delete")


def batch_bucket(n: int) -> int:
    """Pow2 batch-size bucket (floor 8): the padded size the lowered
    entry — and the Pallas decode trace underneath — is keyed by."""
    return 1 << max(3, (max(1, n) - 1).bit_length())


class _Lowered:
    """One cache entry: routing constants for the replay path."""

    __slots__ = ("epoch", "n_parts", "n_shards")

    def __init__(self, epoch: Tuple[int, ...], n_parts: int, n_shards: int):
        self.epoch = epoch
        self.n_parts = n_parts
        self.n_shards = n_shards


class PreparedOp:
    """Prepared handle for one (table, verb); obtain via ``Table.prepare``.

    ``run(...)`` takes the verb's batched arguments — ``run(rows)`` for
    insert, ``run(keys, backend=...)`` for get, ``run(keys, rows)`` for
    update, ``run(keys)`` for delete — and returns exactly what the
    legacy verb returns.
    """

    def __init__(self, table: "Table", verb: str) -> None:
        if verb not in VERBS:
            raise ValueError(f"unknown verb {verb!r}; expected one of {VERBS}")
        self.table = table
        self.verb = verb
        # (bucket, backend) -> lowered entry; at most one entry per slot,
        # so an epoch change invalidates by replacement on next run.
        self._cache: Dict[Tuple[int, Optional[str]], _Lowered] = {}
        self.hits = 0
        self.misses = 0

    # -- plan ------------------------------------------------------------
    def _lowered(self, n: int, backend: Optional[str]) -> _Lowered:
        table = self.table
        epoch = table.plan_epoch
        slot = (batch_bucket(n), backend)
        low = self._cache.get(slot)
        if low is not None and low.epoch == epoch:
            self.hits += 1
            _C_HIT.inc()
            return low
        self.misses += 1
        _C_MISS.inc()
        # Warm each shard's compiled plan OUTSIDE the lower span: compile
        # time stays in its own repro.plan.compile leaf (jit_compile
        # phase) and is not double-counted.
        plans = []
        for shard in table.shards:
            codec = getattr(shard, "codec", None)
            if codec is not None:
                plans.append(codec.compile())
        t0 = telemetry.clock()
        if backend == "pallas":
            for plan in plans:
                if plan is not None and plan.pallas_ok:
                    plan.pallas_tables()
        low = _Lowered(epoch, len(table.schema.primary_key), table.n_shards)
        self._cache[slot] = low
        _H_LOWER.observe_since(t0)
        return low

    def invalidate(self) -> None:
        """Drop every lowered entry (epoch checks make this automatic on
        version bumps; explicit invalidation is for tests/tooling)."""
        self._cache.clear()

    def cache_info(self) -> Dict[str, int]:
        return {
            "entries": len(self._cache),
            "hits": self.hits,
            "misses": self.misses,
        }

    # -- run -------------------------------------------------------------
    def run(self, *args: Any, backend: Optional[str] = None) -> Any:
        verb = self.verb
        table = self.table
        if verb == "insert":
            (rows,) = args
            rows = list(rows)
            if not rows:
                return []
            low = self._lowered(len(rows), None)
            _C_REPLAY.inc()
            _C_REPLAY_ROWS.add(len(rows))
            try:
                keys = table.schema.keys_of(rows)
            except KeyError:
                # Re-raise with the canonical "row missing column" message.
                for r in rows:
                    table.schema.validate_row(r)
                raise
            shards = shard_keys(keys, low.n_parts, low.n_shards)
            return table._exec_insert(rows, keys, shards)
        if verb == "get":
            (keys,) = args
            self._lowered(len(keys), backend)
            _C_REPLAY.inc()
            _C_REPLAY_ROWS.add(len(keys))
            return table._exec_get(keys, backend)
        if verb == "update":
            keys, rows = args
            self._lowered(len(keys), None)
            _C_REPLAY.inc()
            _C_REPLAY_ROWS.add(len(keys))
            return table._exec_update(keys, rows)
        keys = args[0]  # delete
        self._lowered(len(keys), None)
        _C_REPLAY.inc()
        _C_REPLAY_ROWS.add(len(keys))
        return table._exec_delete(keys)


class Session:
    """Execution surface over a :class:`~repro.db.Database`.

    Caches one prepared handle per (table, verb) so a transaction loop
    replays lowered plans without re-resolving tables or verbs:

    >>> ses = db.session()
    >>> ses.insert("orders", rows)
    >>> ses.get("customer", keys, backend="pallas")

    ``prepared(table, verb)`` exposes the underlying handles; ``query``
    passes through to the OLAP entry point unchanged.
    """

    def __init__(self, db: "Database") -> None:
        self._db = db
        self._ops: Dict[Tuple[str, str], PreparedOp] = {}

    def table(self, name: str) -> "Table":
        return self._db.table(name)

    def prepared(self, table: str, verb: str) -> PreparedOp:
        slot = (table, verb)
        op = self._ops.get(slot)
        if op is None:
            op = self._ops[slot] = self._db.table(table).prepare(verb)
        return op

    # -- batched verbs ----------------------------------------------------
    def insert(self, table: str, rows: Sequence[Dict[str, Any]]) -> List["Key"]:
        return self.prepared(table, "insert").run(rows)

    def get(
        self,
        table: str,
        keys: Sequence["Key"],
        backend: Optional[str] = None,
    ) -> List[Optional[Dict[str, Any]]]:
        return self.prepared(table, "get").run(keys, backend=backend)

    def update(
        self,
        table: str,
        keys: Sequence["Key"],
        rows: Sequence[Dict[str, Any]],
    ) -> None:
        return self.prepared(table, "update").run(keys, rows)

    def delete(self, table: str, keys: Sequence["Key"]) -> int:
        return self.prepared(table, "delete").run(keys)

    def query(self, table: str, *args: Any, **kwargs: Any) -> Any:
        return self._db.query(table, *args, **kwargs)
