"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

These mirror the numpy host codecs in :mod:`repro.core` but stay inside jnp
so they can be jit-compiled and compared against kernel outputs on any
backend.  Tests sweep shapes/dtypes and assert allclose/exact-equal between
``kernels.ops`` and these references.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

TOTAL_BITS = 16
TOTAL = 1 << TOTAL_BITS


def pack_tables(coder) -> Tuple[jnp.ndarray, int]:
    """Bucket-major decode table of a DiscreteCoder: [M, 7] float32.

    Columns: threshold, sym_u, sym_v, ja, jb, k_u, k_v.  All magnitudes are
    < 2**18, hence exactly representable in float32 (MXU-friendly one-hot
    matmul lookups).
    """
    import numpy as np
    t = coder.tables
    k_u = t.k_of[t.sym_u].astype(np.int64)
    k_v = t.k_of[t.sym_v].astype(np.int64)
    tab = np.stack(
        [t.threshold.astype(np.int64), t.sym_u, t.sym_v, t.ja, t.jb, k_u, k_v], axis=1
    ).astype(np.float32)
    return jnp.asarray(tab), int(t.m_bits)


def pack_tables_uniform(coder) -> Tuple[jnp.ndarray, int]:
    """Bucket-major decode table of a UniformCoder in the same [M, 7] layout.

    The uniform coder's segments are contiguous: symbol ``j`` owns
    ``[ceil(j*2^16/G), ceil((j+1)*2^16/G))``.  With ``m = ceil(log2 G)`` the
    bucket width ``W = 2^(16-m)`` is <= the minimum segment length, so every
    bucket intersects at most two segments — the one owning the bucket's
    first code and (possibly) its successor — which is exactly the
    (threshold, sym_u, sym_v) split the delayed-decode kernel consumes.
    """
    import numpy as np
    G = int(coder.G)
    m = max(0, int(np.ceil(np.log2(G)))) if G > 1 else 0
    M = 1 << m
    W = TOTAL >> m
    tab = np.zeros((M, 7), np.float32)
    for p in range(M):
        c0 = p * W
        j0 = (c0 * G) >> TOTAL_BITS
        lo0 = -((-j0 * TOTAL) // G)            # ceil(j0 * 2^16 / G)
        b = -((-(j0 + 1) * TOTAL) // G)        # start of segment j0+1
        if b >= c0 + W:                        # bucket entirely inside j0
            tab[p] = (0, j0, j0, lo0, lo0, b - lo0, b - lo0)
        else:                                  # boundary b interior: two syms
            b2 = -((-(j0 + 2) * TOTAL) // G)
            tab[p] = (b - c0, j0, j0 + 1, lo0, b, b - lo0, b2 - b)
    return jnp.asarray(tab), m


def alias_decode_ref(
    codes: jax.Array, table: jax.Array, m_bits: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """codes int32[N] -> (sym, a, k) int32 — Algorithm 6 / Inv-Translate."""
    codes = codes.astype(jnp.int32)
    shift = TOTAL_BITS - m_bits
    p = codes >> shift
    low = codes & ((1 << shift) - 1)
    row = table[p]  # gather in the reference; one-hot matmul in the kernel
    hit = low < row[:, 0].astype(jnp.int32)
    sym = jnp.where(hit, row[:, 1], row[:, 2]).astype(jnp.int32)
    a = codes - jnp.where(hit, row[:, 3], row[:, 4]).astype(jnp.int32)
    k = jnp.where(hit, row[:, 5], row[:, 6]).astype(jnp.int32)
    return sym, a, k


def delayed_decode_ref(
    codes_dense: jax.Array, tables: jax.Array, m_bits: Tuple[int, ...]
) -> jax.Array:
    """Batched delayed decoding (Algorithm 5), division-free uint32 math.

    codes_dense: int32[T, S] physical codes, left-justified per tuple.
    tables: float32[S, M, 7] per-slot alias tables (padded to max M).
    Returns syms int32[T, S].
    """
    T, S = codes_dense.shape
    v_info = jnp.zeros((T,), jnp.uint32)
    v_size = jnp.ones((T,), jnp.uint32)
    pending = jnp.zeros((T,), bool)
    pend_code = jnp.zeros((T,), jnp.int32)
    cursor = jnp.zeros((T,), jnp.int32)
    out = []
    lam = jnp.uint32(TOTAL)
    for s in range(S):
        stream = jnp.take_along_axis(codes_dense, cursor[:, None], axis=1)[:, 0]
        code = jnp.where(pending, pend_code, stream)
        cursor = cursor + jnp.where(pending, 0, 1)
        sym, a, k = alias_decode_ref(code, tables[s], m_bits[s])
        out.append(sym)
        ku = k.astype(jnp.uint32)
        v_info = v_info * ku + a.astype(jnp.uint32)   # exact: result < 2**32
        v_size = v_size * ku
        pending = v_size >= lam
        pend_code = (v_info & jnp.uint32(0xFFFF)).astype(jnp.int32)
        v_info = jnp.where(pending, v_info >> 16, v_info)
        v_size = jnp.where(pending, v_size >> 16, v_size)
    return jnp.stack(out, axis=1)


def twolevel_dequant_ref(
    bucket: jax.Array, digit: jax.Array, vmin: float, p: float, G: int
) -> jax.Array:
    """Two-level numeric reconstruction (§4.2): v = vmin + (i*G + j + .5)p."""
    q = bucket.astype(jnp.float32) * G + digit.astype(jnp.float32)
    return vmin + (q + 0.5) * p


def kv_attention_int8_ref(
    q: jax.Array,
    kq: jax.Array,
    vq: jax.Array,
    k_scale: jax.Array,
    v_scale: jax.Array,
    length: jax.Array,
) -> jax.Array:
    """Decode attention over int8-quantized KV with per-(token, head) scales.

    q: [B, H, D] (bf16/f32); kq/vq: int8[B, S, K, D];
    k_scale/v_scale: f32[B, S, K]; length: [] valid cache length.
    Returns [B, H, D] float32.
    """
    B, H, D = q.shape
    _, S, K, _ = kq.shape
    G = H // K
    kf = kq.astype(jnp.float32) * k_scale[..., None]
    vf = vq.astype(jnp.float32) * v_scale[..., None]
    qf = q.reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return o.reshape(B, H, D)
