"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (TPU v5e
is the compile *target*); on real TPUs callers pass ``interpret=False``.
Helpers convert host-side coder objects into the dense device table layout.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.coders import DiscreteCoder, UniformCoder
from . import ref as ref_lib
from .alias_decode import alias_decode
from .delayed_decode import delayed_decode
from .flash_prefill import flash_prefill_attention
from .kv_attention import kv_attention_int8

__all__ = [
    "alias_decode",
    "delayed_decode",
    "kv_attention_int8",
    "flash_prefill_attention",
    "pack_slot_tables",
    "dense_codes",
]


def pack_slot_tables(coders: Sequence) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """Stack per-slot decode tables into [S, M_max, 7] (padded) + m_bits.

    Accepts a mix of :class:`DiscreteCoder` (alias layout, Appendix C) and
    :class:`UniformCoder` (contiguous segments) — both lower to the same
    bucket-major (threshold, sym_u, sym_v, ja, jb, k_u, k_v) row format the
    delayed-decode kernel consumes.
    """
    tabs: List[np.ndarray] = []
    mbits: List[int] = []
    for c in coders:
        if isinstance(c, DiscreteCoder):
            t, m = ref_lib.pack_tables(c)
        elif isinstance(c, UniformCoder):
            t, m = ref_lib.pack_tables_uniform(c)
        else:
            raise TypeError(f"cannot pack device tables for {type(c).__name__}")
        tabs.append(np.asarray(t))
        mbits.append(m)
    M = max(t.shape[0] for t in tabs)
    out = np.zeros((len(tabs), M, 7), np.float32)
    for i, t in enumerate(tabs):
        out[i, :t.shape[0]] = t
    return jnp.asarray(out), tuple(mbits)


def dense_codes(codes: np.ndarray, offsets: np.ndarray, n_slots: int) -> np.ndarray:
    """CSR (codes, offsets) -> dense [T, S] int32, left-justified."""
    T = offsets.size - 1
    out = np.zeros((T, n_slots), np.int32)
    lens = (offsets[1:] - offsets[:-1]).astype(np.int64)
    cols = np.arange(n_slots)[None, :]
    mask = cols < lens[:, None]
    idx = offsets[:-1, None] + np.minimum(cols, np.maximum(lens[:, None] - 1, 0))
    out = np.where(mask, codes[idx], 0).astype(np.int32)
    return out
