"""Pallas TPU kernel: batched constant-time Inv-Translate (Algorithm 6).

The alias tables live in VMEM; the bucket lookup is a one-hot × table
matmul (MXU) instead of a gather — the TPU-native formulation of the
paper's "O(1) decode" (DESIGN.md §2).  Table entries are < 2**18 so
float32 matmul accumulation is exact.

Block layout: codes are tiled into (BLOCK,) vectors over a 1-D grid; the
[M, 7] table is broadcast to every tile (it is tiny: M <= 2**m buckets).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOTAL_BITS = 16
BLOCK = 1024


def _alias_kernel(m_bits: int, codes_ref, table_ref, sym_ref, a_ref, k_ref):
    codes = codes_ref[...]                                   # [BLOCK] int32
    table = table_ref[...]                                   # [M, 7] f32
    M = table.shape[0]
    shift = TOTAL_BITS - m_bits
    p = codes >> shift
    low = codes & ((1 << shift) - 1)
    # one-hot [BLOCK, M] @ [M, 7] -> per-code table row (exact in f32)
    onehot = (p[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)
              ).astype(jnp.float32)
    rows = jnp.dot(onehot, table, preferred_element_type=jnp.float32)
    thresh = rows[:, 0].astype(jnp.int32)
    hit = low < thresh
    sym = jnp.where(hit, rows[:, 1], rows[:, 2]).astype(jnp.int32)
    a = codes - jnp.where(hit, rows[:, 3], rows[:, 4]).astype(jnp.int32)
    k = jnp.where(hit, rows[:, 5], rows[:, 6]).astype(jnp.int32)
    sym_ref[...] = sym
    a_ref[...] = a
    k_ref[...] = k


@functools.partial(jax.jit, static_argnames=("m_bits", "interpret"))
def alias_decode(
    codes: jax.Array, table: jax.Array, m_bits: int, interpret: bool = True
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """codes int32[N] + table f32[M, 7] -> (sym, a, k) int32[N]."""
    N = codes.shape[0]
    n_blocks = -(-N // BLOCK)
    padded = n_blocks * BLOCK
    codes_p = jnp.pad(codes.astype(jnp.int32), (0, padded - N))
    M = table.shape[0]

    out_shape = [jax.ShapeDtypeStruct((padded,), jnp.int32)] * 3
    grid = (n_blocks,)
    sym, a, k = pl.pallas_call(
        functools.partial(_alias_kernel, m_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((M, 7), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(codes_p, table)
    return sym[:N], a[:N], k[:N]
