"""Pallas TPU kernel: batched delayed-coding decode (Algorithm 5).

The paper's CPU decoder is a scalar loop; the TPU restructuring
(DESIGN.md §2) observes the virtual-bits chain is sequential only *within*
a tuple, so a VMEM tile holds a block of tuples and the kernel unrolls the
slot chain across the whole tile:

* the mixed-radix accumulator update ``V_info = V_info*k + a`` needs no
  division and stays < 2**32 (paper §5.1 invariant), so uint32 lane
  arithmetic is *exact*;
* per-slot alias-table lookups are one-hot × table matmuls (MXU);
* the "read from stream or virtual bits" choice is a select; the stream
  cursor advance is a masked add, and the cursor read is a row-wise
  one-hot dot (no gathers anywhere).

Inputs are the dense per-tuple layout produced by the host encoder
(``codes_dense[T, S]``, left-justified).  Tables: float32[S, M, 7].
"""

from __future__ import annotations

import functools
from typing import Set, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import telemetry

TOTAL_BITS = 16
LAM = 1 << 16  # python literal; materialized inside the kernel
BLOCK_T = 256

# jit-compile observability (DESIGN.md §9): the first call for a new
# (shape, m_bits) signature traces + compiles; later calls replay.  The
# first-call wall time is attributed to the jit_compile phase (it is
# compile-dominated), cache hits are counted separately.
_SEEN_SIGS: Set[Tuple] = set()
_H_JIT = telemetry.histogram("repro.plan.compile.pallas_jit")
_C_JIT_MISS = telemetry.counter("repro.plan.cache.pallas_miss")
_C_JIT_HIT = telemetry.counter("repro.plan.cache.pallas_hit")


def _delayed_kernel(m_bits: Tuple[int, ...], codes_ref, tables_ref, out_ref):
    codes = codes_ref[...]                                  # [BT, S] int32
    BT, S = codes.shape
    tables = tables_ref[...]                                # [S, M, 7] f32
    M = tables.shape[1]

    v_info = jnp.zeros((BT,), jnp.uint32)
    v_size = jnp.ones((BT,), jnp.uint32)
    pending = jnp.zeros((BT,), bool)
    pend_code = jnp.zeros((BT,), jnp.int32)
    cursor = jnp.zeros((BT,), jnp.int32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)

    syms = []
    for s in range(S):
        # stream read: row-wise one-hot dot against the cursor (no gather)
        sel = (cursor[:, None] == cols).astype(jnp.int32)
        stream = jnp.sum(codes * sel, axis=1)
        code = jnp.where(pending, pend_code, stream)
        cursor = cursor + jnp.where(pending, 0, 1)

        # alias lookup via one-hot matmul (exact in f32)
        shift = TOTAL_BITS - m_bits[s]
        p = code >> shift
        low = code & ((1 << shift) - 1)
        onehot = (p[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)).astype(
            jnp.float32
        )
        rows = jnp.dot(onehot, tables[s], preferred_element_type=jnp.float32)
        hit = low < rows[:, 0].astype(jnp.int32)
        sym = jnp.where(hit, rows[:, 1], rows[:, 2]).astype(jnp.int32)
        a = code - jnp.where(hit, rows[:, 3], rows[:, 4]).astype(jnp.int32)
        k = jnp.where(hit, rows[:, 5], rows[:, 6]).astype(jnp.uint32)
        syms.append(sym)

        # division-free mixed-radix update (uint32-exact, §5.1)
        v_info = v_info * k + a.astype(jnp.uint32)
        v_size = v_size * k
        pending = v_size >= jnp.uint32(LAM)
        pend_code = (v_info & jnp.uint32(0xFFFF)).astype(jnp.int32)
        v_info = jnp.where(pending, v_info >> 16, v_info)
        v_size = jnp.where(pending, v_size >> 16, v_size)

    out_ref[...] = jnp.stack(syms, axis=1)


def delayed_decode(
    codes_dense: jax.Array,
    tables: jax.Array,
    m_bits: Tuple[int, ...],
    interpret: bool = True,
) -> jax.Array:
    """codes int32[T, S] + tables f32[S, M, 7] -> syms int32[T, S].

    Thin telemetry shim over the jitted kernel: counts plan-cache
    hits/misses per trace signature and books first-call (compile) time.
    """
    sig = (codes_dense.shape, tables.shape, tuple(m_bits), bool(interpret))
    if sig in _SEEN_SIGS:
        _C_JIT_HIT.inc()
        return _delayed_decode_jit(codes_dense, tables, m_bits, interpret)
    _SEEN_SIGS.add(sig)
    _C_JIT_MISS.inc()
    t0 = telemetry.clock()
    out = _delayed_decode_jit(codes_dense, tables, m_bits, interpret)
    _H_JIT.observe_since(t0)
    return out


@functools.partial(jax.jit, static_argnames=("m_bits", "interpret"))
def _delayed_decode_jit(
    codes_dense: jax.Array,
    tables: jax.Array,
    m_bits: Tuple[int, ...],
    interpret: bool = True,
) -> jax.Array:
    T, S = codes_dense.shape
    n_blocks = -(-T // BLOCK_T)
    padded = n_blocks * BLOCK_T
    codes_p = jnp.pad(codes_dense.astype(jnp.int32), ((0, padded - T), (0, 0)))
    M = tables.shape[1]
    out = pl.pallas_call(
        functools.partial(_delayed_kernel, tuple(m_bits)),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_T, S), lambda i: (i, 0)),
            pl.BlockSpec((S, M, 7), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, S), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, S), jnp.int32),
        interpret=interpret,
    )(codes_p, tables)
    return out[:T]
