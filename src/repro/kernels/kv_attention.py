"""Pallas TPU kernel: decode attention over int8 semantically-quantized KV.

The compressed-KV integration point (DESIGN.md §3.2): KV pages are stored
int8 with per-(token, kv-head) scales fitted by the numeric semantic model;
this kernel dequantizes page tiles *in VMEM* on access and runs
flash-decoding (online softmax over sequence chunks) — the paper's
"decompress on point access" flow with the tile as the access unit.

Layout: grid over KV-sequence chunks; carry (acc, m, l) in VMEM scratch.
q: [B, H, D]; kq/vq: int8[B, S, K, D]; scales f32[B, S, K].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
CHUNK = 512


def _kv_attn_kernel(
    scale_q: float,
    length: int,
    q_ref,
    kq_ref,
    ks_ref,
    vq_ref,
    vs_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
):
    ci = pl.program_id(0)
    nc = pl.num_programs(0)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32) * scale_q     # [B, K, G, D]
    kq = kq_ref[...].astype(jnp.float32)             # [B, C, K, D]
    ks = ks_ref[...]                                 # [B, C, K]
    vq = vq_ref[...].astype(jnp.float32)
    vs = vs_ref[...]
    B, C, K, D = kq.shape

    kf = kq * ks[..., None]
    vf = vq * vs[..., None]
    s = jnp.einsum("bkgd,bckd->bkgc", q, kf)          # [B, K, G, C]
    pos = ci * C + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, C), 3)
    s = jnp.where(pos < length, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum("bkgc,bckd->bkgd", p, vf)
    m_ref[...] = m_new

    @pl.when(ci == nc - 1)
    def _fin():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None])


@ functools.partial(jax.jit, static_argnames= ("length_static", "interpret", "chunk"))
def kv_attention_int8(
    q: jax.Array,
    kq: jax.Array,
    ks: jax.Array,
    vq: jax.Array,
    vs: jax.Array,
    length_static: int,
    chunk: int = CHUNK,
    interpret: bool = True,
) -> jax.Array:
    """Flash-decoding over int8 KV. Returns [B, H, D] float32.

    q: [B, H, D]; kq/vq: int8[B, S, K, D]; ks/vs: f32[B, S, K];
    length_static: number of valid cache entries (static for the dry-run
    tile schedule; masking handles the tail).
    """
    B, H, D = q.shape
    _, S, K, _ = kq.shape
    G = H // K
    nc = -(-S // chunk)
    qr = q.reshape(B, K, G, D)

    out = pl.pallas_call(
        functools.partial(_kv_attn_kernel, D ** -0.5, length_static),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((B, K, G, D), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((B, chunk, K, D), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((B, chunk, K), lambda i: (0, i, 0)),
            pl.BlockSpec((B, chunk, K, D), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((B, chunk, K), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((B, K, G, D), lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B, K, G, D), jnp.float32),   # acc
            pltpu.VMEM((B, K, G), jnp.float32),      # running max
            pltpu.VMEM((B, K, G), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(qr, kq, ks, vq, vs)
    return out.reshape(B, H, D)
