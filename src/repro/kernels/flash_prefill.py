"""Pallas TPU kernel: fused prefill attention (flash-attention schedule).

The §Perf cell-3 structural fix: the XLA chunked attention round-trips
S²-sized score/probability chunks through HBM (~24 B per score element
measured); this kernel keeps the (q-tile × kv-chunk) score tile in VMEM so
per-layer attention HBM traffic collapses to the q/k/v/o IO.

Grid: (q_tiles, kv_chunks) with the kv dimension innermost; online-softmax
accumulators live in VMEM scratch and the output tile is emitted on the
last kv step.  Causal and sliding-window masks come from position
arithmetic.  GQA layout: q [B, Sq, K, G, D], k/v [B, Sk, K, D].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(
    scale: float,
    causal: bool,
    window: int,
    sq: int,
    sk: int,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
):
    qi = pl.program_id(0)
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32) * scale      # [B, qb, K, G, D]
    k = k_ref[...].astype(jnp.float32)              # [B, kc, K, D]
    v = v_ref[...].astype(jnp.float32)
    B, qb, K, G, D = q.shape
    kc = k.shape[1]

    s = jnp.einsum("bqkgd,bckd->bqkgc", q, k)       # VMEM-resident tile
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kc), 0)
    k_pos = kj * kc + jax.lax.broadcasted_iota(jnp.int32, (qb, kc), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "bqkgc,bckd->bqkgd", p, v
    )
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _fin():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]).astype(
            o_ref.dtype
        )


@ functools.partial(
    jax.jit, static_argnames= ("causal", "window", "q_block", "kv_chunk", "interpret")
)
def flash_prefill_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_chunk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """q: [B, Sq, H, D]; k/v: [B, Sk, K, D].  Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    qb = min(q_block, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qb), -(-Sk // kc)
    qr = jnp.pad(
        q.reshape(B, Sq, K, G, D), ((0, 0), (0, nq * qb - Sq), (0, 0), (0, 0), (0, 0))
    )
    kr = jnp.pad(k, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, D ** -0.5, causal, window, Sq, Sk),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((B, qb, K, G, D), lambda i, j: (0, i, 0, 0, 0)),
            pl.BlockSpec((B, kc, K, D), lambda i, j: (0, j, 0, 0)),
            pl.BlockSpec((B, kc, K, D), lambda i, j: (0, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, qb, K, G, D), lambda i, j: (0, i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * qb, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, qb, K, G, D), jnp.float32),
            pltpu.VMEM((B, qb, K, G), jnp.float32),
            pltpu.VMEM((B, qb, K, G), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :Sq].reshape(B, Sq, H, D)
