"""Batched serving engine: prefill -> decode with an explicit state.

Requests are served in static batches (the production pattern for fixed
shapes): ``generate`` prefills the prompt batch (cache-collecting forward),
then iterates jitted single-token decode steps with greedy/temperature
sampling.  The KV cache can be offloaded per-page to the Blitzcrank
compressed host store (`--kv host-blz`), reproducing the paper's
larger-than-memory flow (§7.2) at serving time: hot pages stay on device,
cold pages live compressed in host RAM.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.tensor.kv_cache import CompressedKVStore


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray           # [B, T] generated ids
    logits_last: np.ndarray
    kv_store_stats: Optional[Dict] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 donate: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, s, t: tfm.decode_step(p, cfg, s, t),
            donate_argnums=(1,) if donate else ())
        self._flush = jax.jit(lambda s: tfm.flush_tail(cfg, s),
                              donate_argnums=(0,) if donate else ())
        self._prefill = jax.jit(
            lambda p, toks, kw: tfm.forward(p, cfg, toks, collect_cache=True,
                                            **kw),
            static_argnames=())

    # ------------------------------------------------------------------
    def prefill(self, tokens: jax.Array, prefix_embeds=None,
                encoder_frames=None):
        """Returns (last logits [B,1,V], decode state)."""
        cfg = self.cfg
        B, S = tokens.shape
        kw = {}
        if prefix_embeds is not None:
            kw["prefix_embeds"] = prefix_embeds
        if encoder_frames is not None:
            kw["encoder_frames"] = encoder_frames
        h, _, cache = tfm.forward(self.params, cfg, tokens,
                                  collect_cache=True, **kw)
        logits = tfm.unembed(self.params, cfg, h[:, -1:])
        state = tfm.init_decode_state(cfg, B, self.max_len)
        state["pos"] = jnp.asarray(S, jnp.int32)
        if "k" in cache:
            # split prompt KV into committed pages [0, base) + write tail
            T = state["k_tail"].shape[2]
            base = (S // T) * T if S % T else max(S - T, 0)
            n_tail = S - base
            kt = jnp.zeros_like(state["k_tail"])
            vt = jnp.zeros_like(state["v_tail"])
            kt = kt.at[:, :, :n_tail].set(cache["k"][:, :, base:S])
            vt = vt.at[:, :, :n_tail].set(cache["v"][:, :, base:S])
            state["k_tail"], state["v_tail"] = kt, vt
            if base > 0:
                filler = dict(state)
                filler["pos"] = jnp.asarray(base, jnp.int32)
                filler["k_tail"] = cache["k"][:, :, :base]
                filler["v_tail"] = cache["v"][:, :, :base]
                # commit the prompt pages in T-sized chunks
                for start in range(0, base, T):
                    chunk = dict(state)
                    chunk["pos"] = jnp.asarray(start + T, jnp.int32)
                    chunk["k_tail"] = cache["k"][:, :, start:start + T]
                    chunk["v_tail"] = cache["v"][:, :, start:start + T]
                    chunk["k"], chunk["v"] = state["k"], state["v"]
                    if self.cfg.kv_quant:
                        chunk["k_scale"] = state["k_scale"]
                        chunk["v_scale"] = state["v_scale"]
                    committed = tfm.flush_tail(self.cfg, chunk)
                    state["k"], state["v"] = committed["k"], committed["v"]
                    if self.cfg.kv_quant:
                        state["k_scale"] = committed["k_scale"]
                        state["v_scale"] = committed["v_scale"]
        for key in ("cross_k", "cross_v", "mamba", "mlstm", "slstm"):
            if key in cache:
                state[key] = cache[key]
        return logits, state

    # ------------------------------------------------------------------
    def generate(self, tokens: np.ndarray, max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 prefix_embeds=None, encoder_frames=None) -> GenerateResult:
        logits, state = self.prefill(jnp.asarray(tokens),
                                     prefix_embeds=prefix_embeds,
                                     encoder_frames=encoder_frames)
        key = jax.random.PRNGKey(seed)
        out: List[np.ndarray] = []
        T = state["k_tail"].shape[2] if "k_tail" in state else 0
        cur = self._sample(logits, temperature, key)
        for t in range(max_new):
            out.append(np.asarray(cur[:, 0]))
            logits, state = self._decode(self.params, state, cur)
            if T and int(state["pos"]) % T == 0:
                state = self._flush(state)  # amortized page commit
            key, sub = jax.random.split(key)
            cur = self._sample(logits, temperature, sub)
        return GenerateResult(tokens=np.stack(out, 1),
                              logits_last=np.asarray(logits))

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1:] / temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def offload_kv(self, state, page_tokens: int = 128,
                   store: Optional[CompressedKVStore] = None
                   ) -> CompressedKVStore:
        """Move the filled KV prefix to the compressed host store (§7.2)."""
        store = store or CompressedKVStore(page_tokens=page_tokens)
        if "k" not in state:
            return store
        pos = int(state["pos"])
        k = np.asarray(state["k"][:, :, :pos], np.float32)
        v = np.asarray(state["v"][:, :, :pos], np.float32)
        L, B = k.shape[0], k.shape[1]
        for layer in range(L):
            for start in range(0, pos, page_tokens):
                end = min(start + page_tokens, pos)
                # page = [tokens, B*K, D] viewed per layer
                kp = k[layer, :, start:end].reshape(end - start, -1, k.shape[-1])
                vp = v[layer, :, start:end].reshape(end - start, -1, v.shape[-1])
                store.put(layer, start, kp, vp)
        return store

    def fetch_kv(self, store: CompressedKVStore, state, layer: int,
                 start: int):
        """Random access into the compressed store (paper's point query)."""
        return store.get(layer, start)
