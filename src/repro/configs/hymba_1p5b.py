"""hymba-1.5b [arXiv:2411.13676; hf] — hybrid: parallel attention + mamba.

32L d_model=1600 25H (GQA kv=5) d_ff=5504, ssm_state=16, vocab=32001 —
each block runs attention heads and mamba (selective-SSM) heads in parallel
and averages their (normalized) outputs.  Sliding-window attention except at
the first/middle/last layers, so long_500k decode applies.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    d_head=64,
    act="swiglu",
    attn_pattern="local_mostly",
    window=1024,
    ssm=SSMConfig(kind="mamba", d_state=16),
)
