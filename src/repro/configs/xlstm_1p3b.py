"""xlstm-1.3b [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 — xLSTM[7:1]: every 8th block
is sLSTM (scalar memory, scan), the rest mLSTM (matrix memory, chunkwise
parallel).  Sub-quadratic: runs the long_500k decode shape.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,              # xLSTM blocks carry their own up-projection
    vocab=50304,
    d_head=512,
    act="gelu",
    ssm=SSMConfig(kind="xlstm", mlstm_per_slstm=7, chunk=256),
)
