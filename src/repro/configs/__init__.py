"""Architecture registry: ``get_config(arch_id)`` + reduced smoke configs."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import (EncoderConfig, ModelConfig, MoEConfig,
                                 SHAPES, SHAPES_BY_NAME, ShapeConfig,
                                 SSMConfig, shape_applies)

from . import (deepseek_moe_16b, gemma2_9b, hymba_1p5b, internvl2_26b,
               nemotron_4_15b, phi3_mini_3p8b, phi3p5_moe_42b,
               phi4_mini_3p8b, whisper_tiny, xlstm_1p3b)

_REGISTRY: Dict[str, ModelConfig] = {
    "phi4-mini-3.8b": phi4_mini_3p8b.CONFIG,
    "gemma2-9b": gemma2_9b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3p8b.CONFIG,
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3p5_moe_42b.CONFIG,
    "whisper-tiny": whisper_tiny.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
    "xlstm-1.3b": xlstm_1p3b.CONFIG,
    "hymba-1.5b": hymba_1p5b.CONFIG,
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]


def reduced_config(arch: str) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (shapes/NaN checks)."""
    cfg = get_config(arch)
    small: Dict = dict(
        n_layers=2 if cfg.family != "ssm" else 8,   # keep one sLSTM group
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 // cfg.q_per_kv) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        window=16,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1))
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, chunk=8)
    if cfg.encoder is not None:
        small["encoder"] = EncoderConfig(n_layers=2, n_ctx=16)
    if cfg.n_prefix:
        small["n_prefix"] = 4
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)


__all__ = ["ARCH_IDS", "get_config", "reduced_config", "ModelConfig",
           "MoEConfig", "SSMConfig", "EncoderConfig", "ShapeConfig",
           "SHAPES", "SHAPES_BY_NAME", "shape_applies"]
