"""gemma2-9b [arXiv:2408.00118; hf] — dense GQA, local/global alternating.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 — alternating
sliding-window (4096) and global attention, attention/final logit softcaps.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    d_head=256,
    act="swiglu",
    attn_pattern="local_global",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    embed_scale=True,
)
