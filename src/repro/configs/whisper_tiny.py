"""whisper-tiny [arXiv:2212.04356; unverified] — encoder-decoder (audio).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 — enc-dec; the conv audio
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(B, 1500, 384), per the task statement.
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    d_head=64,
    act="gelu",
    encoder=EncoderConfig(n_layers=4, n_ctx=1500),
    frontend="audio",
)
