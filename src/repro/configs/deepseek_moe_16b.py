"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE.

28L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=102400,
2 shared + 64 routed experts, top-6, first layer dense (d_ff=10944).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,          # the dense first layer's FFN width
    vocab=102400,
    d_head=128,
    act="swiglu",
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_expert=1408,
        first_k_dense=1,
    ),
)
