"""internvl2-26b [arXiv:2404.16821; hf] — VLM: InternViT + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT
vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings occupying the first ``n_prefix`` sequence positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    d_head=128,
    act="swiglu",
    frontend="vision",
    n_prefix=256,  # ViT patch embeddings per image tile
)
