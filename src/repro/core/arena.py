"""Out-of-core cold tier (paper §6.4): a paged on-disk arena + clock policy.

The paper's final claim is that for data sets larger than physical memory,
Blitzcrank "helps the database sustain a high throughput for more
transactions before the I/O overhead dominates".  This module provides the
two pieces the stores need to reproduce that experiment:

* :class:`DiskArena` — an append-only, page-aligned spill file holding the
  compressed code runs of cold blocks.  Extents are byte-addressed
  ``(offset, length)`` pairs owned by the caller; freed extents are
  accounted and reclaimed by an in-place ascending compaction
  (:meth:`compact`), so the file never grows without bound.  Victim runs
  are always written in arena byte order (ascending in-memory offset), so
  blocks that were adjacent in the memory arena stay adjacent on disk and
  a fault over a contiguous range coalesces into one read.

* :class:`ResidencyManager` — the policy half: a memory budget, a
  clock/second-chance hand over per-block referenced bits, and the
  spill/fault counters surfaced through ``stats()``.  The sweep itself is
  driven by the owning store (it owns the per-block arrays); the manager
  decides *how much* to free and records what happened.

The residency lifecycle of a block (DESIGN.md §6)::

    resident --(clock finds ref=0)--> spilled --(get_many miss)--> faulted
       ^                                 |                            |
       +--------- rewrite() keeps tags --+----------- promoted ------+

Hot-path invariant: a fault costs one (coalesced) disk read plus one
vectorized batch decode — never per-row work.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

# Spill segments are aligned to this many bytes so compaction and
# sequential fault-in behave like page I/O rather than byte soup.
PAGE_BYTES = 4096


class DiskArena:
    """Append-only spill file with free-extent accounting and compaction.

    ``path=None`` (the default) uses an anonymous temp file that the OS
    reclaims when the arena is closed or the process exits — spill data
    never outlives the store that wrote it.  All offsets and lengths are
    in bytes.
    """

    def __init__(self, path: Optional[str] = None, page_bytes: int = PAGE_BYTES):
        if page_bytes <= 0:
            raise ValueError("page_bytes must be positive")
        self.page_bytes = int(page_bytes)
        if path is None:
            self._file = tempfile.TemporaryFile(prefix="blitz-spill-")
        else:
            self._file = open(path, "w+b")
        self._fd = self._file.fileno()
        self._tail = 0  # next unallocated byte (page-aligned per segment)
        self._live = 0  # live payload bytes
        self._freed = 0  # dead payload bytes awaiting compaction
        self.writes = 0
        self.reads = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.compactions = 0

    # -- allocation ------------------------------------------------------
    def write(self, payload: bytes) -> int:
        """Append one segment, returning its byte offset.

        Segments start page-aligned; interior layout (many block runs per
        segment) is the caller's business.
        """
        off = -self._tail % self.page_bytes + self._tail
        n = len(payload)
        os.pwrite(self._fd, payload, off)
        self._tail = off + n
        self._live += n
        self.writes += 1
        self.bytes_written += n
        return off

    def free(self, offset: int, length: int) -> None:
        """Mark ``length`` bytes at ``offset`` dead (reclaimed at compact)."""
        self._live -= int(length)
        self._freed += int(length)

    # -- reads -----------------------------------------------------------
    def read(self, offset: int, length: int) -> bytes:
        self.reads += 1
        self.bytes_read += int(length)
        return os.pread(self._fd, int(length), int(offset))

    def read_many(self, offsets: Sequence[int], lengths: Sequence[int]) -> List[bytes]:
        """Batched extent reads, coalescing adjacent extents into one I/O.

        Returns payloads in request order.  Extents written in arena byte
        order by one spill sweep are adjacent on disk, so faulting a range
        of once-neighboring blocks costs one ``pread``, not N.
        """
        offs = np.asarray(list(offsets), dtype=np.int64)
        lens = np.asarray(list(lengths), dtype=np.int64)
        n = offs.size
        out: List[Optional[bytes]] = [None] * n
        if not n:
            return []
        order = np.argsort(offs, kind="stable")
        j = 0
        while j < n:
            # grow a contiguous disk range [start, end)
            k = j
            start = int(offs[order[j]])
            end = start + int(lens[order[j]])
            while k + 1 < n and int(offs[order[k + 1]]) == end:
                k += 1
                end += int(lens[order[k]])
            buf = self.read(start, end - start)
            pos = 0
            for m in range(j, k + 1):
                nxt = pos + int(lens[order[m]])
                out[int(order[m])] = buf[pos:nxt]
                pos = nxt
            j = k + 1
        return out  # type: ignore[return-value]

    # -- compaction ------------------------------------------------------
    @property
    def needs_compact(self) -> bool:
        return self._freed > max(1 << 20, self._live)

    def compact(self, offsets: Sequence[int], lengths: Sequence[int]) -> List[int]:
        """Rewrite the live extents densely from byte 0, in place.

        Extents are moved in ascending offset order and packed with NO
        page alignment: the write cursor is then always <= the sum of the
        already-moved extents' lengths, which is <= the current extent's
        old offset — it can never overtake an unread live extent, so the
        move is safe without a second file.  (Aligning here would break
        that invariant and overwrite live data.)  Returns the new offsets
        in request order and truncates the file.
        """
        offs = np.asarray(list(offsets), dtype=np.int64)
        lens = np.asarray(list(lengths), dtype=np.int64)
        order = np.argsort(offs, kind="stable")
        new_offs = [0] * offs.size
        cursor = 0
        for m in order:
            off, ln = int(offs[m]), int(lens[m])
            if cursor != off:
                os.pwrite(self._fd, os.pread(self._fd, ln, off), cursor)
            new_offs[int(m)] = cursor
            cursor += ln
        self._file.truncate(cursor)
        self._tail = cursor
        self._live = int(lens.sum())
        self._freed = 0
        self.compactions += 1
        return new_offs

    # -- accounting ------------------------------------------------------
    @property
    def live_bytes(self) -> int:
        return self._live

    @property
    def file_bytes(self) -> int:
        """Allocated file span (live + dead + alignment padding)."""
        return self._tail

    def close(self) -> None:
        try:
            self._file.close()
        except Exception:
            pass

    def __del__(self):  # pragma: no cover - GC timing dependent
        self.close()


@dataclasses.dataclass
class ResidencyConfig:
    """Policy knobs for the cold tier (DESIGN.md §6)."""

    # Spill down to this fraction of the budget once over it, so every
    # insert batch doesn't trigger a sweep (hysteresis).
    low_water: float = 0.9
    # Physical arenas hold dead/spilled residue until rewrite(); force a
    # compaction once the physical footprint passes budget + slack.
    slack_frac: float = 0.25
    slack_min_bytes: int = 1 << 16
    # Clock sweep chunk: candidates examined per vectorized step.
    sweep_chunk: int = 2048


class ResidencyManager:
    """Budget + clock state + counters for one store's cold tier.

    The owning store keeps the per-block arrays (referenced bits, disk
    offsets, residency flags) because they must grow and be permuted with
    its other per-block metadata; the manager owns the budget arithmetic,
    the clock hand, the spill file, and the observability counters.
    """

    def __init__(
        self,
        budget_bytes: int,
        spill_path: Optional[str] = None,
        config: Optional[ResidencyConfig] = None,
    ):
        if budget_bytes <= 0:
            raise ValueError("memory_budget must be positive")
        self.budget = int(budget_bytes)
        self.config = config or ResidencyConfig()
        self.disk = DiskArena(spill_path)
        self.hand = 0
        self.spills = 0  # blocks spilled
        self.spill_sweeps = 0
        self.faults = 0  # blocks faulted back in
        self.fault_batches = 0
        self.scalar_faults = 0  # read-through scalar block reads

    # -- budget arithmetic ----------------------------------------------
    @property
    def budget_codes(self) -> int:
        """The budget expressed in uint16 code units."""
        return self.budget // 2

    @property
    def target_codes(self) -> int:
        return int(self.config.low_water * self.budget_codes)

    @property
    def slack_bytes(self) -> int:
        return max(
            self.config.slack_min_bytes,
            int(self.config.slack_frac * self.budget),
        )

    # -- the clock/second-chance sweep (shared by every store) -----------
    def sweep(self, n_items, need, candidates, sizes, ref_get, ref_clear):
        """Pick victims worth >= ``need`` size units via two clock passes.

        Items are ids in ``[0, n_items)``; the callbacks are vectorized
        over id arrays: ``candidates(ids) -> bool mask`` (spillable now),
        ``sizes(ids) -> int64 sizes``, ``ref_get(ids) -> bool mask`` and
        ``ref_clear(ids)`` over the caller-owned referenced bits.  A
        referenced candidate gets its bit cleared and one more chance;
        pass two takes it.  Items picked in an earlier chunk are excluded
        when the hand wraps — a victim is chosen at most once per sweep
        (the caller marks them spilled only after the sweep returns).
        Advances :attr:`hand`; returns the victim ids in pick order.
        """
        if n_items <= 0 or need <= 0:
            return np.zeros(0, dtype=np.int64)
        self.spill_sweeps += 1
        chunk = self.config.sweep_chunk
        picked = np.zeros(n_items, dtype=bool)
        victims = []
        freed = 0
        hand = self.hand % n_items
        scanned = 0
        limit = 2 * n_items + chunk  # two full passes: clear refs, take
        while freed < need and scanned < limit:
            ids = np.arange(hand, min(hand + chunk, n_items), dtype=np.int64)
            hand = int(ids[-1] + 1) % n_items
            scanned += ids.size
            cand = candidates(ids) & ~picked[ids]
            refd = cand & ref_get(ids)
            ref_clear(ids[refd])
            pick = cand & ~refd
            if pick.any():
                pids = ids[pick]
                csum = np.cumsum(sizes(pids))
                k = min(int(np.searchsorted(csum, need - freed)) + 1, pids.size)
                picked[pids[:k]] = True
                victims.append(pids[:k])
                freed += int(csum[k - 1])
        self.hand = hand
        if victims:
            return np.concatenate(victims)
        return np.zeros(0, dtype=np.int64)

    def stats(self) -> Dict[str, Any]:
        return {
            "budget_bytes": self.budget,
            "spills": self.spills,
            "spill_sweeps": self.spill_sweeps,
            "faults": self.faults,
            "fault_batches": self.fault_batches,
            "scalar_faults": self.scalar_faults,
            "disk_live_bytes": self.disk.live_bytes,
            "disk_file_bytes": self.disk.file_bytes,
            "disk_reads": self.disk.reads,
            "disk_writes": self.disk.writes,
            "disk_compactions": self.disk.compactions,
        }
