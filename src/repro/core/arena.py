"""Out-of-core cold tier (paper §6.4): a paged on-disk arena + clock policy.

The paper's final claim is that for data sets larger than physical memory,
Blitzcrank "helps the database sustain a high throughput for more
transactions before the I/O overhead dominates".  This module provides the
two pieces the stores need to reproduce that experiment:

* :class:`DiskArena` — an append-only, page-aligned spill file holding the
  compressed code runs of cold blocks.  Extents are byte-addressed
  ``(offset, length)`` pairs owned by the caller; freed extents are
  accounted and reclaimed by an in-place ascending compaction
  (:meth:`compact`), so the file never grows without bound.  Victim runs
  are always written in arena byte order (ascending in-memory offset), so
  blocks that were adjacent in the memory arena stay adjacent on disk and
  a fault over a contiguous range coalesces into one read.

* :class:`ResidencyManager` — the policy half: a memory budget, a
  clock/second-chance hand over per-block referenced bits, and the
  spill/fault counters surfaced through ``stats()``.  The sweep itself is
  driven by the owning store (it owns the per-block arrays); the manager
  decides *how much* to free and records what happened.

The residency lifecycle of a block (DESIGN.md §6)::

    resident --(clock finds ref=0)--> spilled --(get_many miss)--> faulted
       ^                                 |                            |
       +--------- rewrite() keeps tags --+----------- promoted ------+

Hot-path invariant: a fault costs one (coalesced) disk read plus one
vectorized batch decode — never per-row work.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import tempfile
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

# Spill segments are aligned to this many bytes so compaction and
# sequential fault-in behave like page I/O rather than byte soup.
PAGE_BYTES = 4096

# Checksummed extent frame (DESIGN.md §7): every spill payload written via
# the framed API carries a 12-byte header — magic, payload length, CRC32 —
# verified on fault-in.  A mismatch means the extent is quarantined and the
# rows rebuilt from the WAL, never decoded.
FRAME_MAGIC = 0x53504731  # "SPG1"
FRAME_HEADER = struct.Struct("<III")
FRAME_OVERHEAD = FRAME_HEADER.size


def framed_len(payload_len: int) -> int:
    """On-disk length of a framed extent holding ``payload_len`` bytes."""
    return FRAME_OVERHEAD + int(payload_len)


def read_extents(
    path: str, offsets: Sequence[int], payload_lens: Sequence[int]
) -> List[Optional[bytes]]:
    """Verify-and-read framed extents straight from a spill file path.

    Read-only and stateless (no :class:`DiskArena`): checkpoint restore
    uses it to source extent-referenced payloads from a durable spill file
    *before* a fresh arena — possibly at the same path, which would
    truncate it — is opened.  Returns ``None`` for any extent that is
    missing, short, or fails its magic/length/CRC check; the caller maps
    those back to rows for WAL repair.
    """
    offsets = [int(o) for o in offsets]
    lens = [int(ln) for ln in payload_lens]
    try:
        f = open(path, "rb")
    except OSError:
        return [None] * len(offsets)
    out: List[Optional[bytes]] = []
    with f:
        fd = f.fileno()
        for off, ln in zip(offsets, lens):
            fln = framed_len(ln)
            try:
                raw = os.pread(fd, fln, off)
            except OSError:
                out.append(None)
                continue
            if len(raw) != fln:
                out.append(None)
                continue
            magic, n, crc = FRAME_HEADER.unpack_from(raw)
            body = raw[FRAME_OVERHEAD:]
            ok = (magic == FRAME_MAGIC and n == len(body) and zlib.crc32(body) == crc)
            out.append(body if ok else None)
    return out


class ArenaError(RuntimeError):
    """Base class for spill-file I/O failures."""


class ArenaReadError(ArenaError):
    """A ``pread`` returned fewer bytes than the extent length.

    Before this check a truncated spill file silently fed short (garbage)
    payloads back into the decode path — the checksum layer now converts
    this into quarantine + WAL rebuild instead of wrong answers.
    """

    def __init__(self, offset: int, wanted: int, got: int) -> None:
        super().__init__(
            f"short spill read at offset {offset}: wanted {wanted} bytes, " f"got {got}"
        )
        self.offset = int(offset)
        self.wanted = int(wanted)
        self.got = int(got)


class ExtentCorruptionError(ArenaError):
    """One or more framed extents failed their magic/length/CRC check.

    ``indices`` are positions into the ``read_many_checked`` request, so
    the caller can map them back to blocks/rows and quarantine precisely.
    """

    def __init__(self, indices: Sequence[int]) -> None:
        super().__init__(
            f"{len(list(indices))} corrupt spill extent(s): "
            f"{sorted(int(i) for i in indices)[:8]}"
        )
        self.indices = [int(i) for i in indices]


class SpillCorruptionError(ArenaError):
    """Store-level view of extent corruption: the affected row ids.

    Raised by stores *before* any state mutation so a durability layer can
    rebuild the rows from WAL replay and retry the read; without a repair
    handler it propagates (corrupt data is never returned to the caller).
    """

    def __init__(self, row_ids: Sequence[int]) -> None:
        super().__init__(f"spill corruption affecting {len(list(row_ids))} row(s)")
        self.row_ids = sorted(int(i) for i in row_ids)


class _OsIO:
    """Default I/O provider: direct os calls, crash points are no-ops.

    The durability layer substitutes a fault-injecting implementation with
    the same four methods; core code never imports ``repro.durability``.
    """

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        return os.pwrite(fd, data, offset)

    def pread(self, fd: int, length: int, offset: int) -> bytes:
        return os.pread(fd, length, offset)

    def fsync(self, fd: int) -> None:
        os.fsync(fd)

    def point(self, name: str) -> None:
        pass


OS_IO = _OsIO()


class DiskArena:
    """Append-only spill file with free-extent accounting and compaction.

    ``path=None`` (the default) uses an anonymous temp file that the OS
    reclaims when the arena is closed or the process exits — spill data
    never outlives the store that wrote it.  All offsets and lengths are
    in bytes.
    """

    def __init__(
        self,
        path: Optional[str] = None,
        page_bytes: int = PAGE_BYTES,
        io: Optional[Any] = None,
    ):
        if page_bytes <= 0:
            raise ValueError("page_bytes must be positive")
        self.page_bytes = int(page_bytes)
        self.path = path
        self.io = io if io is not None else OS_IO
        if path is None:
            self._file = tempfile.TemporaryFile(prefix="blitz-spill-")
        else:
            self._file = open(path, "w+b")
        self._fd = self._file.fileno()
        self.closed = False
        self._tail = 0  # next unallocated byte (page-aligned per segment)
        self._live = 0  # live payload bytes
        self._freed = 0  # dead payload bytes awaiting compaction
        self.writes = 0
        self.reads = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.compactions = 0

    # -- allocation ------------------------------------------------------
    def write(self, payload: bytes) -> int:
        """Append one segment, returning its byte offset.

        Segments start page-aligned; interior layout (many block runs per
        segment) is the caller's business.
        """
        off = -self._tail % self.page_bytes + self._tail
        n = len(payload)
        self.io.pwrite(self._fd, payload, off)
        self._tail = off + n
        self._live += n
        self.writes += 1
        self.bytes_written += n
        return off

    def write_many(self, payloads: Sequence[bytes]) -> List[int]:
        """Append payloads as CRC32-framed extents in one segment.

        Returns each frame's byte offset (pointing at its header).  The
        segment is written in two halves around the ``spill.mid_write``
        crash point so a simulated kill can land inside the write; the
        callers' metadata only references the new extents after this
        returns, so a torn segment is dead weight, not corruption.
        """
        frames: List[bytes] = []
        for p in payloads:
            frames.append(FRAME_HEADER.pack(FRAME_MAGIC, len(p), zlib.crc32(p)))
            frames.append(p)
        buf = b"".join(frames)
        off = -self._tail % self.page_bytes + self._tail
        half = len(buf) // 2
        self.io.pwrite(self._fd, buf[:half], off)
        self.io.point("spill.mid_write")
        self.io.pwrite(self._fd, buf[half:], off + half)
        self._tail = off + len(buf)
        self._live += len(buf)
        self.writes += 1
        self.bytes_written += len(buf)
        offs, pos = [], off
        for p in payloads:
            offs.append(pos)
            pos += FRAME_OVERHEAD + len(p)
        return offs

    def free(self, offset: int, length: int) -> None:
        """Mark ``length`` bytes at ``offset`` dead (reclaimed at compact)."""
        self._live -= int(length)
        self._freed += int(length)

    # -- reads -----------------------------------------------------------
    def read(self, offset: int, length: int) -> bytes:
        self.reads += 1
        self.bytes_read += int(length)
        buf = self.io.pread(self._fd, int(length), int(offset))
        if len(buf) != int(length):
            raise ArenaReadError(int(offset), int(length), len(buf))
        return buf

    def read_checked(self, offset: int, payload_len: int) -> bytes:
        """Read and verify one framed extent, returning its payload."""
        return self.read_many_checked([offset], [payload_len])[0]

    def read_many_checked(
        self, offsets: Sequence[int], payload_lens: Sequence[int]
    ) -> List[bytes]:
        """Batched framed-extent reads with magic/length/CRC verification.

        ``payload_lens`` are payload byte counts (the frame overhead is
        added here).  Adjacent frames coalesce into one I/O exactly like
        :meth:`read_many`.  Any extent failing verification — short read,
        bad magic, length mismatch, CRC mismatch — raises
        :class:`ExtentCorruptionError` carrying the request indices of
        every bad extent; no partial result is returned.
        """
        framed = [framed_len(ln) for ln in payload_lens]
        try:
            raws: List[Optional[bytes]] = list(self.read_many(offsets, framed))
        except ArenaReadError:
            # A coalesced read hit a hole/truncation: retry per-extent so
            # only the genuinely bad extents are quarantined.
            raws = []
            for off, fln in zip(offsets, framed):
                try:
                    raws.append(self.read(off, fln))
                except ArenaReadError:
                    raws.append(None)
        out: List[bytes] = []
        bad: List[int] = []
        for j, raw in enumerate(raws):
            payload: Optional[bytes] = None
            if raw is not None and len(raw) == framed[j]:
                magic, ln, crc = FRAME_HEADER.unpack_from(raw)
                body = raw[FRAME_OVERHEAD:]
                if (
                    magic == FRAME_MAGIC and ln == len(body) and zlib.crc32(body) == crc
                ):
                    payload = body
            if payload is None:
                bad.append(j)
                payload = b""
            out.append(payload)
        if bad:
            raise ExtentCorruptionError(bad)
        return out

    def read_many(self, offsets: Sequence[int], lengths: Sequence[int]) -> List[bytes]:
        """Batched extent reads, coalescing adjacent extents into one I/O.

        Returns payloads in request order.  Extents written in arena byte
        order by one spill sweep are adjacent on disk, so faulting a range
        of once-neighboring blocks costs one ``pread``, not N.
        """
        offs = np.asarray(list(offsets), dtype=np.int64)
        lens = np.asarray(list(lengths), dtype=np.int64)
        n = offs.size
        out: List[Optional[bytes]] = [None] * n
        if not n:
            return []
        order = np.argsort(offs, kind="stable")
        j = 0
        while j < n:
            # grow a contiguous disk range [start, end)
            k = j
            start = int(offs[order[j]])
            end = start + int(lens[order[j]])
            while k + 1 < n and int(offs[order[k + 1]]) == end:
                k += 1
                end += int(lens[order[k]])
            buf = self.read(start, end - start)
            pos = 0
            for m in range(j, k + 1):
                nxt = pos + int(lens[order[m]])
                out[int(order[m])] = buf[pos:nxt]
                pos = nxt
            j = k + 1
        return out  # type: ignore[return-value]

    # -- compaction ------------------------------------------------------
    @property
    def needs_compact(self) -> bool:
        return self._freed > max(1 << 20, self._live)

    def compact(self, offsets: Sequence[int], lengths: Sequence[int]) -> List[int]:
        """Rewrite the live extents densely from byte 0, in place.

        Extents are moved in ascending offset order and packed with NO
        page alignment: the write cursor is then always <= the sum of the
        already-moved extents' lengths, which is <= the current extent's
        old offset — it can never overtake an unread live extent, so the
        move is safe without a second file.  (Aligning here would break
        that invariant and overwrite live data.)  Returns the new offsets
        in request order and truncates the file.
        """
        offs = np.asarray(list(offsets), dtype=np.int64)
        lens = np.asarray(list(lengths), dtype=np.int64)
        order = np.argsort(offs, kind="stable")
        new_offs = [0] * offs.size
        cursor = 0
        for m in order:
            off, ln = int(offs[m]), int(lens[m])
            if cursor != off:
                self.io.pwrite(self._fd, self.io.pread(self._fd, ln, off), cursor)
            new_offs[int(m)] = cursor
            cursor += ln
        self._file.truncate(cursor)
        self._tail = cursor
        self._live = int(lens.sum())
        self._freed = 0
        self.compactions += 1
        return new_offs

    # -- accounting ------------------------------------------------------
    @property
    def live_bytes(self) -> int:
        return self._live

    @property
    def file_bytes(self) -> int:
        """Allocated file span (live + dead + alignment padding)."""
        return self._tail

    def fsync(self) -> None:
        self.io.fsync(self._fd)

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        try:
            self._file.close()
        except Exception:
            pass

    def unlink(self) -> None:
        """Close and remove a named spill file (no-op for temp arenas)."""
        self.close()
        if self.path is not None:
            try:
                os.unlink(self.path)
            except FileNotFoundError:
                pass

    def __enter__(self) -> "DiskArena":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC timing dependent
        self.close()


@dataclasses.dataclass
class ResidencyConfig:
    """Policy knobs for the cold tier (DESIGN.md §6)."""

    # Spill down to this fraction of the budget once over it, so every
    # insert batch doesn't trigger a sweep (hysteresis).
    low_water: float = 0.9
    # Physical arenas hold dead/spilled residue until rewrite(); force a
    # compaction once the physical footprint passes budget + slack.
    slack_frac: float = 0.25
    slack_min_bytes: int = 1 << 16
    # Clock sweep chunk: candidates examined per vectorized step.
    sweep_chunk: int = 2048


class ResidencyManager:
    """Budget + clock state + counters for one store's cold tier.

    The owning store keeps the per-block arrays (referenced bits, disk
    offsets, residency flags) because they must grow and be permuted with
    its other per-block metadata; the manager owns the budget arithmetic,
    the clock hand, the spill file, and the observability counters.
    """

    def __init__(
        self,
        budget_bytes: int,
        spill_path: Optional[str] = None,
        config: Optional[ResidencyConfig] = None,
        io: Optional[Any] = None,
    ):
        if budget_bytes <= 0:
            raise ValueError("memory_budget must be positive")
        self.budget = int(budget_bytes)
        self.config = config or ResidencyConfig()
        self.disk = DiskArena(spill_path, io=io)
        self.hand = 0
        self.spills = 0  # blocks spilled
        self.spill_sweeps = 0
        self.faults = 0  # blocks faulted back in
        self.fault_batches = 0
        self.scalar_faults = 0  # read-through scalar block reads
        self.quarantined = 0  # extents that failed their CRC check
        self.repaired_rows = 0  # rows rebuilt from WAL after corruption

    def close(self, unlink: bool = False) -> None:
        if unlink:
            self.disk.unlink()
        else:
            self.disk.close()

    # -- budget arithmetic ----------------------------------------------
    @property
    def budget_codes(self) -> int:
        """The budget expressed in uint16 code units."""
        return self.budget // 2

    @property
    def target_codes(self) -> int:
        return int(self.config.low_water * self.budget_codes)

    @property
    def slack_bytes(self) -> int:
        return max(
            self.config.slack_min_bytes,
            int(self.config.slack_frac * self.budget),
        )

    # -- the clock/second-chance sweep (shared by every store) -----------
    def sweep(
        self,
        n_items: int,
        need: int,
        candidates: Callable[[np.ndarray], np.ndarray],
        sizes: Callable[[np.ndarray], np.ndarray],
        ref_get: Callable[[np.ndarray], np.ndarray],
        ref_clear: Callable[[np.ndarray], None],
    ) -> np.ndarray:
        """Pick victims worth >= ``need`` size units via two clock passes.

        Items are ids in ``[0, n_items)``; the callbacks are vectorized
        over id arrays: ``candidates(ids) -> bool mask`` (spillable now),
        ``sizes(ids) -> int64 sizes``, ``ref_get(ids) -> bool mask`` and
        ``ref_clear(ids)`` over the caller-owned referenced bits.  A
        referenced candidate gets its bit cleared and one more chance;
        pass two takes it.  Items picked in an earlier chunk are excluded
        when the hand wraps — a victim is chosen at most once per sweep
        (the caller marks them spilled only after the sweep returns).
        Advances :attr:`hand`; returns the victim ids in pick order.
        """
        if n_items <= 0 or need <= 0:
            return np.zeros(0, dtype=np.int64)
        self.spill_sweeps += 1
        chunk = self.config.sweep_chunk
        picked = np.zeros(n_items, dtype=bool)
        victims = []
        freed = 0
        hand = self.hand % n_items
        scanned = 0
        limit = 2 * n_items + chunk  # two full passes: clear refs, take
        while freed < need and scanned < limit:
            ids = np.arange(hand, min(hand + chunk, n_items), dtype=np.int64)
            hand = int(ids[-1] + 1) % n_items
            scanned += ids.size
            cand = candidates(ids) & ~picked[ids]
            refd = cand & ref_get(ids)
            ref_clear(ids[refd])
            pick = cand & ~refd
            if pick.any():
                pids = ids[pick]
                csum = np.cumsum(sizes(pids))
                k = min(int(np.searchsorted(csum, need - freed)) + 1, pids.size)
                picked[pids[:k]] = True
                victims.append(pids[:k])
                freed += int(csum[k - 1])
        self.hand = hand
        if victims:
            return np.concatenate(victims)
        return np.zeros(0, dtype=np.int64)

    def stats(self) -> Dict[str, Any]:
        return {
            "budget_bytes": self.budget,
            "spills": self.spills,
            "spill_sweeps": self.spill_sweeps,
            "faults": self.faults,
            "fault_batches": self.fault_batches,
            "scalar_faults": self.scalar_faults,
            "quarantined": self.quarantined,
            "repaired_rows": self.repaired_rows,
            "disk_live_bytes": self.disk.live_bytes,
            "disk_file_bytes": self.disk.file_bytes,
            "disk_reads": self.disk.reads,
            "disk_writes": self.disk.writes,
            "disk_compactions": self.disk.compactions,
        }
