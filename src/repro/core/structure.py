"""Structure learning (§2.2, §3): greedy Bayesian-network column ordering.

Determining the optimal ordering is NP-hard; following the paper (and Squish)
we greedily append the column whose best (single-parent) conditional model
minimizes the estimated compressed size given the columns already ordered.
Learning runs on a random sample (default 2**15 rows, §6.2).

Only categorical-like columns (categorical values, or numeric level-1 bucket
ids) participate as parents; a conditional model is kept only when it beats
the marginal by a margin that covers its own storage cost.
"""

from __future__ import annotations

import math
from collections import Counter, defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _entropy(counter: Counter, total: int) -> float:
    h = 0.0
    for c in counter.values():
        p = c / total
        h -= p * math.log2(p)
    return h


def discretize_column(
    values: Sequence, kind: str, max_card: int = 4096
) -> Optional[List]:
    """Map a column to discrete ids for dependency estimation (or None)."""
    if kind in ("cat", "int", "str"):
        ids = list(values)
    elif kind == "float":
        v = np.asarray(values, dtype=np.float64)
        lo, hi = float(v.min()), float(v.max())
        if hi <= lo:
            return None
        ids = np.minimum(((v - lo) / (hi - lo) * 256).astype(np.int64), 255).tolist()
    else:
        return None
    if len(set(ids)) > max_card:
        return None
    return ids


def learn_order(
    columns: Dict[str, List], n_rows: int, model_cost_weight: float = 16.0
) -> Tuple[List[str], Dict[str, Optional[str]]]:
    """Greedy ordering; returns (order, parent-of map).

    ``columns``: name -> discretized ids (same length).  Columns that could
    not be discretized should be omitted; they are appended unconditioned.
    """
    names = list(columns)
    marginal_h = {c: _entropy(Counter(columns[c]), n_rows) for c in names}
    cond_h: Dict[Tuple[str, str], float] = {}

    def get_cond(child: str, parent: str) -> float:
        key = (child, parent)
        if key not in cond_h:
            groups: Dict = defaultdict(Counter)
            for pv, cv in zip(columns[parent], columns[child]):
                groups[pv][cv] += 1
            h = 0.0
            distinct = 0
            for pv, cnt in groups.items():
                tot = sum(cnt.values())
                h += tot / n_rows * _entropy(cnt, tot)
                distinct += len(cnt)
            # charge an approximate model cost (bits per table entry)
            h += model_cost_weight * distinct / n_rows
            cond_h[key] = h
        return cond_h[key]

    order: List[str] = []
    parents: Dict[str, Optional[str]] = {}
    remaining = set(names)
    while remaining:
        best_c, best_bits, best_p = None, None, None
        for c in sorted(remaining):
            bits, parent = marginal_h[c], None
            for p in order:
                hb = get_cond(c, p)
                if hb < bits * 0.95:  # must beat the marginal meaningfully
                    bits, parent = hb, p
            if best_bits is None or bits < best_bits:
                best_c, best_bits, best_p = c, bits, parent
        order.append(best_c)
        parents[best_c] = best_p
        remaining.discard(best_c)
    return order, parents
