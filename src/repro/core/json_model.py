"""JSON node model (Appendix E.1): semantic compression of JSON collections.

Each node of the (learned) JSON schema tree carries:
  * an *existence* model (2-ary categorical) when the node is optional,
  * a *type* model (categorical over the types observed at this path),
  * per-type attribute models (categorical for strings/bools, two-level
    numeric for ints/floats),
  * sub-models for objects (children by key) and arrays (length model +
    element model).

Objects that deviate from the learned schema escape gracefully (unseen keys
are carried through a categorical escape with their JSON text), preserving
the semantic-model property that unseen data stays encodable.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence

from .delayed import BlockDecoder
from .models import BlockEncoder, CategoricalModel, NumericModel

_TYPES = ("null", "bool", "int", "float", "str", "object", "array")


def _type_of(v: Any) -> str:
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "bool"
    if isinstance(v, int):
        return "int"
    if isinstance(v, float):
        return "float"
    if isinstance(v, str):
        return "str"
    if isinstance(v, dict):
        return "object"
    return "array"


class JsonNodeModel:
    """Model for one schema-tree node, built from sample values at the path."""

    def __init__(
        self, values: Sequence[Any], present: int, total: int
    ) -> None:
        self.optional = present < total
        if self.optional:
            self.exist = CategoricalModel(
                [True] * max(present, 1) + [False] * max(total - present, 1)
            )
        types = [_type_of(v) for v in values] or ["null"]
        self.type_model = CategoricalModel(types)
        self.by_type: Dict[str, Any] = {}
        for t in set(types):
            tv = [v for v in values if _type_of(v) == t]
            if t == "bool":
                self.by_type[t] = CategoricalModel([bool(v) for v in tv])
            elif t == "int":
                self.by_type[t] = NumericModel(
                    [int(v) for v in tv], precision=1, integer=True
                )
            elif t == "float":
                self.by_type[t] = NumericModel([float(v) for v in tv], precision=1e-6)
            elif t == "str":
                self.by_type[t] = CategoricalModel([str(v) for v in tv])
            elif t == "object":
                keys: Dict[str, List[Any]] = {}
                for obj in tv:
                    for k2, v2 in obj.items():
                        keys.setdefault(k2, []).append(v2)
                self.by_type[t] = {
                    k2: JsonNodeModel(vals, present=len(vals), total=len(tv))
                    for k2, vals in sorted(keys.items())}
                self._known_keys = CategoricalModel(
                    [k2 for obj in tv for k2 in obj] or [""]
                )
            elif t == "array":
                lens = [len(v) for v in tv]
                self.by_type[t] = (
                    NumericModel(lens or [0], precision=1, integer=True),
                    JsonNodeModel([x for v in tv for x in v], present=1, total=1),
                )

    # ------------------------------------------------------------------
    def encode(self, v: Any, enc: BlockEncoder, present: bool = True) -> None:
        if self.optional:
            self.exist.encode_value(bool(present), enc)
            if not present:
                return
        t = _type_of(v)
        self.type_model.encode_value(t, enc)
        m = self.by_type.get(t)
        if t == "null" or m is None:
            if m is None:  # type unseen at fit: escape via the type model's
                # categorical escape already emitted the tag; carry JSON text
                CategoricalModel([""]).encode_value(json.dumps(v), enc)
            return
        if t in ("bool", "int", "float", "str"):
            m.encode_value(v if t != "bool" else bool(v), enc)
        elif t == "object":
            for k2, child in m.items():
                child.encode(v.get(k2), enc, present=(k2 in v))
            # unseen keys escape as (key, json) pairs, count-prefixed
            extra = [k2 for k2 in v if k2 not in m]
            cnt = NumericModel([0], precision=1, integer=True)
            cnt.encode_value(len(extra), enc)
            for k2 in extra:
                self._known_keys.encode_value(k2, enc)
                CategoricalModel([""]).encode_value(json.dumps(v[k2]), enc)
        else:  # array
            len_m, item_m = m
            len_m.encode_value(len(v), enc)
            for x in v:
                item_m.encode(x, enc)

    def decode(self, dec: BlockDecoder) -> Any:
        if self.optional:
            if not self.exist.decode_value(dec):
                return _MISSING
        t = self.type_model.decode_value(dec)
        m = self.by_type.get(t)
        if t == "null":
            return None
        if m is None:
            return json.loads(CategoricalModel([""]).decode_value(dec))
        if t in ("bool", "int", "float", "str"):
            return m.decode_value(dec)
        if t == "object":
            out = {}
            for k2, child in m.items():
                got = child.decode(dec)
                if got is not _MISSING:
                    out[k2] = got
            cnt = NumericModel([0], precision=1, integer=True)
            for _ in range(cnt.decode_value(dec)):
                k2 = self._known_keys.decode_value(dec)
                out[k2] = json.loads(CategoricalModel([""]).decode_value(dec))
            return out
        len_m, item_m = m
        return [item_m.decode(dec) for _ in range(len_m.decode_value(dec))]


class _Missing:
    __slots__ = ()


_MISSING = _Missing()


class JsonCodec:
    """Collection-level facade: fit on sample objects, encode/decode each."""

    def __init__(self, samples: Sequence[Any]) -> None:
        self.root = JsonNodeModel(
            list(samples), present=len(samples), total=len(samples)
        )

    def encode(self, obj: Any) -> List[int]:
        from . import delayed
        enc = BlockEncoder()
        self.root.encode(obj, enc)
        return delayed.encode_block(enc.slots)

    def decode(self, codes) -> Any:
        dec = BlockDecoder(list(codes))
        return self.root.decode(dec)
