"""Integer-probability coders: the paper's 16-bit interval machinery (§5.1).

A probability is a 16-bit integer ``U`` logically representing ``U / 2**16``
(§5.1).  Every slot of a tuple is coded by one of two primitive coders:

* :class:`DiscreteCoder` — a categorical distribution whose code space
  ``[0, 2**16)`` is laid out by the alias-method decomposition of Theorem 1 /
  Appendix C, giving O(1) ``inv_translate`` (code -> symbol) with no binary
  search.  Because the alias layout scatters a symbol's code options across
  buckets, symbols own *non-continuous* interval unions (§5.6); the coder
  exposes the option-index mapping ``a <-> code`` both ways.
* :class:`UniformCoder` — an exactly-uniform G-ary distribution used for the
  second quantization level of the numeric model (§4.2) and for raw-payload
  escapes.  Both directions are closed-form (no tables).

All arithmetic is exact integer arithmetic; invariants are asserted in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .casts import checked_astype

TOTAL_BITS = 16
TOTAL = 1 << TOTAL_BITS  # 2**16: the fixed code-space size (§5.1)


# ---------------------------------------------------------------------------
# Frequency quantization
# ---------------------------------------------------------------------------

def quantize_freqs(counts: np.ndarray, total: int = TOTAL) -> np.ndarray:
    """Quantize raw counts to integer frequencies summing exactly to ``total``.

    Every symbol with a nonzero count receives frequency >= 1 so that it stays
    encodable (the paper keeps all seen symbols in the model).  Uses the
    largest-remainder method, then repairs the sum by adjusting the largest
    entries (never dropping an entry below 1).
    """
    counts = np.asarray(counts, dtype=np.float64)
    n = counts.size
    if n == 0:
        raise ValueError("empty distribution")
    if n > total:
        raise ValueError(f"more than {total} symbols in one model")
    s = counts.sum()
    if s <= 0:
        counts = np.ones(n, dtype=np.float64)
        s = float(n)
    ideal = counts / s * total
    k = np.floor(ideal).astype(np.int64)
    k = np.maximum(k, 1)
    # Largest-remainder distribution of the leftover mass.
    diff = int(total - k.sum())
    if diff > 0:
        order = np.argsort(-(ideal - k))
        bump, rem = divmod(diff, n)
        k += bump
        k[order[:rem]] += 1
    elif diff < 0:
        # Took too much (due to the >=1 floor): remove from the largest.
        order = np.argsort(-k)
        i = 0
        while diff < 0:
            j = order[i % n]
            take = min(int(k[j]) - 1, -diff)
            if take > 0:
                k[j] -= take
                diff += take
            i += 1
            if i > 4 * n and diff < 0:  # pragma: no cover - defensive
                raise RuntimeError("cannot quantize distribution")
    assert int(k.sum()) == total and (k >= 1).all()
    return k.astype(np.int64)


# ---------------------------------------------------------------------------
# Alias decomposition (Theorem 1 / Appendix C)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AliasTables:
    """Dense alias-layout tables for a categorical distribution.

    Decode-side (Algorithm 6): for code ``c``, bucket ``P = c >> (16 - m)``;
    if the low bits are below ``threshold[P]`` the symbol is ``sym_u[P]`` with
    option index ``a = c - ja[P]``, else ``sym_v[P]`` with ``a = c - jb[P]``.

    Encode-side: CSR arrays mapping (symbol, option index a) -> code.
    ``seg_off[s]:seg_off[s+1]`` are the segment rows of symbol ``s``;
    ``seg_cum`` holds cumulative option counts (per symbol) at segment starts;
    ``seg_start`` the code-space start of each segment.
    """

    m_bits: int                 # bucket index uses the top m bits of the code
    k_of: np.ndarray            # uint32[n]  option count per symbol
    threshold: np.ndarray       # uint32[M]  a_P (size of the u-part)
    sym_u: np.ndarray           # int32[M]
    sym_v: np.ndarray           # int32[M]
    ja: np.ndarray              # int64[M]   a = code - ja[P]   (u branch)
    jb: np.ndarray              # int64[M]   a = code - jb[P]   (v branch)
    seg_off: np.ndarray         # int32[n+1] CSR offsets per symbol
    seg_cum: np.ndarray         # int64[nseg] cumulative option count
    seg_start: np.ndarray       # int64[nseg] code-space start of segment

    @property
    def n_symbols(self) -> int:
        return int(self.k_of.size)

    @property
    def n_buckets(self) -> int:
        return int(self.threshold.size)


def build_alias(k: np.ndarray) -> AliasTables:
    """Decompose integer frequencies (sum=2**16) into M=2**m equal buckets.

    Exactly Appendix C: each bucket of width ``W = 2**(16-m)`` is split between
    at most two symbols.  Returns dense tables for O(1) decode and CSR encode.
    """
    k = np.asarray(k, dtype=np.int64)
    n = k.size
    assert int(k.sum()) == TOTAL, "frequencies must sum to 2**16"
    assert (k >= 1).all()
    m = max(0, int(np.ceil(np.log2(n))))
    M = 1 << m
    W = TOTAL >> m  # bucket width

    rem = k.astype(np.int64).copy()
    small = [i for i in range(n) if rem[i] < W]
    large = [i for i in range(n) if rem[i] >= W]

    threshold = np.zeros(M, dtype=np.int64)
    sym_u = np.zeros(M, dtype=np.int64)
    sym_v = np.zeros(M, dtype=np.int64)

    for p in range(M):
        if small:
            # Invariant: elems_left <= buckets_left, so the average remaining
            # mass is >= W; hence a large element always exists alongside a
            # small one (this is the induction of Theorem 1 / Appendix C).
            s = small.pop()
            a = int(rem[s])
            rem[s] = 0
            lg = large.pop()
            threshold[p], sym_u[p], sym_v[p] = a, s, lg
            rem[lg] -= (W - a)
        else:
            lg = large.pop()
            threshold[p], sym_u[p], sym_v[p] = 0, lg, lg
            rem[lg] -= W
        if rem[lg] < 0:  # pragma: no cover - defensive
            raise RuntimeError("alias decomposition went negative")
        if rem[lg] > 0:
            (small if rem[lg] < W else large).append(int(lg))
    assert not small and not large and (rem == 0).all(), "mass not consumed"

    # ---- assemble per-symbol segments in canonical (bucket, part) order ----
    # part 0 = u-side [P*W, P*W + a_P); part 1 = v-side [P*W + a_P, (P+1)*W)
    segs_by_sym: list[list[Tuple[int, int]]] = [[] for _ in range(n)]
    for p in range(M):
        a = int(threshold[p])
        if a > 0:
            segs_by_sym[int(sym_u[p])].append((p * W, a))
        if W - a > 0:
            segs_by_sym[int(sym_v[p])].append((p * W + a, W - a))

    seg_off = np.zeros(n + 1, dtype=np.int64)
    seg_cum_l, seg_start_l = [], []
    ja = np.zeros(M, dtype=np.int64)
    jb = np.zeros(M, dtype=np.int64)
    cum_of = np.zeros(n, dtype=np.int64)
    # Walk buckets again to fill ja/jb with running per-symbol cumulative
    # counts (Algorithm 6's precomputed constants): a = code - (start - cum).
    for p in range(M):
        a = int(threshold[p])
        u, v = int(sym_u[p]), int(sym_v[p])
        if a > 0:
            ja[p] = p * W - cum_of[u]
            cum_of[u] += a
        else:
            ja[p] = p * W  # unused branch (threshold 0 -> never taken)
        if W - a > 0:
            jb[p] = (p * W + a) - cum_of[v]
            cum_of[v] += W - a
        else:
            jb[p] = p * W + a
    assert (cum_of == k).all()

    for s in range(n):
        seg_off[s + 1] = seg_off[s] + len(segs_by_sym[s])
        c = 0
        for (start, ln) in segs_by_sym[s]:
            seg_cum_l.append(c)
            seg_start_l.append(start)
            c += ln
        assert c == int(k[s])

    return AliasTables(
        m_bits=m,
        k_of=k.astype(np.uint32),
        threshold=threshold.astype(np.uint32),
        sym_u=checked_astype(sym_u, np.int32, where="alias sym_u"),
        sym_v=checked_astype(sym_v, np.int32, where="alias sym_v"),
        ja=ja,
        jb=jb,
        seg_off=checked_astype(seg_off, np.int32, where="alias seg_off"),
        seg_cum=np.asarray(seg_cum_l, dtype=np.int64),
        seg_start=np.asarray(seg_start_l, dtype=np.int64),
    )


# ---------------------------------------------------------------------------
# Primitive coders
# ---------------------------------------------------------------------------

class DiscreteCoder:
    """Categorical coder with O(1) decode via the alias layout (§4.1, §5.6).

    ``inv_translate(code) -> (sym, a, k)`` and ``code_for(sym, a) -> code``
    are exact inverses over the option-index ``a`` in ``[0, k(sym))``.
    """

    __slots__ = ("tables", "_cdf", "_lut_sym", "_lut_a", "_lut_k")

    def __init__(self, quantized: np.ndarray) -> None:
        self.tables = build_alias(quantized)
        self._cdf = None
        self._lut_sym = None
        self._lut_a = None
        self._lut_k = None

    def __getstate__(self) -> "AliasTables":
        # The cdf and 2**16-entry LUT caches are pure functions of the
        # alias tables but dominate a pickled coder ~100x once any decode
        # has built them — drop them and rebuild lazily after unpickling
        # (checkpoint shrink, DESIGN.md §8).
        return self.tables

    def __setstate__(self, tables: "AliasTables") -> None:
        self.tables = tables
        self._cdf = None
        self._lut_sym = None
        self._lut_a = None
        self._lut_k = None

    # -- scalar API (reference path) -------------------------------------
    def k(self, sym: int) -> int:
        return int(self.tables.k_of[sym])

    def inv_translate(self, code: int) -> Tuple[int, int, int]:
        t = self.tables
        shift = TOTAL_BITS - t.m_bits
        p = code >> shift
        low = code & ((1 << shift) - 1)
        if low < int(t.threshold[p]):
            sym = int(t.sym_u[p])
            a = code - int(t.ja[p])
        else:
            sym = int(t.sym_v[p])
            a = code - int(t.jb[p])
        return sym, a, int(t.k_of[sym])

    def code_for(self, sym: int, a: int) -> int:
        t = self.tables
        lo, hi = int(t.seg_off[sym]), int(t.seg_off[sym + 1])
        # Find the segment row containing option ``a``: tiny linear scan
        # (symbols own very few segments; binary search for the pathological).
        if hi - lo <= 8:
            r = lo
            for r2 in range(lo, hi):
                if int(t.seg_cum[r2]) <= a:
                    r = r2
                else:
                    break
        else:
            r = lo + int(np.searchsorted(t.seg_cum[lo:hi], a, side="right")) - 1
        return int(t.seg_start[r]) + (a - int(t.seg_cum[r]))

    # -- vectorized API ---------------------------------------------------
    def inv_translate_batch(
        self, codes: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        t = self.tables
        codes = np.asarray(codes, dtype=np.int64)
        shift = TOTAL_BITS - t.m_bits
        p = codes >> shift
        low = codes & ((1 << shift) - 1)
        hit_u = low < t.threshold[p].astype(np.int64)
        sym = np.where(hit_u, t.sym_u[p], t.sym_v[p]).astype(np.int64)
        a = codes - np.where(hit_u, t.ja[p], t.jb[p])
        return sym, a, t.k_of[sym].astype(np.int64)

    def code_for_batch(self, syms: np.ndarray, a: np.ndarray) -> np.ndarray:
        t = self.tables
        syms = np.asarray(syms, dtype=np.int64)
        a = np.asarray(a, dtype=np.int64)
        out = np.empty(syms.shape, dtype=np.int64)
        # Per-symbol segment search, vectorized over the (few) segment rows.
        lo = t.seg_off[syms].astype(np.int64)
        hi = t.seg_off[syms + 1].astype(np.int64)
        max_rows = int((hi - lo).max()) if syms.size else 0
        row = lo.copy()
        for d in range(1, max_rows):
            cand = lo + d
            ok = (cand < hi) & (t.seg_cum[np.minimum(cand, len(t.seg_cum) - 1)] <= a)
            row = np.where(ok, cand, row)
        out = t.seg_start[row] + (a - t.seg_cum[row])
        return out

    # -- CDF layout (for the arithmetic/rANS baselines which need
    #    contiguous intervals) ------------------------------------------
    @property
    def cdf(self) -> np.ndarray:
        if self._cdf is None:
            self._cdf = np.concatenate(
                [[0], np.cumsum(self.tables.k_of.astype(np.int64))]
            )
        return self._cdf

    # -- direct 2**16 LUT (the "decoding map" variant of Fig 11) ---------
    def build_lut(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._lut_sym is None:
            codes = np.arange(TOTAL, dtype=np.int64)
            sym, a, k = self.inv_translate_batch(codes)
            self._lut_sym = checked_astype(sym, np.int32, where="build_lut sym")
            self._lut_a = a.astype(np.int64)
            self._lut_k = k.astype(np.int64)
        return self._lut_sym, self._lut_a

    def entropy_bits(self) -> float:
        p = self.tables.k_of.astype(np.float64) / TOTAL
        return float(-(p * np.log2(p)).sum())


class UniformCoder:
    """Exactly-uniform G-ary coder; closed-form in both directions.

    Segment ``j`` owns codes ``{c : (c*G) >> 16 == j}``, i.e.
    ``[ceil(j*2^16/G), ceil((j+1)*2^16/G))``.  Used for the second-level
    quantization of the numeric model (§4.2) and raw escape payloads.
    """

    __slots__ = ("G",)

    def __init__(self, G: int) -> None:
        if not (1 <= G <= TOTAL):
            raise ValueError(f"uniform coder arity out of range: {G}")
        self.G = int(G)

    def _lo(self, j: int) -> int:
        return -((-j * TOTAL) // self.G)  # ceil(j*2^16/G)

    def k(self, j: int) -> int:
        return self._lo(j + 1) - self._lo(j)

    def inv_translate(self, code: int) -> Tuple[int, int, int]:
        j = (code * self.G) >> TOTAL_BITS
        lo = self._lo(j)
        return j, code - lo, self._lo(j + 1) - lo

    def code_for(self, j: int, a: int) -> int:
        return self._lo(j) + a

    def inv_translate_batch(
        self, codes: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        codes = np.asarray(codes, dtype=np.int64)
        j = (codes * self.G) >> TOTAL_BITS
        lo = -((-j * TOTAL) // self.G)
        hi = -((-(j + 1) * TOTAL) // self.G)
        return j, codes - lo, hi - lo

    def code_for_batch(self, j: np.ndarray, a: np.ndarray) -> np.ndarray:
        j = np.asarray(j, dtype=np.int64)
        return -((-j * TOTAL) // self.G) + np.asarray(a, dtype=np.int64)

    def entropy_bits(self) -> float:
        return float(np.log2(self.G))
