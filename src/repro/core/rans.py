"""rANS baseline (the paper's §6.3 ANS/FSE comparison point).

Standard range-ANS with 16-bit stream renormalization over the same 16-bit
integer probabilities as delayed coding.  A notable property: rANS only needs
a bijection ``slot <-> (symbol, option a in [0, k))`` over the 2**16 code
space — so Blitzcrank's alias layout (O(1) inverse) plugs in directly, and we
also provide the contiguous-CDF + binary-search variant (the classic
implementation) so benchmarks can separate layout effects from coder effects,
mirroring the solid/dotted lines of Figure 11.

State invariant: x in [2**16, 2**32) between symbols; streamed words are 16
bits.  Encoding walks the block in reverse (LIFO), decoding forward.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .coders import TOTAL, TOTAL_BITS, UniformCoder

_LOW = TOTAL          # 2**16
_MASK = TOTAL - 1


def encode_block(syms: Sequence[int], coders: Sequence) -> List[int]:
    """Encode one block; returns the 16-bit word stream (decode order)."""
    x = _LOW
    words: List[int] = []
    for sym, coder in zip(reversed(syms), list(coders)[::-1]):
        k = coder.k(sym)
        # renormalize so the decoder's lower-bound invariant holds
        while x >= (k << TOTAL_BITS):
            words.append(x & _MASK)
            x >>= TOTAL_BITS
        x = ((x // k) << TOTAL_BITS) | coder.code_for(sym, x % k)
    words.append(x & _MASK)
    words.append((x >> TOTAL_BITS) & _MASK)
    return words[::-1]


def decode_block(words: Sequence[int], coders: Sequence) -> Tuple[List[int], int]:
    """Decode; returns (symbols, words consumed)."""
    x = (words[0] << TOTAL_BITS) | words[1]
    pos = 2
    out: List[int] = []
    for coder in coders:
        slot = x & _MASK
        sym, a, k = coder.inv_translate(slot)  # O(1) via the alias layout
        out.append(sym)
        x = k * (x >> TOTAL_BITS) + a
        while x < _LOW:
            x = (x << TOTAL_BITS) | words[pos]
            pos += 1
    return out, pos


def decode_block_cdf(words: Sequence[int], coders: Sequence) -> Tuple[List[int], int]:
    """Classic rANS decode: binary search in the contiguous CDF (O(log N))."""
    x = (words[0] << TOTAL_BITS) | words[1]
    pos = 2
    out: List[int] = []
    for coder in coders:
        slot = x & _MASK
        if isinstance(coder, UniformCoder):
            sym = (slot * coder.G) >> TOTAL_BITS
            lo = -((-sym * TOTAL) // coder.G)
            hi = -((-(sym + 1) * TOTAL) // coder.G)
            a, k = slot - lo, hi - lo
        else:
            cdf = coder.cdf
            sym = int(np.searchsorted(cdf, slot, side="right")) - 1
            a, k = slot - int(cdf[sym]), int(cdf[sym + 1] - cdf[sym])
        out.append(int(sym))
        x = k * (x >> TOTAL_BITS) + a
        while x < _LOW:
            x = (x << TOTAL_BITS) | words[pos]
            pos += 1
    return out, pos


def encode_block_cdf(syms: Sequence[int], coders: Sequence) -> List[int]:
    """Encoder paired with :func:`decode_block_cdf` (contiguous layout)."""
    x = _LOW
    words: List[int] = []
    for sym, coder in zip(reversed(syms), list(coders)[::-1]):
        if isinstance(coder, UniformCoder):
            lo = -((-sym * TOTAL) // coder.G)
            hi = -((-(sym + 1) * TOTAL) // coder.G)
            L, k = lo, hi - lo
        else:
            cdf = coder.cdf
            L, k = int(cdf[sym]), int(cdf[sym + 1] - cdf[sym])
        while x >= (k << TOTAL_BITS):
            words.append(x & _MASK)
            x >>= TOTAL_BITS
        x = ((x // k) << TOTAL_BITS) | (L + x % k)
    words.append(x & _MASK)
    words.append((x >> TOTAL_BITS) & _MASK)
    return words[::-1]
