"""Canonical Huffman coding — the Raman-style static-dictionary baseline (§6).

Raman & Swart concatenate per-column Huffman codes into variable-length
tuples.  We reproduce the essential behaviour the paper measures against:
variable-length codes (slower, branchier decode), a *static* dictionary (no
unseen-value support without an escape), and near-entropy-per-symbol sizes on
low-entropy columns (where it beats fixed 16-bit delayed codes, Fig. 9).

Codes are canonical (sorted by length then symbol), decoded MSB-first with
the first-code/offset table — O(max_len) per symbol.
"""

from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

MAX_LEN = 32


class BitWriter:
    __slots__ = ("buf", "acc", "nbits")

    def __init__(self) -> None:
        self.buf = bytearray()
        self.acc = 0
        self.nbits = 0

    def write(self, value: int, length: int) -> None:
        self.acc = (self.acc << length) | (value & ((1 << length) - 1))
        self.nbits += length
        while self.nbits >= 8:
            self.nbits -= 8
            self.buf.append((self.acc >> self.nbits) & 0xFF)
        self.acc &= (1 << self.nbits) - 1

    def getvalue(self) -> Tuple[bytes, int]:
        total_bits = len(self.buf) * 8 + self.nbits
        if self.nbits:
            tail = (self.acc << (8 - self.nbits)) & 0xFF
            return bytes(self.buf) + bytes([tail]), total_bits
        return bytes(self.buf), total_bits


class BitReader:
    __slots__ = ("data", "pos")

    def __init__(self, data: bytes, bit_offset: int = 0) -> None:
        self.data = data
        self.pos = bit_offset

    def peek(self, length: int) -> int:
        out = 0
        for i in range(length):
            p = self.pos + i
            bit = (self.data[p >> 3] >> (7 - (p & 7))) & 1 if (p >> 3) < len(self.data) else 0
            out = (out << 1) | bit
        return out

    def skip(self, length: int) -> None:
        self.pos += length


class HuffmanCode:
    """Canonical Huffman code for one column."""

    def __init__(self, counts: np.ndarray) -> None:
        counts = np.asarray(counts, dtype=np.float64)
        n = counts.size
        if n == 1:
            lengths = np.array([1])
        else:
            # package-merge-free: plain Huffman then clamp (clamping is rare)
            heap = [(float(max(c, 1e-9)), i, None) for i, c in enumerate(counts)]
            heapq.heapify(heap)
            forest = {}
            nxt = n
            while len(heap) > 1:
                a = heapq.heappop(heap)
                b = heapq.heappop(heap)
                forest[nxt] = (a[1], b[1])
                heapq.heappush(heap, (a[0] + b[0], nxt, None))
                nxt += 1
            lengths = np.zeros(n, dtype=np.int64)
            stack = [(heap[0][1], 0)]
            while stack:
                node, d = stack.pop()
                if node < n:
                    lengths[node] = max(d, 1)
                else:
                    l, r = forest[node]
                    stack.append((l, d + 1))
                    stack.append((r, d + 1))
            lengths = np.minimum(lengths, MAX_LEN)
            # repair Kraft inequality if clamping broke it
            while (2.0 ** (-lengths.astype(np.float64))).sum() > 1.0:
                lengths[np.argmin(lengths)] += 1
        self.lengths = lengths
        # canonical assignment
        order = np.lexsort((np.arange(n), lengths))
        codes = np.zeros(n, dtype=np.int64)
        code = 0
        prev_len = int(lengths[order[0]])
        for idx in order:
            L = int(lengths[idx])
            code <<= (L - prev_len)
            codes[idx] = code
            code += 1
            prev_len = L
        self.codes = codes
        # decode tables: for each length, first canonical code and base index
        self.order = order
        max_l = int(lengths.max())
        self.first_code = np.full(max_l + 2, 1 << 62, dtype=np.int64)
        self.base_index = np.zeros(max_l + 2, dtype=np.int64)
        pos = 0
        for L in range(1, max_l + 1):
            sel = lengths[order] == L
            cnt = int(sel.sum())
            if cnt:
                self.first_code[L] = int(codes[order[pos]])
                self.base_index[L] = pos
            pos += cnt
        self.max_len = max_l

    def encode(self, sym: int, bw: BitWriter) -> None:
        bw.write(int(self.codes[sym]), int(self.lengths[sym]))

    def decode(self, br: BitReader) -> int:
        window = br.peek(self.max_len)
        for L in range(1, self.max_len + 1):
            prefix = window >> (self.max_len - L)
            fc = int(self.first_code[L])
            if fc <= prefix:
                # count of codes at this length bounds prefix - fc
                idx = int(self.base_index[L]) + (prefix - fc)
                if idx < len(self.order) and int(
                    self.lengths[self.order[idx]]
                ) == L and int(self.codes[self.order[idx]]) == prefix:
                    br.skip(L)
                    return int(self.order[idx])
        raise ValueError("bad Huffman stream")

    def mean_bits(self, probs: np.ndarray) -> float:
        return float((probs * self.lengths).sum())
