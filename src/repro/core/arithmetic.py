"""Integer-probability arithmetic coding (Appendix A baseline).

The paper's baseline coder: 16-bit integer probabilities, interval-product
state updates, and O(log N) binary-search decode (the complexity delayed
coding removes).  Blocks in the OLTP setting are single tuples (a few hundred
bits), so this reference keeps the interval product in exact big-int
arithmetic — functionally identical to App. A's early-bit-emission variant
(which exists to bound the *working precision*, not to change the output
length by more than the final-rounding bit or two).

Encode returns the shortest dyadic fraction inside the final interval
(§2.1); sizes are therefore entropy-optimal per block up to ~2 bits.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .coders import TOTAL, TOTAL_BITS, UniformCoder


def _cdf_bounds(coder, sym: int) -> Tuple[int, int]:
    """Contiguous [L, R) of a symbol (arithmetic coding needs the CDF layout)."""
    if isinstance(coder, UniformCoder):
        lo = -((-sym * TOTAL) // coder.G)
        hi = -((-(sym + 1) * TOTAL) // coder.G)
        return lo, hi
    cdf = coder.cdf
    return int(cdf[sym]), int(cdf[sym + 1])


def encode_block(syms: Sequence[int], coders: Sequence) -> Tuple[bytes, int]:
    """Arithmetic-encode one block; returns (payload bytes, exact bit length)."""
    low = 0      # big-int numerator of the interval low end
    rng = 1      # numerator of the interval width
    den_bits = 0  # denominator = 2**den_bits
    for sym, coder in zip(syms, coders):
        l, r = _cdf_bounds(coder, sym)
        low = (low << TOTAL_BITS) + rng * l
        rng = rng * (r - l)
        den_bits += TOTAL_BITS
    # choose the dyadic fraction with the fewest bits in [low, low+rng)
    hi = low + rng
    nbits = 0
    while nbits <= den_bits:
        # smallest multiple of 2**(den_bits-nbits) that is >= low
        step = 1 << (den_bits - nbits)
        q = -((-low) // step)  # ceil division
        if q * step < hi:
            code = q
            break
        nbits += 1
    else:  # pragma: no cover - rng >= 1 guarantees termination
        raise RuntimeError("no dyadic point found")
    payload = int(code).to_bytes((nbits + 7) // 8 or 1, "big")
    return payload, nbits


def decode_block(payload: bytes, nbits: int, coders: Sequence) -> List[int]:
    """Mirror of :func:`encode_block`; binary-searches the CDF per symbol."""
    code = int.from_bytes(payload, "big") if payload else 0
    den_bits = TOTAL_BITS * len(coders)
    value = code << (den_bits - nbits)  # align to full precision
    low = 0      # full-precision numerator (scale 2**den_bits)
    rng = 1      # width in units of 2**unit_bits
    unit_bits = den_bits
    out: List[int] = []
    for coder in coders:
        unit_bits -= TOTAL_BITS
        unit = 1 << unit_bits
        # 16-bit position of `value` inside the current interval
        target = (value - low) // (rng * unit)
        if isinstance(coder, UniformCoder):
            sym = (target * coder.G) >> TOTAL_BITS
            l, r = _cdf_bounds(coder, sym)
        else:
            cdf = coder.cdf  # O(log N) binary search: the paper's complaint
            sym = int(np.searchsorted(cdf, target, side="right")) - 1
            l, r = int(cdf[sym]), int(cdf[sym + 1])
        out.append(int(sym))
        low += rng * l * unit
        rng = rng * (r - l)
    return out
