"""The Blitzcrank facade (§3): Semantic Learner + Attribute Encoder + Tuple
Encoder wired together for relational rows.

``TableCodec.fit`` is the Semantic Learner: (1) structure-learn a column
ordering + conditional models on a random sample, (2) scan the full data to
fit accurate per-column semantic models.  ``compress_block`` /
``decompress_block`` are the Attribute Encoder (value <-> intervals) feeding
the Tuple Encoder (delayed coding).  ``CompressedTable`` is the in-memory
store with per-block random access (default granularity: 1 tuple, §6.4).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import sanitize, telemetry

if TYPE_CHECKING:
    from .plan import TablePlan

from . import delayed
from .casts import checked_asarray, checked_astype
from .arena import (
    FRAME_OVERHEAD,
    ArenaReadError,
    ExtentCorruptionError,
    ResidencyConfig,
    ResidencyManager,
    SpillCorruptionError,
    framed_len,
    read_extents,
)
from .delayed import BlockDecoder
from .models import (
    BlockEncoder,
    CategoricalModel,
    ConditionalCategoricalModel,
    NumericModel,
    StringModel,
    TimeSeriesModel,
)
from .structure import discretize_column, learn_order

# Telemetry handles (DESIGN.md §9).  Scalar encode/decode and
# spill/fault-in are leaf phases of the wall-time breakdown; plan-cache
# hit/miss and maintenance verbs are counters the gap hunt reads.
_H_ENC_SCALAR = telemetry.histogram("repro.core.encode.scalar_block")
_H_DEC_SCALAR = telemetry.histogram("repro.core.decode.scalar_block")
_H_COMPILE = telemetry.histogram("repro.plan.compile")
_C_PLAN_HIT = telemetry.counter("repro.plan.cache.hit")
_C_PLAN_MISS = telemetry.counter("repro.plan.cache.miss")
_H_SPILL = telemetry.histogram("repro.residency.spill")
_H_FAULT = telemetry.histogram("repro.residency.fault_in")
_C_SPILL_BLOCKS = telemetry.counter("repro.residency.spill.blocks")
_C_FAULT_BLOCKS = telemetry.counter("repro.residency.fault_in.blocks")
_H_REWRITE = telemetry.histogram("repro.store.rewrite")
_C_MIGRATED = telemetry.counter("repro.store.migrate.rows")


@dataclasses.dataclass
class ColumnSpec:
    name: str
    kind: str                    # 'cat' | 'int' | 'float' | 'str' | 'ts'
    precision: float = 1.0       # for 'float' (absolute precision p, §4.2)
    buckets: int = 512           # level-1 bucket budget T
    # Headroom for append-mostly columns (order ids, ytd counters,
    # balances): fraction of the observed value span added to each end of
    # the fitted numeric range, so values that grow past the load-time
    # population keep conforming instead of escaping on every insert.
    # growth > 0 also pins an 'int' column to the numeric (range) model —
    # a growing key must never specialize to a closed categorical vocab.
    growth: float = 0.0


def column_specs(schema: Any) -> List[ColumnSpec]:
    """Normalize a schema argument to a list of :class:`ColumnSpec`.

    Accepts either a plain sequence of specs or a schema object exposing
    ``.columns`` (e.g. :class:`repro.db.TableSchema`), so the codec and
    every :class:`~repro.oltp.store.RowStore` take both interchangeably —
    the `db` engine layer hands its declarative schemas straight down.
    """
    cols = getattr(schema, "columns", schema)
    cols = list(cols)
    for c in cols:
        if not isinstance(c, ColumnSpec):
            raise TypeError(f"expected ColumnSpec, got {type(c).__name__}")
    return cols


@dataclasses.dataclass
class FitStats:
    structuring_s: float = 0.0
    generation_s: float = 0.0
    sample_rows: int = 0
    order: Tuple[str, ...] = ()
    parents: Dict[str, Optional[str]] = dataclasses.field(default_factory=dict)


def fit_column_model(
    spec: ColumnSpec,
    rows: Sequence[Dict[str, Any]],
    parent: Optional[str] = None,
    block_tuples: int = 1,
    extra_values: Optional[Sequence[Any]] = None,
    extra_pairs: Optional[Sequence[Tuple[Any, Any]]] = None,
) -> Any:
    """Fit one column's semantic model (Semantic Learner step 2, per column).

    Shared by :meth:`TableCodec.fit` and the adaptive per-column refitter
    (``repro.adaptive.refit``): both must produce models under identical
    rules or a refit would silently change plan-ability.  ``extra_values``
    augments the training column (each value once) — the refitter passes the
    outgoing model's vocabulary / range endpoints there so every value the
    old model encoded stays conforming under the new one.  For conditional
    columns ``extra_pairs`` additionally preserves the per-parent child
    vocabularies (the encode-side conformance check is per parent group,
    so marginal coverage alone is not enough).
    """
    col = [r[spec.name] for r in rows]
    if extra_values:
        col = col + list(extra_values)
    if spec.growth > 0.0 and spec.kind in ("int", "float", "ts") and col:
        # Synthetic range endpoints widen the fitted range by
        # ``growth * max(span, magnitude)`` on each side: two extra values
        # cost two near-empty buckets, not a distribution shift.  Basing
        # the pad on magnitude too keeps constant columns (a ytd counter
        # loaded at one value) from getting a degenerate zero-width pad.
        lo, hi = float(min(col)), float(max(col))
        unit = spec.precision if spec.kind != "int" else 1.0
        pad = spec.growth * max(hi - lo, abs(hi), abs(lo), unit)
        if spec.kind == "int":
            col = col + [int(lo - pad) - 1, int(hi + pad) + 1]
        else:
            col = col + [lo - pad, hi + pad]
    # growth>0 numeric columns never specialize to a conditional (closed)
    # vocabulary either — same reasoning as the categorical pin below
    if parent is not None and (spec.kind in ("cat", "str")
                               or (spec.kind == "int"
                                   and spec.growth <= 0.0)):
        pairs = [(r[parent], r[spec.name]) for r in rows]
        if extra_pairs:
            pairs = pairs + list(extra_pairs)
        if extra_values:
            # A fresh sentinel parent keeps the extras out of every real
            # conditional group while still feeding the marginal fallback.
            sentinel = object()
            pairs = pairs + [(sentinel, v) for v in extra_values]
        return ConditionalCategoricalModel(pairs, parent)
    if spec.kind == "cat":
        return CategoricalModel(col)
    if spec.kind == "int":
        # small-cardinality ints behave better as categorical — unless the
        # schema declares growth: a growing key needs an open-ended range
        card = len(set(col[:4096]))
        if spec.growth <= 0.0 and card <= 256 and len(set(col)) <= 4096:
            return CategoricalModel(col)
        return NumericModel(col, precision=1, T=spec.buckets, integer=True)
    if spec.kind == "float":
        return NumericModel(col, precision=spec.precision, T=spec.buckets)
    if spec.kind == "ts":
        return TimeSeriesModel(col, precision=spec.precision, T=spec.buckets)
    if spec.kind == "str":
        return StringModel(col, block_tuples=block_tuples)
    raise ValueError(f"unknown column kind {spec.kind}")


class TableCodec:
    """Compresses/decompresses rows (dicts or tuples in schema order)."""

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        models: Dict[str, Any],
        order: List[str],
        stats: FitStats,
        block_tuples: int = 1,
        lam: int = delayed.LAMBDA_DEFAULT,
    ):
        self.schema = column_specs(schema)
        self.by_name = {c.name: c for c in self.schema}
        self.models = models
        self.order = order
        self.stats = stats
        self.block_tuples = block_tuples
        self.lam = lam
        self._plan = None
        self._plan_reason: Optional[str] = None
        self._plan_tried = False

    # ------------------------------------------------------------------
    @classmethod
    def fit(
        cls,
        rows: Sequence[Dict[str, Any]],
        schema: Sequence[ColumnSpec],
        correlation: bool = False,
        sample: int = 1 << 15,
        block_tuples: int = 1,
        seed: int = 0,
        lam: int = delayed.LAMBDA_DEFAULT,
    ) -> "TableCodec":
        schema = column_specs(schema)
        rng = np.random.default_rng(seed)
        n = len(rows)
        stats = FitStats()
        idx = rng.choice(n, size=min(sample, n), replace=False)
        sample_rows = [rows[i] for i in idx]
        stats.sample_rows = len(sample_rows)

        # ---- Semantic Learner step 1: structure learning on the sample ----
        # blitzlint: waive[BL007] -- fit wall time is FitStats data returned to the caller, not a telemetry series
        t0 = time.perf_counter()
        order = [c.name for c in schema]
        parents: Dict[str, Optional[str]] = {c.name: None for c in schema}
        if correlation:
            disc: Dict[str, List] = {}
            for c in schema:
                col = [r[c.name] for r in sample_rows]
                d = discretize_column(col, c.kind)
                if d is not None and c.kind in ("cat", "int", "str"):
                    disc[c.name] = d
            if disc:
                sub_order, sub_parents = learn_order(disc, len(sample_rows))
                rest = [c.name for c in schema if c.name not in disc]
                order = sub_order + rest
                parents.update(sub_parents)
        # blitzlint: waive[BL007] -- fit wall time is FitStats data returned to the caller, not a telemetry series
        stats.structuring_s = time.perf_counter() - t0
        stats.order = tuple(order)
        stats.parents = dict(parents)

        # ---- Semantic Learner step 2: model generation on the full scan ----
        # blitzlint: waive[BL007] -- fit wall time is FitStats data returned to the caller, not a telemetry series
        t0 = time.perf_counter()
        models: Dict[str, Any] = {}
        for c in schema:
            models[c.name] = fit_column_model(
                c, rows, parents.get(c.name), block_tuples
            )
        # blitzlint: waive[BL007] -- fit wall time is FitStats data returned to the caller, not a telemetry series
        stats.generation_s = time.perf_counter() - t0
        return cls(schema, models, order, stats, block_tuples, lam)

    # ------------------------------------------------------------------
    # Compiled fast path (DESIGN.md §2): lower the fitted models to a
    # static slot plan once, then batch-encode/decode through the
    # vectorized codec (and the Pallas kernel for plain-table plans).
    # ------------------------------------------------------------------
    def compile(self, force: bool = False) -> Optional["TablePlan"]:
        """Return the compiled :class:`~repro.core.plan.TablePlan` or None.

        Compilation is attempted once and cached; on fallback the reason is
        recorded in :attr:`plan_fallback_reason`.
        """
        if not self._plan_tried or force:
            self._plan_tried = True
            _C_PLAN_MISS.inc()
            t0 = telemetry.clock()
            from .plan import PlanFallback, compile_plan
            try:
                self._plan = compile_plan(self)
                self._plan_reason = None
            except PlanFallback as e:
                self._plan = None
                self._plan_reason = str(e)
            _H_COMPILE.observe_since(t0)
        else:
            _C_PLAN_HIT.inc()
        return self._plan

    @property
    def plan_fallback_reason(self) -> Optional[str]:
        self.compile()
        return self._plan_reason

    # -- pickling (durability checkpoints, DESIGN.md §7) ----------------
    def __getstate__(self) -> Dict[str, Any]:
        """Drop the compiled plan: it holds prebuilt decode tables that are
        pure functions of the models, so a restored codec recompiles to an
        identical plan (escape counters are snapshotted separately by
        :meth:`CompressedTable.snapshot_state`)."""
        state = dict(self.__dict__)
        state["_plan"] = None
        state["_plan_reason"] = None
        state["_plan_tried"] = False
        return state

    def __setstate__(self, state: Dict[str, Any]) -> None:
        self.__dict__.update(state)

    # ------------------------------------------------------------------
    def _reset_block_state(self) -> None:
        for m in self.models.values():
            if hasattr(m, "reset_block"):
                m.reset_block()

    def _scalar_compress(self, rows: Sequence[Dict[str, Any]]) -> np.ndarray:
        t0 = telemetry.clock()
        self._reset_block_state()
        enc = BlockEncoder()
        # blitzlint: waive[BL001] -- scalar encode chains each model on the previous column value (sequential by design)
        for r in rows:
            ctx: Dict[str, Any] = {}
            for name in self.order:
                self.models[name].encode_value(r[name], enc, ctx)
                ctx[name] = r[name]
        codes = delayed.encode_block(enc.slots, self.lam)
        _H_ENC_SCALAR.observe_since(t0)
        return checked_asarray(codes, np.uint16, where="scalar_compress codes")

    def compress_block(self, rows: Sequence[Dict[str, Any]]) -> np.ndarray:
        """Compress a block of rows into a uint16 code array.

        The compiled plan emits bit-identical codes for conforming
        single-tuple blocks (verified in tests), so the scalar path is used
        here unconditionally — for one row its Python loop beats the fixed
        overhead of a 1-row numpy batch.  Bulk compression goes through
        :meth:`compress_rows`, which amortizes ``encode_batch`` over N rows.
        """
        return self._scalar_compress(rows)

    def compress_rows(
        self, rows: Sequence[Dict[str, Any]]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch-compress rows at single-tuple granularity.

        Returns ``(codes uint16, offsets int64[N+1], fast bool[N])`` — a CSR
        arena where row ``r`` owns ``codes[offsets[r]:offsets[r+1]]``.
        Conforming rows go through one vectorized ``encode_batch`` call;
        the rest are scalar-encoded one block each (identical stream format).
        Requires ``block_tuples == 1``.
        """
        if self.block_tuples != 1:
            raise ValueError("compress_rows requires block_tuples == 1")
        n = len(rows)
        offsets = np.zeros(n + 1, np.int64)
        fast = np.zeros(n, bool)
        if n == 0:
            return np.zeros(0, np.uint16), offsets, fast
        plan = self.compile()
        fcodes = foff = None
        if plan is not None:
            syms, fast = plan.encode_rows(rows)
            if fast.all():
                # All rows conform: the batch CSR is already the arena
                # layout — skip the per-row interleave entirely.
                fcodes, foff = plan.encode_batch(syms)
                codes = checked_astype(
                    fcodes, np.uint16, where="compress_rows codes"
                )
                return codes, np.asarray(foff, np.int64), fast
            if fast.any():
                fcodes, foff = plan.encode_batch(syms[fast])
        chunks: List[np.ndarray] = []
        fi = 0
        pos = 0
        # blitzlint: waive[BL001] -- interleaves vectorized conforming blocks with per-row escape encodes
        for r in range(n):
            if fast[r]:
                c = fcodes[foff[fi]:foff[fi + 1]]
                fi += 1
            else:
                c = self._scalar_compress([rows[r]])
            chunks.append(c)
            pos += len(c)
            offsets[r + 1] = pos
        codes = checked_astype(
            np.concatenate(chunks) if chunks else np.zeros(0, np.uint16),
            np.uint16,
            where="compress_rows codes",
        )
        return codes, offsets, fast

    def decompress_rows(
        self,
        codes: np.ndarray,
        offsets: np.ndarray,
        indices: Sequence[int],
        backend: str = "numpy",
    ) -> List[Dict[str, Any]]:
        """Batch random-access decode from a CSR arena (compiled codecs only).

        Every indexed row must have been encoded on the fast path (its codes
        follow the plan's fixed slot layout).  ``backend`` is ``"numpy"`` or
        ``"pallas"`` (interpret mode on CPU, verified against numpy).
        """
        plan = self.compile()
        if plan is None:
            raise RuntimeError(f"codec did not compile: {self._plan_reason}")
        syms = plan.decode_select(
            checked_asarray(codes, np.uint16, where="decompress_rows codes"),
            np.asarray(offsets, np.int64),
            np.asarray(indices, np.int64),
            backend=backend,
        )
        return plan.decode_syms_to_rows(syms)

    def decompress_block(self, codes: np.ndarray, n_rows: int) -> List[Dict[str, Any]]:
        t0 = telemetry.clock()
        self._reset_block_state()
        dec = BlockDecoder(
            codes.tolist() if isinstance(codes, np.ndarray) else codes, self.lam
        )
        out = []
        for _ in range(n_rows):
            ctx: Dict[str, Any] = {}
            for name in self.order:
                ctx[name] = self.models[name].decode_value(dec, ctx)
            out.append(ctx)
        _H_DEC_SCALAR.observe_since(t0)
        return out

    # ------------------------------------------------------------------
    def model_bytes(self) -> int:
        return sum(m.model_bytes() for m in self.models.values())

    def est_row_bits(self, row: Dict[str, Any]) -> float:
        return sum(self.models[n].est_bits(row[n]) for n in self.order
                   if hasattr(self.models[n], "est_bits"))


def _read_spill_extents(
    path: str, extents: Dict[int, Tuple[int, int]], block2row: np.ndarray
) -> Dict[int, bytes]:
    """Read extent-referenced spill payloads for an extent-mode checkpoint
    (see :meth:`CompressedTable.snapshot_state`).  Must run *before* any
    :class:`ResidencyManager` re-opens (and truncates) the spill path.
    CRC or length mismatches surface as :class:`SpillCorruptionError`
    carrying the affected row ids for WAL-backed repair."""
    blocks = sorted(int(b) for b in extents)
    offs = [extents[b][0] for b in blocks]
    lens = [2 * extents[b][1] for b in blocks]
    payloads = read_extents(path, offs, lens)
    bad = [b for b, p in zip(blocks, payloads) if p is None]
    if bad:
        b2r = np.asarray(block2row, dtype=np.int64)
        raise SpillCorruptionError([int(b2r[b]) for b in bad])
    return {b: p for b, p in zip(blocks, payloads)}


def _raw_row_bytes(row: Dict[str, Any]) -> int:
    """Silo-style uncompressed footprint of one row (for honest accounting)."""
    total = 0
    for v in row.values():
        if isinstance(v, str):
            total += len(v.encode()) + 1
        elif isinstance(v, bytes):
            total += len(v) + 1
        else:
            total += 8
    return total


class CompressedTable:
    """In-memory compressed row store with per-block random access (§6.1).

    Tuples are grouped into blocks of ``codec.block_tuples`` (default 1);
    blocks live in one growing uint16 code arena addressed by a CSR offset
    array ``(codes uint16[], offsets int64[n_blocks+1])`` — the storage
    layout Blitzcrank sits above in Silo, and exactly the layout the batched
    decoder (``vectorized`` / Pallas ``delayed_decode``) consumes.

    When the codec compiled (``codec.compile()``), blocks whose rows conform
    to the slot plan are flagged *fast*; :meth:`get_many` decodes fast rows
    with one ``decode_select`` call (no per-tuple Python loop) and falls back
    to scalar block decode for the rest.  ``use_pallas`` selects the kernel
    backend for large fast batches: ``None`` auto-detects (kernel only on a
    non-CPU jax backend), ``True`` forces it (interpret mode on CPU),
    ``False`` disables it.
    """

    PALLAS_MIN_ROWS = 4096  # auto mode: below this, numpy always wins
    ZONE_CHUNK = 256        # physical blocks per zone-map extent

    def __init__(
        self,
        codec: TableCodec,
        capacity_hint: int = 1 << 16,
        use_pallas: Optional[bool] = None,
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        residency: Optional[ResidencyConfig] = None,
        spill_io: Optional[Any] = None,
    ):
        # Versioned codecs (DESIGN.md §4): writes always encode under the
        # newest codec; every block carries the version it was encoded with
        # so older blocks stay readable after a refit installs a new codec.
        self._codecs: List[TableCodec] = [codec]
        self._plan_ver = np.zeros(1023, dtype=np.uint16)
        self.use_pallas = use_pallas
        self.arena = np.zeros(capacity_hint, dtype=np.uint16)
        self.used = 0
        self.n_blocks = 0
        self._offsets = np.zeros(1024, dtype=np.int64)
        self._fast = np.zeros(1023, dtype=bool)
        self.block_rows: List[int] = []
        self._rows_stored = 0
        self._pending: List[Dict[str, Any]] = []
        # Mutation support (DESIGN.md §3), single-tuple granularity only:
        # logical row id -> physical block, -1 = tombstone.  Replaced and
        # deleted runs stay in the arena as dead bytes until rewrite().
        self._row2block = np.full(1024, -1, dtype=np.int64)
        self._dead_codes = 0
        self._n_deleted = 0
        self.rewrites = 0
        self.migrated_rows = 0
        # Out-of-core cold tier (DESIGN.md §6): when a memory budget is
        # set, cold blocks spill their code runs to a DiskArena and fault
        # back in on access.  The per-block arrays below only exist while
        # a ResidencyManager is installed.
        # Zone maps (DESIGN.md §8): raw-value min/max per *chunk* of
        # ZONE_CHUNK consecutive physical blocks, over the numeric schema
        # columns.  The scan engine prunes chunks whose bounds exclude a
        # range predicate before any decode or disk read.  Bounds are
        # conservative supersets: they only widen between rewrites (a
        # rewrite renumbers blocks and rebuilds them as chunk unions), so
        # pruning is always safe; NaN poisons a chunk (never pruned).
        self._zone_cols: List[str] = [c.name for c in codec.schema
                                      if c.kind in ("int", "float", "ts")]
        self._zcol_idx = {c: j for j, c in enumerate(self._zone_cols)}
        self._zmin = np.full((0, len(self._zone_cols)), np.inf)
        self._zmax = np.full((0, len(self._zone_cols)), -np.inf)
        self._res: Optional[ResidencyManager] = None
        self._resident: Optional[np.ndarray] = None   # bool[cap]
        self._disk_off: Optional[np.ndarray] = None   # int64[cap], bytes
        self._disk_len: Optional[np.ndarray] = None   # int64[cap], codes
        self._ref: Optional[np.ndarray] = None        # uint8[cap], clock bit
        self._block2row: Optional[np.ndarray] = None  # int64[cap], -1=orphan
        self._spilled_codes = 0
        self._in_enforce = False
        if memory_budget is not None:
            self.set_memory_budget(
                memory_budget,
                spill_path=spill_path,
                config=residency,
                spill_io=spill_io,
            )

    # -- codec versions (DESIGN.md §4) -----------------------------------
    @property
    def codec(self) -> TableCodec:
        """The newest installed codec — all writes encode under it."""
        return self._codecs[-1]

    @property
    def current_version(self) -> int:
        return len(self._codecs) - 1

    @property
    def n_versions(self) -> int:
        return len(self._codecs)

    def codec_at(self, version: int) -> TableCodec:
        return self._codecs[version]

    def install_codec(self, codec: TableCodec) -> int:
        """Install a refit codec as the new current version.

        Pending rows are flushed first (they were probed against the old
        plan); existing blocks keep their version tag and remain decodable
        forever — migration to the new plan is opportunistic
        (:meth:`migrate_rows`, merge re-encodes), never stop-the-world.
        """
        if codec.block_tuples != self.codec.block_tuples:
            raise ValueError("install_codec: block_tuples mismatch")
        if codec.order != self.codec.order:
            raise ValueError("install_codec: column order mismatch")
        if len(self._codecs) >= 0xFFFF:  # the uint16 tag must never wrap
            raise ValueError("install_codec: plan version limit reached")
        self.flush()
        self._codecs.append(codec)
        return self.current_version

    @property
    def block_versions(self) -> np.ndarray:
        """Per-block plan-version tag ``uint16[n_blocks]``."""
        return self._plan_ver[:self.n_blocks]

    def version_rows(self) -> Dict[int, int]:
        """Live-row counts keyed by the plan version of their block."""
        live = self._row2block[:self._rows_stored]
        live = live[live >= 0]
        vers, counts = np.unique(self._plan_ver[live], return_counts=True)
        return {int(v): int(c) for v, c in zip(vers, counts)}

    def migrate_rows(self, limit: int = 1 << 12, resident_only: bool = True) -> int:
        """Re-encode up to ``limit`` stale rows under the newest plan.

        Candidates are live rows whose block is tagged with an older version
        AND flagged slow — they escaped their own plan, so the refit that
        superseded it is the first realistic chance to encode them fast
        (plus reclaim their oversized escape runs at the next rewrite).
        Old *fast* blocks are left alone: their codes are already tight and
        every installed version stays decodable.  Under a memory budget,
        ``resident_only`` (the default) keeps maintenance off the cold
        tier: faulting spilled blocks in just to re-encode them would
        evict the workload's hot set — cache thrash for a background
        chore.  Spilled stale blocks migrate when the workload itself
        faults them.  Returns rows migrated.
        """
        self._require_mutable("migrate_rows")
        if limit <= 0 or self.current_version == 0:
            return 0
        self.flush()
        r2b = self._row2block[:self._rows_stored]
        live = r2b >= 0
        blks = r2b[live]
        stale = (self._plan_ver[blks] < self.current_version) & ~self._fast[blks]
        if resident_only and self._res is not None:
            stale &= self._resident[blks]
        rows_idx = np.nonzero(live)[0][stale][:limit]
        if not rows_idx.size:
            return 0
        rows = self.get_many(rows_idx.tolist())
        # Maintenance re-encodes must not feed the drift monitor: these
        # rows already escaped once; recounting them would make migration
        # traffic look like fresh workload drift.
        plan = self.codec.compile()
        ctx = (plan.pause_escape_accounting() if plan is not None
               else contextlib.nullcontext())
        with ctx:
            self.replace_many(rows_idx, rows)
        self.migrated_rows += int(rows_idx.size)
        _C_MIGRATED.add(int(rows_idx.size))
        return int(rows_idx.size)

    # -- out-of-core residency (DESIGN.md §6) ----------------------------
    @property
    def memory_budget(self) -> Optional[int]:
        return self._res.budget if self._res is not None else None

    @property
    def spilled_bytes(self) -> int:
        """Compressed payload bytes currently living on disk (not memory)."""
        return 2 * self._spilled_codes

    def set_memory_budget(
        self,
        budget: int,
        spill_path: Optional[str] = None,
        config: Optional[ResidencyConfig] = None,
        spill_io: Optional[Any] = None,
    ) -> None:
        """Install a residency manager bounding live resident code bytes.

        Single-tuple granularity only (the spill unit is the block and
        fault-in re-points rows at freshly appended blocks, which needs
        the mutation machinery).  Can be enabled at any point in the
        table's life; existing blocks start resident-and-referenced and
        the first enforcement sweeps them against the budget.
        """
        self._require_mutable("set_memory_budget")
        if self._res is not None:
            raise ValueError("memory budget already set")
        self.flush()
        self._res = ResidencyManager(budget, spill_path, config, io=spill_io)
        cap = self._offsets.size - 1
        self._resident = np.ones(cap, dtype=bool)
        self._disk_off = np.full(cap, -1, dtype=np.int64)
        self._disk_len = np.zeros(cap, dtype=np.int64)
        self._ref = np.ones(cap, dtype=np.uint8)
        self._block2row = np.full(cap, -1, dtype=np.int64)
        live = np.nonzero(self._row2block[:self._rows_stored] >= 0)[0]
        self._block2row[self._row2block[live]] = live
        self._spilled_codes = 0
        self._enforce_budget()

    def sanitize_boundary(self, where: str) -> None:
        """``REPRO_SANITIZE=1`` boundary assertions (DESIGN.md §10): CSR
        offset monotonicity, plan-version tag validity, residency
        accounting vs ground truth, and zone-map well-formedness.  A
        no-op (one falsy branch) when the sanitizer is off."""
        if not sanitize.ENABLED:
            return
        nb = self.n_blocks
        sanitize.check_csr_offsets(self._offsets[:nb + 1], self.used, where=where)
        sanitize.check_plan_versions(
            self._plan_ver[:nb], len(self._codecs), where=where
        )
        if self._res is not None:
            res_mask = self._resident[:nb]
            actual = int(self._disk_len[:nb][~res_mask].sum())
            sanitize.check_residency(
                self._spilled_codes,
                actual,
                res_mask,
                self._disk_off[:nb],
                where=where,
            )
        if self._zone_cols:
            sanitize.check_zone_maps(self._zmin, self._zmax, where=where)

    def note_repaired_rows(self, n: int) -> None:
        """Designated entry point for repair drivers (WAL-backed stores) to
        record ``n`` quarantined rows rebuilt from the log.  Foreign writes
        to residency counters are confined to these note_* methods (BL004)."""
        if self._res is not None:
            self._res.repaired_rows += int(n)

    def note_quarantined_rows(self, n: int) -> None:
        """Record ``n`` rows quarantined by a failed checked spill read
        (scan engine / fault-in paths)."""
        if self._res is not None:
            self._res.quarantined += int(n)

    def _init_new_blocks(self, first: int, n: int, rows: Optional[np.ndarray]) -> None:
        """Fresh blocks are resident and referenced (recently written)."""
        if self._res is None:
            return
        self._resident[first:first + n] = True
        self._disk_off[first:first + n] = -1
        self._disk_len[first:first + n] = 0
        self._ref[first:first + n] = 1
        self._block2row[first:first + n] = -1 if rows is None else rows

    def _enforce_budget(self) -> None:
        """Spill cold blocks until live resident codes fit the budget, then
        physically reclaim the arena once residue outgrows the slack."""
        res = self._res
        if res is None or self._in_enforce:
            return
        self._in_enforce = True
        try:
            if self.used - self._dead_codes > res.budget_codes:
                self._spill_until(res.target_codes)
            # Spilled/dead residue stays in the memory arena until a
            # rewrite; force one when physical footprint passes the slack.
            if self._dead_codes and 2 * self.used > res.budget + res.slack_bytes:
                self.rewrite()
            self._maybe_compact_disk()
        finally:
            self._in_enforce = False

    def _spill_until(self, target_codes: int) -> None:
        """Spill cold blocks via the shared clock sweep: victims are live
        resident blocks whose referenced bit is clear (DESIGN.md §6)."""
        res = self._res
        need = (self.used - self._dead_codes) - target_codes

        def candidates(ids: np.ndarray) -> np.ndarray:
            lens = self._offsets[ids + 1] - self._offsets[ids]
            rows = self._block2row[ids]
            cand = self._resident[ids] & (lens > 0) & (rows >= 0)
            if cand.any():
                ok = np.zeros_like(cand)
                ok[cand] = self._row2block[rows[cand]] == ids[cand]
                cand = ok
            return cand

        victims = res.sweep(
            self.n_blocks, need, candidates,
            lambda ids: self._offsets[ids + 1] - self._offsets[ids],
            lambda ids: self._ref[ids] != 0,
            lambda ids: self._ref.__setitem__(ids, 0))
        if victims.size:
            self._spill_blocks(victims)

    def _spill_blocks(self, blocks: np.ndarray) -> None:
        """Write the victims' code runs to disk in arena byte order (one
        coalesced segment write of CRC32-framed extents) and mark them
        non-resident.  Their in-memory runs become dead bytes until the
        next rewrite."""
        t0 = telemetry.clock()
        res = self._res
        order = np.argsort(self._offsets[blocks], kind="stable")
        blocks = blocks[order]
        starts = self._offsets[blocks]
        lens = self._offsets[blocks + 1] - starts
        total = int(lens.sum())
        payloads = [
            self.arena[int(s):int(s) + int(ln)].tobytes() for s, ln in zip(starts, lens)
        ]
        offs = res.disk.write_many(payloads)
        self._disk_off[blocks] = np.asarray(offs, dtype=np.int64)
        self._disk_len[blocks] = lens
        self._resident[blocks] = False
        self._dead_codes += total
        self._spilled_codes += total
        res.spills += int(blocks.size)
        _C_SPILL_BLOCKS.add(int(blocks.size))
        _H_SPILL.observe_since(t0)
        self.sanitize_boundary("spill_blocks")

    def _fault_in(self, blocks: np.ndarray) -> None:
        """Promote spilled blocks: one coalesced disk read, then append the
        runs back into the memory arena as fresh physical blocks carrying
        their fast/version tags, and re-point their rows.  The batched
        decode path then serves them exactly like always-resident blocks —
        a miss costs one read plus one vectorized decode, never per-row
        work."""
        t0 = telemetry.clock()
        res = self._res
        lens = self._disk_len[blocks].copy()
        offs_old = self._disk_off[blocks].copy()
        try:
            payloads = res.disk.read_many_checked(offs_old, 2 * lens)
        except ExtentCorruptionError as e:
            # No state was mutated: surface the affected row ids so a
            # durability layer can rebuild them from the WAL and retry.
            bad = blocks[np.asarray(e.indices, dtype=np.int64)]
            res.quarantined += len(e.indices)
            raise SpillCorruptionError(self._block2row[bad].tolist()) from e
        total = int(lens.sum())
        buf = np.empty(total, dtype=np.uint16)
        pos = 0
        for j in range(blocks.size):
            ln = int(lens[j])
            buf[pos:pos + ln] = np.frombuffer(payloads[j], dtype=np.uint16)
            pos += ln
        n = int(blocks.size)
        base = self.used
        self._append_codes(buf)
        self._grow_index(n)
        first = self.n_blocks
        self._offsets[first + 1:first + 1 + n] = base + np.cumsum(lens)
        self._fast[first:first + n] = self._fast[blocks]
        self._plan_ver[first:first + n] = self._plan_ver[blocks]
        self._zone_union(first, blocks)
        rows = self._block2row[blocks]
        self._init_new_blocks(first, n, rows)
        self.n_blocks += n
        self.block_rows.extend([1] * n)
        self._row2block[rows] = np.arange(first, first + n)
        # the old slots are orphans now; their disk extents are freed
        self._block2row[blocks] = -1
        self._resident[blocks] = True
        self._disk_off[blocks] = -1
        self._disk_len[blocks] = 0
        for o, ln in zip(offs_old.tolist(), lens.tolist()):
            res.disk.free(o, framed_len(2 * ln))
        self._spilled_codes -= total
        res.faults += n
        res.fault_batches += 1
        _C_FAULT_BLOCKS.add(n)
        _H_FAULT.observe_since(t0)
        self.sanitize_boundary("fault_in")

    def _maybe_compact_disk(self) -> None:
        res = self._res
        if res is None or not res.disk.needs_compact:
            return
        spilled = np.nonzero(~self._resident[:self.n_blocks])[0]
        new_offs = res.disk.compact(
            self._disk_off[spilled], 2 * self._disk_len[spilled] + FRAME_OVERHEAD
        )
        self._disk_off[spilled] = np.asarray(new_offs, dtype=np.int64)

    def residency(self) -> Dict[str, Any]:
        """Cold-tier observability: budget, resident/spilled split, faults."""
        if self._res is None:
            return {}
        out = self._res.stats()
        out.update(
            resident_bytes=self.nbytes,
            spilled_bytes=self.spilled_bytes,
            spilled_blocks=int((~self._resident[:self.n_blocks]).sum()),
        )
        return out

    # -- storage helpers -------------------------------------------------
    def _append_codes(self, codes: np.ndarray) -> None:
        need = self.used + codes.size
        if need > self.arena.size:
            new = np.zeros(max(need, 2 * self.arena.size), dtype=np.uint16)
            new[:self.used] = self.arena[:self.used]
            self.arena = new
        self.arena[self.used:need] = codes
        self.used = need

    def _grow_index(self, n_new: int) -> None:
        need = self.n_blocks + n_new + 1
        if need > self._offsets.size:
            cap = max(need, 2 * self._offsets.size)
            off = np.zeros(cap, dtype=np.int64)
            off[:self.n_blocks + 1] = self._offsets[:self.n_blocks + 1]
            self._offsets = off
            fast = np.zeros(cap - 1, dtype=bool)
            fast[:self.n_blocks] = self._fast[:self.n_blocks]
            self._fast = fast
            ver = np.zeros(cap - 1, dtype=np.uint16)
            ver[:self.n_blocks] = self._plan_ver[:self.n_blocks]
            self._plan_ver = ver
            if self._res is not None:
                nb = self.n_blocks
                resident = np.ones(cap - 1, dtype=bool)
                resident[:nb] = self._resident[:nb]
                doff = np.full(cap - 1, -1, dtype=np.int64)
                doff[:nb] = self._disk_off[:nb]
                dlen = np.zeros(cap - 1, dtype=np.int64)
                dlen[:nb] = self._disk_len[:nb]
                ref = np.zeros(cap - 1, dtype=np.uint8)
                ref[:nb] = self._ref[:nb]
                b2r = np.full(cap - 1, -1, dtype=np.int64)
                b2r[:nb] = self._block2row[:nb]
                self._resident, self._disk_off, self._disk_len = resident, doff, dlen
                self._ref, self._block2row = ref, b2r

    def _grow_rows(self, n_new: int) -> None:
        need = self._rows_stored + n_new
        if need > self._row2block.size:
            cap = max(need, 2 * self._row2block.size)
            r2b = np.full(cap, -1, dtype=np.int64)
            r2b[:self._rows_stored] = self._row2block[:self._rows_stored]
            self._row2block = r2b

    # -- zone maps (DESIGN.md §8) ----------------------------------------
    def _zone_chunks(self, n_blocks: int) -> int:
        return -(-int(n_blocks) // self.ZONE_CHUNK)

    def _zone_ensure(self, n_chunks: int) -> None:
        if n_chunks > self._zmin.shape[0]:
            cap = max(n_chunks, 2 * self._zmin.shape[0], 8)
            zc = len(self._zone_cols)
            zmin = np.full((cap, zc), np.inf)
            zmax = np.full((cap, zc), -np.inf)
            zmin[:self._zmin.shape[0]] = self._zmin
            zmax[:self._zmax.shape[0]] = self._zmax
            self._zmin, self._zmax = zmin, zmax

    def _zone_values(self, rows: Sequence[Dict[str, Any]]) -> np.ndarray:
        """``float64[n, Z]`` raw zone-column values; non-numeric or
        non-finite entries become NaN (poisoning their chunk)."""
        n = len(rows)
        vals = np.full((n, len(self._zone_cols)), np.nan)
        for j, c in enumerate(self._zone_cols):
            col = [r.get(c) for r in rows]
            try:
                v = np.asarray(col, dtype=np.float64)
                if v.shape != (n,):
                    raise ValueError("ragged zone column")
            except (TypeError, ValueError):
                v = np.full(n, np.nan)
                for i, x in enumerate(col):
                    try:
                        v[i] = float(x)
                    except (TypeError, ValueError):
                        pass
            vals[:, j] = np.where(np.isfinite(v), v, np.nan)
        return vals

    def _zone_widen(self, blocks: np.ndarray, rows: Sequence[Dict[str, Any]]) -> None:
        """Widen chunk bounds with the raw values of ``rows``, one entry
        per row landing in the matching ``blocks`` id (ids may repeat for
        multi-row blocks).  Raw values bound decoded values for escapes
        exactly and for quantized values within the model's slack, which
        the pruning test re-adds — so the maps are valid for fast AND
        slow blocks."""
        if not self._zone_cols or not len(rows):
            return
        blocks = np.asarray(blocks, dtype=np.int64)
        self._zone_ensure(self._zone_chunks(int(blocks.max()) + 1))
        chunks = blocks // self.ZONE_CHUNK
        vals = self._zone_values(rows)
        np.minimum.at(self._zmin, chunks, vals)
        np.maximum.at(self._zmax, chunks, vals)

    def _zone_union(self, first: int, old_blocks: np.ndarray) -> None:
        """Blocks ``[first, first+n)`` now carry the rows of ``old_blocks``
        (fault-in promotion): union the old chunks' bounds into the new
        chunks — conservative, and tight when the rows dominated their old
        chunk."""
        if not self._zone_cols or not old_blocks.size:
            return
        n = int(old_blocks.size)
        self._zone_ensure(self._zone_chunks(first + n))
        nc = (first + np.arange(n, dtype=np.int64)) // self.ZONE_CHUNK
        oc = np.asarray(old_blocks, np.int64) // self.ZONE_CHUNK
        np.minimum.at(self._zmin, nc, self._zmin[oc])
        np.maximum.at(self._zmax, nc, self._zmax[oc])

    def _zone_rebuild(self, old_blocks: np.ndarray, nb: int) -> None:
        """After a rewrite renumbers blocks (new block ``i`` holds old
        block ``old_blocks[i]``), rebuild chunk bounds as unions of each
        new chunk's contributing old chunks."""
        if not self._zone_cols:
            return
        zc = len(self._zone_cols)
        n_chunks = self._zone_chunks(nb)
        cap = max(n_chunks, 8)
        zmin = np.full((cap, zc), np.inf)
        zmax = np.full((cap, zc), -np.inf)
        if nb:
            nc = np.arange(nb, dtype=np.int64) // self.ZONE_CHUNK
            oc = np.asarray(old_blocks, np.int64) // self.ZONE_CHUNK
            np.minimum.at(zmin, nc, self._zmin[oc])
            np.maximum.at(zmax, nc, self._zmax[oc])
        self._zmin, self._zmax = zmin, zmax

    @property
    def zone_columns(self) -> List[str]:
        """Columns with zone maps (numeric schema kinds)."""
        return list(self._zone_cols)

    def zone_block_mask(
        self,
        column: str,
        lo: Optional[float] = None,
        hi: Optional[float] = None,
        slack: float = 0.0,
    ) -> Optional[np.ndarray]:
        """Keep-mask ``bool[n_blocks]``: False = zone maps prove no row of
        the block can satisfy ``lo <= value <= hi`` (widened by ``slack``,
        the worst-case quantization error of the predicate's decoded
        values).  ``None`` when the column has no zone map; NaN-poisoned
        chunks always keep."""
        j = self._zcol_idx.get(column)
        if j is None:
            return None
        nc = self._zone_chunks(self.n_blocks)
        self._zone_ensure(nc)
        zmin = self._zmin[:nc, j]
        zmax = self._zmax[:nc, j]
        drop = np.zeros(nc, dtype=bool)
        if lo is not None and math.isfinite(lo):
            drop |= zmax < (float(lo) - slack)   # NaN compares False: keep
        if hi is not None and math.isfinite(hi):
            drop |= zmin > (float(hi) + slack)
        blocks = np.arange(self.n_blocks, dtype=np.int64)
        return ~drop[blocks // self.ZONE_CHUNK]

    def _append_block(
        self,
        codes: np.ndarray,
        n_rows: int,
        fast: bool,
        rows: Optional[Sequence[Dict[str, Any]]] = None,
    ) -> None:
        self._append_codes(codes)
        self._grow_index(1)
        self.n_blocks += 1
        self._offsets[self.n_blocks] = self.used
        self._fast[self.n_blocks - 1] = fast
        self._plan_ver[self.n_blocks - 1] = self.current_version
        if rows is not None:
            self._zone_widen(np.full(len(rows), self.n_blocks - 1, np.int64), rows)
        self.block_rows.append(n_rows)
        if self.codec.block_tuples == 1:
            self._grow_rows(n_rows)
            self._row2block[self._rows_stored] = self.n_blocks - 1
            self._init_new_blocks(self.n_blocks - 1, 1, np.asarray([self._rows_stored]))
        self._rows_stored += n_rows
        self.sanitize_boundary("append_block")

    @property
    def block_offsets(self) -> np.ndarray:
        """CSR offsets ``int64[n_blocks + 1]`` into the code arena."""
        return self._offsets[:self.n_blocks + 1]

    @property
    def block_fast(self) -> np.ndarray:
        """Per-block flag: True when the block decodes on the compiled path."""
        return self._fast[:self.n_blocks]

    # -- write path ------------------------------------------------------
    def append(self, row: Dict[str, Any]) -> None:
        self._pending.append(row)
        if len(self._pending) >= self.codec.block_tuples:
            self.flush()

    def extend(self, rows: Sequence[Dict[str, Any]]) -> None:
        """Bulk insert: one vectorized encode for all plan-conforming rows."""
        rows = list(rows)
        if self.codec.block_tuples != 1 or self.codec.compile() is None:
            # blitzlint: waive[BL001] -- extend falls back to per-row append only for non-conforming rows (escape path)
            for r in rows:
                self.append(r)
            return
        self.flush()
        codes, offsets, fast = self.codec.compress_rows(rows)
        base = self.used
        self._append_codes(codes)
        n = len(rows)
        self._grow_index(n)
        self._offsets[self.n_blocks + 1:self.n_blocks + 1 + n] = base + offsets[1:]
        self._fast[self.n_blocks:self.n_blocks + n] = fast
        self._plan_ver[self.n_blocks:self.n_blocks + n] = self.current_version
        self._zone_widen(np.arange(self.n_blocks, self.n_blocks + n), rows)
        self._init_new_blocks(
            self.n_blocks, n, np.arange(self._rows_stored, self._rows_stored + n)
        )
        self._grow_rows(n)
        self._row2block[self._rows_stored:self._rows_stored + n] = np.arange(
            self.n_blocks, self.n_blocks + n
        )
        self.n_blocks += n
        self.block_rows.extend([1] * n)
        self._rows_stored += n
        self._enforce_budget()
        self.sanitize_boundary("extend")

    def flush(self) -> None:
        if not self._pending:
            return
        rows, self._pending = self._pending, []
        # Scalar encode (cheapest for one row; identical codes either way),
        # plus a cheap pure-Python conformance probe for the fast flag.
        plan = self.codec.compile()
        fast = (plan is not None and len(rows) == 1 and plan.row_conforms(rows[0]))
        codes = self.codec._scalar_compress(rows)
        self._append_block(codes, len(rows), fast, rows=rows)
        self._enforce_budget()

    def __len__(self) -> int:
        return self._rows_stored + len(self._pending)

    # -- read path -------------------------------------------------------
    def get(self, i: int) -> Dict[str, Any]:
        """Random access: decompress the block containing row ``i``.

        Raises :class:`KeyError` for tombstoned rows (single-tuple
        granularity; see :meth:`delete_many`).
        """
        i = int(i)
        if self.codec.block_tuples == 1:
            if i < self._rows_stored:
                b = int(self._row2block[i])
                if b < 0:
                    raise KeyError(f"row {i} is deleted")
                return self.get_block(b)[0]
            return dict(self._pending[i - self._rows_stored])
        bt = self.codec.block_tuples
        b = i // bt  # blocks are fixed-size except the trailing pending rows
        if b < self.n_blocks:
            return self.get_block(b)[i % bt]
        return dict(self._pending[i - bt * self.n_blocks])

    def _block_codes(self, b: int) -> np.ndarray:
        """A block's code run — read through to disk for spilled blocks.

        Scalar reads never promote (no row re-pointing): a point lookup of
        one cold block costs one pread, and the batched :meth:`get_many`
        path is the one that faults blocks back to residency.
        """
        if self._res is not None:
            if not self._resident[b]:
                self._res.scalar_faults += 1
                try:
                    raw = self._res.disk.read_checked(
                        int(self._disk_off[b]), 2 * int(self._disk_len[b])
                    )
                except (ExtentCorruptionError, ArenaReadError) as e:
                    self._res.quarantined += 1
                    raise SpillCorruptionError([int(self._block2row[b])]) from e
                return np.frombuffer(raw, dtype=np.uint16)
            self._ref[b] = 1
        return self.arena[self._offsets[b]:self._offsets[b + 1]]

    def get_block(self, b: int) -> List[Dict[str, Any]]:
        codes = self._block_codes(b)
        codec = self._codecs[self._plan_ver[b]]  # decode under the block's
        return codec.decompress_block(codes, self.block_rows[b])  # own plan

    def _resolve_backend(
        self, backend: Optional[str], n_rows: int, codec: Optional[TableCodec] = None
    ) -> str:
        plan = (codec or self.codec).compile()
        if backend in ("numpy", "pallas"):
            # Explicit request; quietly downgrade when the plan has
            # conditional slots the kernel cannot run.
            if backend == "pallas" and (plan is None or not plan.pallas_ok):
                return "numpy"
            return backend
        if plan is None or not plan.pallas_ok or self.use_pallas is False:
            return "numpy"
        if self.use_pallas:
            return "pallas"
        if n_rows >= self.PALLAS_MIN_ROWS:  # auto: only off-CPU is it a win
            try:
                import jax
                if jax.default_backend() != "cpu":
                    return "pallas"
            except Exception:  # pragma: no cover - jax always present here
                pass
        return "numpy"

    def get_many(
        self, indices: Sequence[int], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        """Batched point gets (``None`` for tombstoned rows).

        Rows in plan-conforming single-tuple blocks decode with ONE
        ``decode_select`` call *per plan version present in the batch*
        (a block's fast flag certifies it against the plan it was encoded
        with); the rest fall back to per-block scalar decode (each touched
        block decoded once, under its own version's codec).
        """
        self.sanitize_boundary("get_many")
        idx_arr = np.asarray(list(indices), dtype=np.int64)
        n = idx_arr.size
        out: List[Optional[Dict[str, Any]]] = [None] * n
        bt = self.codec.block_tuples
        scalar_blocks: Dict[int, List[Tuple[int, int]]] = {}
        if bt == 1:
            if not n:
                return out
            # logical row -> physical block; -2 = pending tail, -1 = deleted
            in_store = idx_arr < self._rows_stored
            blks = np.full(n, -2, dtype=np.int64)
            blks[in_store] = self._row2block[idx_arr[in_store]]
            if self._res is not None:
                # grouped fault-in: every spilled block this batch needs is
                # promoted with ONE coalesced read, then decoded below by
                # the same vectorized decode_select as resident blocks
                sb = blks[blks >= 0]
                if sb.size:
                    cold = np.unique(sb[~self._resident[sb]])
                    if cold.size:
                        self._fault_in(cold)
                        blks[in_store] = self._row2block[idx_arr[in_store]]
                    self._ref[blks[blks >= 0]] = 1  # clock: referenced
            fmask = np.zeros(n, dtype=bool)
            stored = blks >= 0
            if stored.any():
                # fast flags are self-certifying: a block is only flagged
                # fast if its version's codec compiled at encode time
                fmask[stored] = self._fast[blks[stored]]
            fast_pos = np.nonzero(fmask)[0]
            if fast_pos.size:
                vers = self._plan_ver[blks[fast_pos]]
                for v in np.unique(vers):
                    sel = fast_pos[vers == v]
                    codec_v = self._codecs[v]
                    rows = codec_v.decompress_rows(
                        self.arena[:self.used],
                        self.block_offsets,
                        blks[sel],
                        backend=self._resolve_backend(backend, sel.size, codec_v),
                    )
                    # blitzlint: waive[BL001] -- scatters scalar-decoded escape rows back into the batched result
                    for j, r in zip(sel.tolist(), rows):
                        out[j] = r
            for j in np.nonzero(~fmask)[0].tolist():
                b = int(blks[j])
                if b == -2:
                    out[j] = dict(self._pending[int(idx_arr[j]) - self._rows_stored])
                elif b >= 0:
                    scalar_blocks.setdefault(b, []).append((j, 0))
                # b == -1: tombstone, leave None
        else:
            for j in range(n):
                i = int(idx_arr[j])
                if i >= self._rows_stored:
                    out[j] = dict(self._pending[i - self._rows_stored])
                else:
                    b = i // bt
                    scalar_blocks.setdefault(b, []).append((j, i - b * bt))
        for b, items in scalar_blocks.items():
            blk = self.get_block(b)
            seen: set = set()
            for j, off in items:
                # duplicate indices get independent dicts, matching get()
                out[j] = blk[off] if off not in seen else dict(blk[off])
                seen.add(off)
        if self._res is not None:
            self._enforce_budget()  # fault-ins may have overrun the budget
        return out

    # -- mutation path (DESIGN.md §3; single-tuple granularity only) -----
    def _require_mutable(self, what: str) -> None:
        if self.codec.block_tuples != 1:
            raise ValueError(
                f"{what} requires block_tuples == 1 (multi-tuple blocks "
                "share code runs across rows)")

    def _retire_blocks(self, blocks: np.ndarray) -> None:
        """Account the code runs of abandoned physical blocks as dead.

        A spilled block's in-memory run was already counted dead when it
        spilled, so retiring it only frees its disk extent."""
        if not blocks.size:
            return
        if self._res is not None:
            self._block2row[blocks] = -1
            sp = ~self._resident[blocks]
            if sp.any():
                cold = blocks[sp]
                for o, ln in zip(
                    self._disk_off[cold].tolist(), self._disk_len[cold].tolist()
                ):
                    self._res.disk.free(o, framed_len(2 * ln))
                self._spilled_codes -= int(self._disk_len[cold].sum())
                self._resident[cold] = True
                self._disk_off[cold] = -1
                self._disk_len[cold] = 0
                blocks = blocks[~sp]
        if blocks.size:
            self._dead_codes += int(
                (self._offsets[blocks + 1] - self._offsets[blocks]).sum()
            )

    def replace_many(
        self, indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> None:
        """Re-encode ``rows`` in place of ``indices`` (delta-merge step).

        New code runs are appended to the arena through the bulk
        ``compress_rows`` path (one ``encode_batch`` call for conforming
        rows); the old runs are tombstoned in place and counted as dead
        bytes until :meth:`rewrite` reclaims them.  ``indices`` must be
        unique; replacing a tombstoned row resurrects it.
        """
        self._require_mutable("replace_many")
        self.flush()
        idx = np.asarray(list(indices), dtype=np.int64)
        n = idx.size
        if n != len(rows):
            raise ValueError("indices and rows length mismatch")
        if not n:
            return
        if idx.min() < 0 or idx.max() >= self._rows_stored:
            raise IndexError("replace_many index out of range")
        if np.unique(idx).size != n:
            # duplicates would double-count dead bytes and orphan runs
            raise ValueError("replace_many indices must be unique")
        codes, offsets, fast = self.codec.compress_rows(list(rows))
        base = self.used
        self._append_codes(codes)
        self._grow_index(n)
        first = self.n_blocks
        self._offsets[first + 1:first + 1 + n] = base + offsets[1:]
        self._fast[first:first + n] = fast
        self._plan_ver[first:first + n] = self.current_version
        self._zone_widen(np.arange(first, first + n), list(rows))
        self._init_new_blocks(first, n, idx)
        self.n_blocks += n
        self.block_rows.extend([1] * n)
        old = self._row2block[idx]
        live = old >= 0
        self._retire_blocks(old[live])
        self._n_deleted -= int(n - np.count_nonzero(live))  # resurrections
        self._row2block[idx] = np.arange(first, first + n)
        self._enforce_budget()

    def delete_many(self, indices: Sequence[int]) -> int:
        """Tombstone rows: their code runs become dead bytes.  Returns the
        number of rows newly deleted (repeat deletes are no-ops)."""
        self._require_mutable("delete_many")
        self.flush()
        idx = np.unique(np.asarray(list(indices), dtype=np.int64))
        if not idx.size:
            return 0
        if idx[0] < 0 or idx[-1] >= self._rows_stored:
            raise IndexError("delete_many index out of range")
        old = self._row2block[idx]
        live = old >= 0
        self._retire_blocks(old[live])
        self._row2block[idx[live]] = -1
        newly = int(np.count_nonzero(live))
        self._n_deleted += newly
        return newly

    def is_live(self, i: int) -> bool:
        """True when logical row ``i`` exists and is not tombstoned."""
        i = int(i)
        if i < 0 or i >= len(self):
            return False
        if self.codec.block_tuples != 1 or i >= self._rows_stored:
            return True
        return self._row2block[i] >= 0

    @property
    def n_live(self) -> int:
        return len(self) - self._n_deleted

    @property
    def dead_bytes(self) -> int:
        """Bytes of abandoned (replaced/deleted) code runs in the arena."""
        return 2 * self._dead_codes

    def rewrite(self) -> int:
        """Compact the arena: copy live runs, drop dead ones, renumber
        physical blocks.  Spilled blocks survive as zero-length resident
        runs carrying their residency tags (disk extent, fast flag, plan
        version) — compaction never forces a fault-in.  Returns the number
        of bytes reclaimed."""
        t0 = telemetry.clock()
        self._require_mutable("rewrite")
        self.flush()
        reclaimed = self.dead_bytes
        nrows = self._rows_stored
        live_rows = np.nonzero(self._row2block[:nrows] >= 0)[0]
        blks = self._row2block[live_rows]
        starts = self._offsets[blks]
        lens = self._offsets[blks + 1] - starts
        res = self._res
        if res is not None:
            res_mask = self._resident[blks]
            lens = np.where(res_mask, lens, 0)  # spilled: no memory run
        total = int(lens.sum())
        new_off = np.zeros(live_rows.size + 1, dtype=np.int64)
        np.cumsum(lens, out=new_off[1:])
        gather = np.repeat(starts - new_off[:-1], lens) + np.arange(total)
        arena = np.zeros(max(total, 1024), dtype=np.uint16)
        arena[:total] = self.arena[gather]
        nb = live_rows.size
        offs = np.zeros(max(nb + 1, 1024), dtype=np.int64)
        offs[:nb + 1] = new_off
        fast = np.zeros(offs.size - 1, dtype=bool)
        fast[:nb] = self._fast[blks]
        ver = np.zeros(offs.size - 1, dtype=np.uint16)
        ver[:nb] = self._plan_ver[blks]  # tags survive compaction
        if res is not None:
            resident = np.ones(offs.size - 1, dtype=bool)
            resident[:nb] = res_mask
            doff = np.full(offs.size - 1, -1, dtype=np.int64)
            doff[:nb] = np.where(res_mask, -1, self._disk_off[blks])
            dlen = np.zeros(offs.size - 1, dtype=np.int64)
            dlen[:nb] = np.where(res_mask, 0, self._disk_len[blks])
            ref = np.zeros(offs.size - 1, dtype=np.uint8)
            ref[:nb] = self._ref[blks]
            b2r = np.full(offs.size - 1, -1, dtype=np.int64)
            b2r[:nb] = live_rows
            self._resident, self._disk_off, self._disk_len = resident, doff, dlen
            self._ref, self._block2row = ref, b2r
            # the clock hand's position is meaningless after renumbering
            res.hand = 0
        self.arena, self.used = arena, total
        self._offsets, self._fast, self.n_blocks = offs, fast, nb
        self._plan_ver = ver
        self._zone_rebuild(blks, nb)
        self.block_rows = [1] * nb
        self._row2block[:nrows] = -1
        self._row2block[live_rows] = np.arange(nb)
        self._dead_codes = 0
        self.rewrites += 1
        _H_REWRITE.observe_since(t0)
        return reclaimed

    # -- durability (DESIGN.md §7) ---------------------------------------
    def close(self, unlink: bool = False) -> None:
        """Release the spill file (if any); the table stays readable for
        resident blocks but must not touch disk afterwards."""
        if self._res is not None:
            self._res.close(unlink=unlink)

    def _snapshot_escapes(self) -> Dict[int, Dict[str, Any]]:
        """Per-version drift counters of every *compiled* plan.

        Plans are stripped from pickled codecs (pure functions of the
        models), but their escape counters are live adaptive state: replay
        must resume from the same window or the next drift check would
        diverge from the pre-crash schedule."""
        out: Dict[int, Dict[str, Any]] = {}
        for v, codec in enumerate(self._codecs):
            plan = codec._plan
            if plan is None:
                continue
            out[v] = {
                "escape_counts": dict(plan.escape_counts),
                "window_escapes": dict(plan.window_escapes),
                "rows_seen": int(plan.rows_seen),
                "window_rows": int(plan.window_rows),
            }
        return out

    def _restore_escapes(self, escapes: Dict[int, Dict[str, Any]]) -> None:
        for v, st in escapes.items():
            plan = self._codecs[int(v)].compile()
            if plan is None:
                continue
            plan.escape_counts.update(st["escape_counts"])
            plan.window_escapes.update(st["window_escapes"])
            plan.rows_seen = int(st["rows_seen"])
            plan.window_rows = int(st["window_rows"])

    def snapshot_state(self, embed_spilled: Optional[bool] = None) -> Dict[str, Any]:
        """Everything needed to rebuild this table bit-identically.

        Spilled payloads are handled one of two ways.  *Embedded* mode
        reads them back (CRC-verified) into the snapshot: self-contained,
        so the spill file never needs to survive a crash.  *Extent* mode
        (the default whenever the spill file is a named durable path)
        records only ``(offset, length)`` references — the spill file's
        own CRC frames already protect the payloads, so re-embedding them
        would double the checkpoint for no extra safety; the file is
        fsynced first so the references are durable.  Corruption found
        here surfaces as :class:`SpillCorruptionError` so the owner can
        repair from the WAL and retry."""
        nb, n = self.n_blocks, self._rows_stored
        st: Dict[str, Any] = {
            "codecs": self._codecs,
            "use_pallas": self.use_pallas,
            "arena": self.arena[:self.used].copy(),
            "offsets": self._offsets[:nb + 1].copy(),
            "fast": self._fast[:nb].copy(),
            "plan_ver": self._plan_ver[:nb].copy(),
            "block_rows": list(self.block_rows),
            "row2block": self._row2block[:n].copy(),
            "rows_stored": n,
            "dead_codes": self._dead_codes,
            "n_deleted": self._n_deleted,
            "rewrites": self.rewrites,
            "migrated_rows": self.migrated_rows,
            "pending": [dict(r) for r in self._pending],
            "escapes": self._snapshot_escapes(),
            "zones": {
                "chunk": self.ZONE_CHUNK,
                "cols": list(self._zone_cols),
                "zmin": self._zmin[:self._zone_chunks(nb)].copy(),
                "zmax": self._zmax[:self._zone_chunks(nb)].copy(),
            },
        }
        if self._res is not None:
            spilled = np.nonzero(~self._resident[:nb])[0]
            res_st: Dict[str, Any] = {
                "budget": self._res.budget,
                "config": self._res.config,
                "resident": self._resident[:nb].copy(),
                "ref": self._ref[:nb].copy(),
                "block2row": self._block2row[:nb].copy(),
                "disk_len": self._disk_len[:nb].copy(),
            }
            embed = (embed_spilled if embed_spilled is not None
                     else self._res.disk.path is None)
            if embed:
                try:
                    payloads = self._res.disk.read_many_checked(
                        self._disk_off[spilled], 2 * self._disk_len[spilled]
                    )
                except ExtentCorruptionError as e:
                    bad = spilled[np.asarray(e.indices, dtype=np.int64)]
                    self._res.quarantined += len(e.indices)
                    raise SpillCorruptionError(self._block2row[bad].tolist()) from e
                res_st["payloads"] = {int(b): p for b, p in zip(spilled, payloads)}
            else:
                self._res.disk.fsync()
                res_st["spill_file"] = self._res.disk.path
                res_st["extents"] = {
                    int(b): (int(self._disk_off[b]), int(self._disk_len[b]))
                    for b in spilled}
            st["residency"] = res_st
        return st

    @classmethod
    def from_state(
        cls,
        state: Dict[str, Any],
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ) -> "CompressedTable":
        """Rebuild a table from :meth:`snapshot_state` output.

        Previously spilled blocks are re-spilled into a fresh spill file,
        so the resident/cold split (and therefore ``nbytes``) matches the
        snapshot exactly."""
        t = cls(state["codecs"][0], use_pallas=state["use_pallas"])
        t._codecs = list(state["codecs"])
        arena = checked_asarray(state["arena"], np.uint16, where="from_state arena")
        t.arena = np.zeros(max(arena.size, 1024), dtype=np.uint16)
        t.arena[:arena.size] = arena
        t.used = int(arena.size)
        nb = len(state["block_rows"])
        cap = max(nb + 1, 1024)
        t._offsets = np.zeros(cap, dtype=np.int64)
        t._offsets[:nb + 1] = state["offsets"]
        t._fast = np.zeros(cap - 1, dtype=bool)
        t._fast[:nb] = state["fast"]
        t._plan_ver = np.zeros(cap - 1, dtype=np.uint16)
        t._plan_ver[:nb] = state["plan_ver"]
        t.n_blocks = nb
        t.block_rows = list(state["block_rows"])
        n = int(state["rows_stored"])
        t._row2block = np.full(max(n, 1024), -1, dtype=np.int64)
        t._row2block[:n] = state["row2block"]
        t._rows_stored = n
        t._dead_codes = int(state["dead_codes"])
        t._n_deleted = int(state["n_deleted"])
        t.rewrites = int(state["rewrites"])
        t.migrated_rows = int(state["migrated_rows"])
        t._pending = [dict(r) for r in state["pending"]]
        res_state = state.get("residency")
        if res_state is not None:
            payload_map = res_state.get("payloads")
            if payload_map is None:
                # Extent-mode checkpoint: payloads live in the (durable)
                # spill file referenced by the snapshot.  Read them out
                # BEFORE constructing the ResidencyManager — opening a
                # named spill path truncates it, and recovery commonly
                # reuses the same path.
                payload_map = _read_spill_extents(
                    res_state["spill_file"], res_state["extents"],
                    res_state["block2row"])
            t._res = ResidencyManager(
                res_state["budget"], spill_path, res_state.get("config"), io=spill_io
            )
            t._resident = np.ones(cap - 1, dtype=bool)
            t._resident[:nb] = res_state["resident"]
            t._disk_off = np.full(cap - 1, -1, dtype=np.int64)
            t._disk_len = np.zeros(cap - 1, dtype=np.int64)
            t._disk_len[:nb] = res_state["disk_len"]
            t._ref = np.zeros(cap - 1, dtype=np.uint8)
            t._ref[:nb] = res_state["ref"]
            t._block2row = np.full(cap - 1, -1, dtype=np.int64)
            t._block2row[:nb] = res_state["block2row"]
            spilled = sorted(payload_map)
            if spilled:
                offs = t._res.disk.write_many([payload_map[b] for b in spilled])
                t._disk_off[np.asarray(spilled, dtype=np.int64)] = np.asarray(
                    offs, dtype=np.int64
                )
            t._spilled_codes = int(t._disk_len[:nb].sum())
        zst = state.get("zones")
        if (zst is not None and zst["chunk"] == t.ZONE_CHUNK
                and zst["cols"] == t._zone_cols):
            t._zone_ensure(max(t._zone_chunks(nb), 8))
            nc = np.asarray(zst["zmin"]).shape[0]
            t._zmin[:nc] = zst["zmin"]
            t._zmax[:nc] = zst["zmax"]
        elif t._zone_cols and nb:
            # Older snapshot (or layout change): poison every chunk so
            # pruning is disabled but never wrong; fresh inserts land in
            # new chunks and prune normally.
            t._zone_ensure(t._zone_chunks(nb))
            t._zmin[:t._zone_chunks(nb)] = np.nan
            t._zmax[:t._zone_chunks(nb)] = np.nan
        t._restore_escapes(state.get("escapes") or {})
        return t

    @property
    def nbytes(self) -> int:
        """Compressed footprint: code arena + block index + unflushed rows.

        Offsets are counted at 4 B each (a uint32 arena index suffices for
        <8 GiB of codes) plus 1 bit per block for the fast flag; pending
        rows sit uncompressed and are charged at their raw size.  At
        single-tuple granularity the row->block indirection (mutation
        support) adds 4 B per logical row.  Once a refit installs a second
        codec the per-block plan-version tag is charged at 1 B per block
        (a single-version table needs no tags).  Dead bytes from replaced
        or deleted runs are *included* — they are held memory until
        :meth:`rewrite` — and reported separately via :attr:`dead_bytes`.

        Under a memory budget this is the *resident* footprint, matching
        how the paper counts the budget: spilled code runs live on disk
        and are excluded (reported via :attr:`spilled_bytes`), while the
        per-block residency metadata (packed disk extent + flags, 9 B per
        block) is charged here.
        """
        pending = sum(_raw_row_bytes(r) for r in self._pending)
        indirection = (4 * self._rows_stored if self.codec.block_tuples == 1 else 0)
        ver_tags = self.n_blocks if len(self._codecs) > 1 else 0
        res_meta = 9 * self.n_blocks if self._res is not None else 0
        zone_bytes = (16 * len(self._zone_cols) * self._zone_chunks(self.n_blocks))
        return (self.used * 2 + 4 * (self.n_blocks + 1)
                + (self.n_blocks + 7) // 8 + indirection + ver_tags
                + res_meta + zone_bytes + pending)
