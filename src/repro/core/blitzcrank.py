"""The Blitzcrank facade (§3): Semantic Learner + Attribute Encoder + Tuple
Encoder wired together for relational rows.

``TableCodec.fit`` is the Semantic Learner: (1) structure-learn a column
ordering + conditional models on a random sample, (2) scan the full data to
fit accurate per-column semantic models.  ``compress_block`` /
``decompress_block`` are the Attribute Encoder (value <-> intervals) feeding
the Tuple Encoder (delayed coding).  ``CompressedTable`` is the in-memory
store with per-block random access (default granularity: 1 tuple, §6.4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import delayed
from .coders import TOTAL_BITS
from .delayed import BlockDecoder
from .models import (BlockEncoder, CategoricalModel, ConditionalCategoricalModel,
                     NumericModel, StringModel, TimeSeriesModel)
from .structure import discretize_column, learn_order


@dataclasses.dataclass
class ColumnSpec:
    name: str
    kind: str                    # 'cat' | 'int' | 'float' | 'str' | 'ts'
    precision: float = 1.0       # for 'float' (absolute precision p, §4.2)
    buckets: int = 512           # level-1 bucket budget T


@dataclasses.dataclass
class FitStats:
    structuring_s: float = 0.0
    generation_s: float = 0.0
    sample_rows: int = 0
    order: Tuple[str, ...] = ()
    parents: Dict[str, Optional[str]] = dataclasses.field(default_factory=dict)


class TableCodec:
    """Compresses/decompresses rows (dicts or tuples in schema order)."""

    def __init__(self, schema: Sequence[ColumnSpec], models: Dict[str, Any],
                 order: List[str], stats: FitStats,
                 block_tuples: int = 1, lam: int = delayed.LAMBDA_DEFAULT):
        self.schema = list(schema)
        self.by_name = {c.name: c for c in self.schema}
        self.models = models
        self.order = order
        self.stats = stats
        self.block_tuples = block_tuples
        self.lam = lam

    # ------------------------------------------------------------------
    @classmethod
    def fit(cls, rows: Sequence[Dict[str, Any]], schema: Sequence[ColumnSpec],
            correlation: bool = False, sample: int = 1 << 15,
            block_tuples: int = 1, seed: int = 0,
            lam: int = delayed.LAMBDA_DEFAULT) -> "TableCodec":
        rng = np.random.default_rng(seed)
        n = len(rows)
        stats = FitStats()
        idx = rng.choice(n, size=min(sample, n), replace=False)
        sample_rows = [rows[i] for i in idx]
        stats.sample_rows = len(sample_rows)

        # ---- Semantic Learner step 1: structure learning on the sample ----
        t0 = time.perf_counter()
        order = [c.name for c in schema]
        parents: Dict[str, Optional[str]] = {c.name: None for c in schema}
        if correlation:
            disc: Dict[str, List] = {}
            for c in schema:
                col = [r[c.name] for r in sample_rows]
                d = discretize_column(col, c.kind)
                if d is not None and c.kind in ("cat", "int", "str"):
                    disc[c.name] = d
            if disc:
                sub_order, sub_parents = learn_order(disc, len(sample_rows))
                rest = [c.name for c in schema if c.name not in disc]
                order = sub_order + rest
                parents.update(sub_parents)
        stats.structuring_s = time.perf_counter() - t0
        stats.order = tuple(order)
        stats.parents = dict(parents)

        # ---- Semantic Learner step 2: model generation on the full scan ----
        t0 = time.perf_counter()
        models: Dict[str, Any] = {}
        for c in schema:
            col = [r[c.name] for r in rows]
            parent = parents.get(c.name)
            if parent is not None and c.kind in ("cat", "int", "str"):
                pairs = [(r[parent], r[c.name]) for r in rows]
                models[c.name] = ConditionalCategoricalModel(pairs, parent)
            elif c.kind == "cat":
                models[c.name] = CategoricalModel(col)
            elif c.kind == "int":
                # small-cardinality ints behave better as categorical
                card = len(set(col[:4096]))
                if card <= 256 and len(set(col)) <= 4096:
                    models[c.name] = CategoricalModel(col)
                else:
                    models[c.name] = NumericModel(col, precision=1,
                                                  T=c.buckets, integer=True)
            elif c.kind == "float":
                models[c.name] = NumericModel(col, precision=c.precision,
                                              T=c.buckets)
            elif c.kind == "ts":
                models[c.name] = TimeSeriesModel(col, precision=c.precision,
                                                 T=c.buckets)
            elif c.kind == "str":
                models[c.name] = StringModel(col, block_tuples=block_tuples)
            else:
                raise ValueError(f"unknown column kind {c.kind}")
        stats.generation_s = time.perf_counter() - t0
        return cls(schema, models, order, stats, block_tuples, lam)

    # ------------------------------------------------------------------
    def _reset_block_state(self) -> None:
        for m in self.models.values():
            if hasattr(m, "reset_block"):
                m.reset_block()

    def compress_block(self, rows: Sequence[Dict[str, Any]]) -> np.ndarray:
        """Compress a block of rows into a uint16 code array."""
        self._reset_block_state()
        enc = BlockEncoder()
        for r in rows:
            ctx: Dict[str, Any] = {}
            for name in self.order:
                self.models[name].encode_value(r[name], enc, ctx)
                ctx[name] = r[name]
        codes = delayed.encode_block(enc.slots, self.lam)
        return np.asarray(codes, dtype=np.uint16)

    def decompress_block(self, codes: np.ndarray, n_rows: int
                         ) -> List[Dict[str, Any]]:
        self._reset_block_state()
        dec = BlockDecoder(codes.tolist() if isinstance(codes, np.ndarray)
                           else codes, self.lam)
        out = []
        for _ in range(n_rows):
            ctx: Dict[str, Any] = {}
            for name in self.order:
                ctx[name] = self.models[name].decode_value(dec, ctx)
            out.append(ctx)
        return out

    # ------------------------------------------------------------------
    def model_bytes(self) -> int:
        return sum(m.model_bytes() for m in self.models.values())

    def est_row_bits(self, row: Dict[str, Any]) -> float:
        return sum(self.models[n].est_bits(row[n]) for n in self.order
                   if hasattr(self.models[n], "est_bits"))


class CompressedTable:
    """In-memory compressed row store with per-block random access (§6.1).

    Tuples are grouped into blocks of ``codec.block_tuples`` (default 1);
    blocks live in one growing uint16 arena addressed by a block offset
    index — the storage layout Blitzcrank sits above in Silo.
    """

    def __init__(self, codec: TableCodec, capacity_hint: int = 1 << 16):
        self.codec = codec
        self.arena = np.zeros(capacity_hint, dtype=np.uint16)
        self.used = 0
        self.block_offsets: List[int] = [0]
        self.block_rows: List[int] = []
        self._pending: List[Dict[str, Any]] = []

    def _append_codes(self, codes: np.ndarray) -> None:
        need = self.used + codes.size
        if need > self.arena.size:
            new = np.zeros(max(need, 2 * self.arena.size), dtype=np.uint16)
            new[:self.used] = self.arena[:self.used]
            self.arena = new
        self.arena[self.used:need] = codes
        self.used = need

    def append(self, row: Dict[str, Any]) -> None:
        self._pending.append(row)
        if len(self._pending) >= self.codec.block_tuples:
            self.flush()

    def flush(self) -> None:
        if not self._pending:
            return
        codes = self.codec.compress_block(self._pending)
        self._append_codes(codes)
        self.block_offsets.append(self.used)
        self.block_rows.append(len(self._pending))
        self._pending = []

    def __len__(self) -> int:
        return sum(self.block_rows) + len(self._pending)

    def get(self, i: int) -> Dict[str, Any]:
        """Random access: decompress the block containing row ``i``."""
        bt = self.codec.block_tuples
        b = i // bt  # blocks are fixed-size except the trailing pending rows
        if b < len(self.block_rows):
            codes = self.arena[self.block_offsets[b]:self.block_offsets[b + 1]]
            return self.codec.decompress_block(codes, self.block_rows[b])[i % bt]
        return self._pending[i - bt * len(self.block_rows)]

    def get_block(self, b: int) -> List[Dict[str, Any]]:
        codes = self.arena[self.block_offsets[b]:self.block_offsets[b + 1]]
        return self.codec.decompress_block(codes, self.block_rows[b])

    @property
    def nbytes(self) -> int:
        return self.used * 2 + 8 * len(self.block_offsets)
