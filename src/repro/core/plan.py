"""Slot-plan compilation: lowering fitted semantic models to static slots.

This is the compile step of the batched fast path (DESIGN.md §2).  A fitted
:class:`~repro.core.blitzcrank.TableCodec` walks value-at-a-time through
Python models; ``compile_plan`` lowers it — when the schema allows — into a
*slot plan*: a fixed sequence of ``S`` slots per tuple, each owned by a
static :class:`DiscreteCoder`/:class:`UniformCoder` (or a
:class:`~repro.core.vectorized.CondSlot` for conditional columns), plus
vectorized value<->symbol translation tables.  The plan feeds
``vectorized.encode_batch``/``decode_batch``/``decode_select`` and, when all
slots are plain tables, the Pallas ``delayed_decode`` kernel.

Plan-ability rules (DESIGN.md §2.3):

* ``block_tuples == 1`` — multi-tuple blocks chain virtual bits across rows,
  which the tuple-parallel layout cannot reproduce;
* every column model lowers: categorical (1 slot), numeric two-level
  (1 + len(l2) slots), conditional categorical with an earlier categorical
  (or conditional) parent chain (1 CondSlot), and format-fixed strings
  (fixed word/delimiter template);
* time-series models are stateful across rows and always fall back.

Plan-ability is *per schema*; conformance is *per row*: a row whose value
escapes (unseen category, out-of-range numeric, off-template string) is
encoded by the scalar path and its block flagged slow.  Fast and slow blocks
share one code-stream format — the plan emits bit-identical codes to the
scalar encoder — so the flag only routes decoding.
"""

from __future__ import annotations

import contextlib
import math

import numpy as np
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro import sanitize, telemetry

from . import vectorized
from .casts import checked_astype
from .coders import TOTAL, DiscreteCoder, UniformCoder
from .models import (
    _DIGIT10,
    CategoricalModel,
    ConditionalCategoricalModel,
    NumericModel,
    StringModel,
    TimeSeriesModel,
    _is_digit_token,
)
from .vectorized import CondSlot

MAX_COND_KEYS = 1 << 16  # cap on enumerated parent-chain combinations

# Hot-path metric handles (DESIGN.md §9): encode/decode are leaf phases
# of the wall-time breakdown, pallas_pack is a jit-compile event.
_H_ENCODE = telemetry.histogram("repro.core.encode")
_H_ENCODE_SCALAR = telemetry.histogram("repro.core.encode.scalar")
_H_DECODE = telemetry.histogram("repro.core.decode")
_C_ENCODE_ROWS = telemetry.counter("repro.core.encode.rows")
_C_DECODE_ROWS = telemetry.counter("repro.core.decode.rows")
_H_PALLAS_PACK = telemetry.histogram("repro.plan.pallas_pack")
_C_PALLAS_PACK = telemetry.counter("repro.plan.pallas_pack.events")


class PlanFallback(Exception):
    """A fitted codec cannot lower to a static slot plan (reason in str)."""


def _hashable(v: Any) -> bool:
    try:
        hash(v)
        return True
    except TypeError:
        return False


def _safe_get(get, v, default: int = -1) -> int:
    """Dictionary id lookup that treats unhashable values as misses, so the
    batch path charges the same rows the scalar `conforms` probe would."""
    try:
        return get(v, default)
    except TypeError:
        return default


def _obj_array(values: Sequence, pad: Any = None) -> np.ndarray:
    out = np.empty(len(values) + 1, dtype=object)
    # blitzlint: waive[BL001] -- boundary conversion of heterogeneous Python values into an object array
    for i, v in enumerate(values):
        out[i] = v
    out[len(values)] = pad  # escape symbol row (never produced by the plan)
    return out


# ---------------------------------------------------------------------------
# Per-column lowerings
# ---------------------------------------------------------------------------

class _CatPlan:
    """CategoricalModel -> 1 DiscreteCoder slot; escape rows non-conforming."""

    def __init__(self, model: CategoricalModel) -> None:
        self.m = model
        self.n_slots = 1
        self._values = _obj_array(model.id2value)

    def coders(self) -> List:
        return [self.m.coder]

    def encode(
        self, vals: Sequence, ctx: Dict[str, Sequence]
    ) -> Tuple[np.ndarray, np.ndarray]:
        get = self.m.value2id.get
        ids = np.fromiter((_safe_get(get, v) for v in vals), np.int64, len(vals))
        return ids[:, None], ids >= 0

    def decode(self, syms: np.ndarray, ctx: Dict[str, Any]) -> np.ndarray:
        if sanitize.ENABLED:
            # Alphabet = id2value rows + the escape-pad row appended by
            # _obj_array; the np.minimum clamp below would silently hide
            # a wider (corrupt) code, so check loudly first.
            sanitize.check_code_range(
                syms[:, 0], len(self._values), where="_CatPlan.decode", slot=0
            )
        return self._values[np.minimum(syms[:, 0], len(self._values) - 1)]

    def conforms(self, v, row) -> bool:
        return v in self.m.value2id


class _NumPlan:
    """NumericModel -> level-1 DiscreteCoder + level-2 UniformCoder digits."""

    def __init__(self, model: NumericModel) -> None:
        self.m = model
        self.n_slots = 1 + len(model.l2)

    def coders(self) -> List:
        return [self.m.l1] + list(self.m.l2)

    def encode(
        self, vals: Sequence, ctx: Dict[str, Sequence]
    ) -> Tuple[np.ndarray, np.ndarray]:
        m = self.m
        n = len(vals)
        syms = np.zeros((n, self.n_slots), np.int64)
        ok = np.ones(n, bool)
        try:
            v = np.asarray(vals, dtype=np.float64)
            if v.shape != (n,):
                raise ValueError("ragged numeric column")
        except (TypeError, ValueError):
            # Mixed-type column: convert per element so only the rows that
            # actually fail are charged (scalar `conforms` semantics).
            v = np.zeros(n, np.float64)
            # blitzlint: waive[BL001] -- mixed-type fallback escapes non-conforming values one at a time
            for r, x in enumerate(vals):
                try:
                    v[r] = float(x)
                except (TypeError, ValueError):
                    ok[r] = False
        ok &= np.isfinite(v)
        q = m._quantize(np.where(ok, v, 0.0))
        ok &= (q >= 0) & (q < m.total_steps)
        q = np.clip(q, 0, m.total_steps - 1)
        syms[:, 0] = q // m.G
        j = q % m.G
        for t, w in enumerate(m.radix):
            d = j // w
            j -= d * w
            syms[:, 1 + t] = d
        return syms, ok

    def decode(self, syms: np.ndarray, ctx: Dict[str, Any]) -> np.ndarray:
        m = self.m
        q = syms[:, 0] * m.G
        for t, w in enumerate(m.radix):
            q = q + syms[:, 1 + t] * w
        if m.integer:
            return np.rint(m.vmin + q * m.p).astype(np.int64)
        return m.vmin + (q + 0.5) * m.p

    def conforms(self, v, row) -> bool:
        m = self.m
        try:
            fv = float(v)
        except (TypeError, ValueError):
            return False
        if not math.isfinite(fv):
            return False
        q = math.floor((fv - m.vmin) / m.p + 1e-9)
        return 0 <= q < m.total_steps


class _CondPlan:
    """ConditionalCategoricalModel -> 1 CondSlot keyed on the parent chain.

    The coder of the slot is selected per tuple.  At encode time selection is
    by the parent's *raw value* (as the scalar model does); inside the batch
    decoder it is by the parent chain's decoded *symbols*, which resolve to
    the same sub-model because each (chain-symbol tuple) names exactly one
    parent value.
    """

    n_slots = 1

    def __init__(
        self,
        model: ConditionalCategoricalModel,
        chain_slots: Tuple[int, ...],
        bases: Tuple[int, ...],
        sub_by_tuple: Dict[Tuple[int, ...], CategoricalModel],
    ):
        self.m = model
        self.chain_slots = chain_slots
        self.bases = bases
        self.sub_by_tuple = sub_by_tuple
        packed_coders = {}
        for key_t, sm in sub_by_tuple.items():
            packed_coders[_pack_key(key_t, bases)] = sm.coder
        self.slot = CondSlot(chain_slots, bases, packed_coders, model.marginal.coder)

    def coders(self) -> List:
        return [self.slot]

    def encode(
        self, vals: Sequence, ctx: Dict[str, Sequence]
    ) -> Tuple[np.ndarray, np.ndarray]:
        m = self.m
        pvals = ctx[m.parent]
        ids = np.empty(len(vals), np.int64)
        # blitzlint: waive[BL001] -- conditional-slot encode keys each codebook on the row's parent value
        for r, (pv, v) in enumerate(zip(pvals, vals)):
            sub = m.cond.get(pv, m.marginal) if _hashable(pv) else m.marginal
            ids[r] = _safe_get(sub.value2id.get, v)
        return ids[:, None], ids >= 0

    def decode(self, syms: np.ndarray, ctx: Dict[str, Any]) -> np.ndarray:
        m = self.m
        pvals = ctx[m.parent]
        out = np.empty(syms.shape[0], dtype=object)
        # blitzlint: waive[BL001] -- conditional-slot decode selects a per-row codebook from the parent symbol
        for r in range(syms.shape[0]):
            sub = m.cond.get(pvals[r], m.marginal)
            s = int(syms[r, 0])
            out[r] = sub.id2value[s] if s < len(sub.id2value) else None
        return out

    def conforms(self, v, row) -> bool:
        pv = row[self.m.parent]
        sub = (
            self.m.cond.get(pv, self.m.marginal) if _hashable(pv) else self.m.marginal
        )
        return v in sub.value2id


_DIGIT_CHARS = np.array(list("0123456789"), dtype=object)


class _StrPlan:
    """StringModel -> fixed word/delimiter template slots.

    Requires ``block_tuples == 1`` (enforced at plan level): the per-block
    prefix queue is then always empty at encode time, so the match slot is
    the constant "no prefix" symbol and no prefix-length slots are emitted.
    The template fixes ``W`` = the modal word count of the training column;
    each word position is lowered in its *modal kind*: a dictionary word
    (one dict-coder slot) or an all-digit token of up to ``cap`` digits
    (constant ``esc_digits`` + length slots, then ``cap`` uniform digit
    slots — the scalar encoder's cap-padded digit path, flattened, so
    street numbers and sku/phone runs of varying width share one layout).
    Rows with a different segment count, a kind mismatch or over-cap digit
    run at any position, dictionary-miss words, or escape delimiters are
    non-conforming.
    """

    def __init__(self, model: StringModel) -> None:
        m = model
        counts = getattr(m, "n_words_counts", None)
        if not counts:
            raise PlanFallback("string model has no template statistics")
        self.m = m
        self.W = int(counts.most_common(1)[0][0])
        if self.W < 1:
            raise PlanFallback("string template has no words")
        n_m = m.n_model
        q = int(n_m._quantize(self.W))
        if not (0 <= q < n_m.total_steps):
            raise PlanFallback("string template word count not encodable")
        n_syms = [q // n_m.G]
        j = q % n_m.G
        for w in n_m.radix:
            d = j // w
            j -= d * w
            n_syms.append(d)
        self._n_syms = np.asarray(n_syms, np.int64)
        self._nn = len(n_syms)
        # Per word-position mode: None = dictionary word (1 slot), cap >= 1
        # = all-digit token of up to ``cap`` digits (2 constant slots + cap
        # digit slots; the scalar coder pads every digit token to the same
        # cap, so conforming streams stay bit-identical).  ``_digit_modal``
        # keeps the most common length for the fixed-shape pre-pass.
        per_pos = getattr(m, "pos_kinds", {}).get(self.W)
        self._esc_digits = getattr(m.dict_model, "esc_digits", None)
        self._modes: List[Optional[int]] = []
        self._digit_modal: List[Optional[int]] = []
        for t in range(self.W):
            mode: Optional[int] = None
            modal: Optional[int] = None
            if per_pos is not None and t < len(per_pos) and per_pos[t]:
                kind = int(per_pos[t].most_common(1)[0][0])
                if kind >= 1 and self._esc_digits is not None:
                    mode = int(m.digit_cap(self.W, t))
                    modal = kind
            self._modes.append(mode)
            self._digit_modal.append(modal)
        # Slot offsets (relative to the first template slot) of each word
        # position and of the delimiter that follows it.
        self._word_off: List[int] = []
        self._delim_off: List[int] = []
        off = 0
        for t, mode in enumerate(self._modes):
            self._word_off.append(off)
            off += 1 if mode is None else 2 + mode
            if t < self.W - 1:
                self._delim_off.append(off)
                off += 1
        self.n_slots = 1 + self._nn + off
        self._words = _obj_array(
            [wb.decode("utf-8", errors="replace") for wb in m.dict_model.id2value],
            pad="",
        )
        self._delims = _obj_array(list(m.delim_model.id2value), pad="")
        self._fixed = self._build_fixed_spec()

    def _build_fixed_spec(self) -> Optional[Dict[str, Any]]:
        """Character-matrix spec for fully fixed-shape templates.

        When every word position is a fixed-length digit run or a
        near-constant dictionary word, conforming strings all share one
        exact character layout, so a whole batch lowers through vectorized
        char-code compares with no per-row Python.  Rows failing the check
        fall back to the exact row-wise encoder, keeping the fast mask
        identical to :meth:`conforms`.
        """
        m = self.m
        per_words = getattr(m, "pos_words", {}).get(self.W)
        base = 1 + self._nn
        spec: List[Tuple[str, int, int, int, Any]] = []
        coff = 0
        for t, mode in enumerate(self._modes):
            if mode is not None:
                # Fixed layout needs one exact char width: use the modal
                # digit length; other lengths re-check through the exact
                # row-wise encoder.
                modal = self._digit_modal[t]
                if modal is None or modal > mode:
                    return None
                spec.append(
                    ("digit", coff, modal, base + self._word_off[t], mode)
                )
                coff += modal
            else:
                if per_words is None or t >= len(per_words) or not per_words[t]:
                    return None
                pw = per_words[t]
                if None in pw:
                    return None
                w, c = pw.most_common(1)[0]
                if c < 0.95 * sum(pw.values()):
                    return None
                wid = m.dict_model.value2id.get(w.encode("utf-8"))
                if wid is None:
                    return None
                codes = np.array([ord(ch) for ch in w], np.uint32)
                spec.append(
                    ("word", coff, len(w), base + self._word_off[t], (codes, wid))
                )
                coff += len(w)
            if t < self.W - 1:
                spec.append(("delim", coff, 1, base + self._delim_off[t], None))
                coff += 1
        lut = np.full(128, -1, np.int64)
        for d, did in m.delim_model.value2id.items():
            if isinstance(d, str) and len(d) == 1 and ord(d) < 128:
                lut[ord(d)] = did
        return {"t_len": coff, "spec": spec, "lut": lut}

    def coders(self) -> List:
        m = self.m
        out = [m.i_model, m.n_model.l1, *m.n_model.l2]
        for t, mode in enumerate(self._modes):
            out.append(m.dict_model.coder)
            if mode is not None:
                out.append(m.digit_len_model)
                out.extend([_DIGIT10] * mode)
            if t < self.W - 1:
                out.append(m.delim_model.coder)
        return out

    def encode(
        self, vals: Sequence, ctx: Dict[str, Sequence]
    ) -> Tuple[np.ndarray, np.ndarray]:
        if self._fixed is not None and len(vals):
            return self._encode_fixed(vals)
        return self._encode_rowwise(vals)

    def _encode_fixed(self, vals: Sequence) -> Tuple[np.ndarray, np.ndarray]:
        fixed = self._fixed
        assert fixed is not None
        t_len = fixed["t_len"]
        sv = [v if isinstance(v, str) else str(v) for v in vals]
        n = len(sv)
        ua = np.array(sv, dtype=f"U{t_len + 1}")
        cm = ua.view(np.uint32).reshape(n, t_len + 1)
        ok = np.char.str_len(ua) == t_len
        syms = np.zeros((n, self.n_slots), np.int64)
        base = 1 + self._nn
        syms[:, 0] = self.m.K
        syms[:, 1:base] = self._n_syms
        lut = fixed["lut"]
        for kind, coff, ln, slot, payload in fixed["spec"]:
            if kind == "digit":
                d = cm[:, coff:coff + ln].astype(np.int64) - 48
                ok &= ((d >= 0) & (d <= 9)).all(axis=1)
                syms[:, slot] = self._esc_digits
                syms[:, slot + 1] = ln - 1
                syms[:, slot + 2:slot + 2 + ln] = d
            elif kind == "word":
                codes, wid = payload
                if ln:
                    ok &= (cm[:, coff:coff + ln] == codes).all(axis=1)
                syms[:, slot] = wid
            else:  # delim
                ch = cm[:, coff].astype(np.int64)
                did = lut[np.clip(ch, 0, 127)]
                ok &= (ch < 128) & (did >= 0)
                syms[:, slot] = np.maximum(did, 0)
        bad = np.nonzero(~ok)[0]
        if bad.size:
            # Non-matching rows may still conform through other dictionary
            # words — re-check them with the exact row-wise encoder.
            sub_syms, sub_ok = self._encode_rowwise([sv[i] for i in bad])
            syms[bad] = sub_syms
            ok[bad] = sub_ok
        return syms, ok

    def _encode_rowwise(self, vals: Sequence) -> Tuple[np.ndarray, np.ndarray]:
        m, W = self.m, self.W
        n = len(vals)
        syms = np.zeros((n, self.n_slots), np.int64)
        ok = np.ones(n, bool)
        wget = m.dict_model.value2id.get
        dget = m.delim_model.value2id.get
        base = 1 + self._nn
        modes, woff, doff = self._modes, self._word_off, self._delim_off
        # blitzlint: waive[BL001] -- string tokenizer walks variable-length values on the fit/escape path
        for r, v in enumerate(vals):
            s = v if isinstance(v, str) else str(v)
            segs = m._split(s)
            if (len(segs) + 1) // 2 != W:
                ok[r] = False
                continue
            syms[r, 0] = m.K                      # empty queue: no prefix hit
            syms[r, 1:base] = self._n_syms
            for t, tok in enumerate(segs):
                if t % 2 == 1:
                    did = dget(tok)
                    if did is None:
                        ok[r] = False
                        break
                    syms[r, base + doff[t // 2]] = did
                    continue
                mode = modes[t // 2]
                off = base + woff[t // 2]
                if mode is None:
                    wid = wget(tok.encode("utf-8"))
                    if wid is None:               # dict miss (or digit token)
                        ok[r] = False
                        break
                    syms[r, off] = wid
                else:
                    if len(tok) > mode or not _is_digit_token(tok):
                        ok[r] = False
                        break
                    syms[r, off] = self._esc_digits
                    syms[r, off + 1] = len(tok) - 1
                    for i, ch in enumerate(tok):
                        syms[r, off + 2 + i] = ord(ch) - 48
                    # slots past len(tok) stay 0 — the scalar cap padding
        return syms, ok

    def decode(self, syms: np.ndarray, ctx: Dict[str, Any]) -> np.ndarray:
        base = 1 + self._nn
        cols = []
        for t, mode in enumerate(self._modes):
            off = base + self._word_off[t]
            if mode is None:
                tab = self._words
                cols.append(tab[np.minimum(syms[:, off], len(tab) - 1)])
            else:
                # variable-length digit run: grow each row's string up to
                # its decoded length (<= mode concat passes, vectorized)
                lens = np.minimum(syms[:, off + 1], mode - 1) + 1
                col = _DIGIT_CHARS[np.minimum(syms[:, off + 2], 9)].copy()
                for i in range(1, mode):
                    live = lens > i
                    if not live.any():
                        break
                    col[live] = col[live] + _DIGIT_CHARS[
                        np.minimum(syms[live, off + 2 + i], 9)
                    ]
                cols.append(col)
            if t < self.W - 1:
                tab = self._delims
                doff = base + self._delim_off[t]
                cols.append(tab[np.minimum(syms[:, doff], len(tab) - 1)])
        if len(cols) == 1:
            return cols[0]
        return np.asarray(["".join(parts) for parts in zip(*cols)], dtype=object)

    def conforms(self, v, row) -> bool:
        s = v if isinstance(v, str) else str(v)
        segs = self.m._split(s)
        if (len(segs) + 1) // 2 != self.W:
            return False
        wids = self.m.dict_model.value2id
        dids = self.m.delim_model.value2id
        for t, tok in enumerate(segs):
            if t % 2 == 1:
                if tok not in dids:
                    return False
                continue
            mode = self._modes[t // 2]
            if mode is None:
                if tok.encode("utf-8") not in wids:
                    return False
            elif len(tok) > mode or not _is_digit_token(tok):
                return False
        return True


# ---------------------------------------------------------------------------
# Table plan
# ---------------------------------------------------------------------------

def _pack_key(key_t: Tuple[int, ...], bases: Tuple[int, ...]) -> int:
    out = 0
    for k, b in zip(key_t, bases):
        out = out * b + k
    return out


def _parent_enum(
    plan_of: Dict[str, Tuple[Any, int]], parent: str
) -> Tuple[Tuple[int, ...], List[Tuple[Tuple[int, ...], Any]]]:
    """Enumerate (chain-symbol tuple, parent value) pairs for a parent column."""
    cp, off = plan_of[parent]
    if isinstance(cp, _CatPlan):
        return (off,), [((i,), v) for i, v in enumerate(cp.m.id2value)]
    if isinstance(cp, _CondPlan):
        chain = cp.chain_slots + (off,)
        out = []
        for key_t, sub in cp.sub_by_tuple.items():
            for i, v in enumerate(sub.id2value):
                out.append((key_t + (i,), v))
        return chain, out
    raise PlanFallback(f"conditional parent {parent!r} is not a categorical column")


def _build_cond(
    model: ConditionalCategoricalModel, plan_of: Dict[str, Tuple[Any, int]], name: str
) -> _CondPlan:
    if model.parent not in plan_of:
        raise PlanFallback(
            f"column {name!r}: parent {model.parent!r} not ordered before it"
        )
    chain, enum = _parent_enum(plan_of, model.parent)
    if len(enum) > MAX_COND_KEYS:
        raise PlanFallback(
            f"column {name!r}: {len(enum)} parent combinations exceed cap"
        )
    bases = tuple(max(k[i] for k, _ in enum) + 2 for i in range(len(chain)))
    sub_by_tuple = {key_t: model.cond.get(pv, model.marginal) for key_t, pv in enum}
    return _CondPlan(model, chain, bases, sub_by_tuple)


class TablePlan:
    """A compiled codec: static slots + vectorized value<->symbol tables."""

    def __init__(
        self, codec: Any, lowerings: List[Tuple[str, Any, int]]
    ) -> None:
        self.codec = codec
        self.order = list(codec.order)
        self.lowerings = lowerings
        self.by_column = {name: (cp, off) for name, cp, off in lowerings}
        self.lam = codec.lam
        # Per-column escape counters (§5-style dynamic value sets): how many
        # values failed to lower at encode time — the signal the adaptive
        # maintenance layer (DESIGN.md §4) watches to decide a column's model
        # has drifted.  Both the batch `encode_rows` masks and the scalar
        # `row_conforms` probe charge *every* non-conforming column of a row
        # (identical semantics, tested in tests/test_plan_escapes.py).
        # `escape_counts`/`rows_seen` are cumulative for the plan's lifetime;
        # the `window_*` pair resets on `reset_escapes()` so drift detection
        # sees rates over the current window, not the whole history.
        self.escape_counts: Dict[str, int] = {n: 0 for n, _, _ in lowerings}
        self.window_escapes: Dict[str, int] = {n: 0 for n, _, _ in lowerings}
        self.rows_seen = 0
        self.window_rows = 0
        self._accounting_paused = False
        self.coders: List = []
        for _, cp, _ in lowerings:
            self.coders.extend(cp.coders())
        self.S = len(self.coders)
        self.pallas_ok = (self.lam == TOTAL and all(
            isinstance(c, (DiscreteCoder, UniformCoder)) for c in self.coders))
        self._tables = None
        self._m_bits: Optional[Tuple[int, ...]] = None
        # Pre-build the 2**16 decoding maps (Fig 11): turns the per-slot
        # alias lookup into two gathers on the hot decode path.  Conditional
        # sub-coders are skipped — there can be thousands of them, and each
        # map costs ~0.75 MiB; they decode via the alias tables instead.
        for c in self.coders:
            if isinstance(c, DiscreteCoder):
                c.build_lut()

    # -- escape accounting (refit hook, DESIGN.md §4) --------------------
    def _charge(self, name: str, misses: int = 1) -> None:
        if self._accounting_paused:
            return
        self.escape_counts[name] += misses
        self.window_escapes[name] += misses

    def _note_rows(self, n: int) -> None:
        if self._accounting_paused:
            return
        self.rows_seen += n
        self.window_rows += n

    @contextlib.contextmanager
    def pause_escape_accounting(self) -> Iterator[None]:
        """Suspend counter updates for maintenance re-encodes.

        Migration re-encodes rows that already escaped once; charging them
        again would make maintenance traffic indistinguishable from
        workload drift and feed the monitor a signal it generated itself.
        """
        self._accounting_paused = True
        try:
            yield
        finally:
            self._accounting_paused = False

    def reset_escapes(self) -> Dict[str, int]:
        """Close the current escape window; returns its per-column counts.

        Cumulative ``escape_counts``/``rows_seen`` are untouched — drift
        detection consumes windows, long-horizon stats the totals.
        """
        snapshot = dict(self.window_escapes)
        for k in self.window_escapes:
            self.window_escapes[k] = 0
        self.window_rows = 0
        return snapshot

    def escape_rates(self) -> Dict[str, float]:
        """Per-column escape rate over the current window (0.0 if empty)."""
        n = self.window_rows
        if not n:
            return {k: 0.0 for k in self.window_escapes}
        return {k: v / n for k, v in self.window_escapes.items()}

    # -- encode ----------------------------------------------------------
    def encode_rows(self, rows: Sequence[Dict[str, Any]]
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Rows -> (syms int64[N, S], conforming bool[N])."""
        t0 = telemetry.clock()
        n = len(rows)
        self._note_rows(n)
        cols = {name: [r[name] for r in rows] for name in self.order}
        syms = np.zeros((n, self.S), np.int64)
        ok = np.ones(n, bool)
        for name, cp, off in self.lowerings:
            try:
                s_col, o = cp.encode(cols[name], cols)
            except Exception:
                self._charge(name, n)
                ok[:] = False
                continue
            syms[:, off:off + cp.n_slots] = s_col
            misses = int(n - np.count_nonzero(o))
            if misses:
                self._charge(name, misses)
            ok &= o
        _H_ENCODE_SCALAR.observe_since(t0)
        return syms, ok

    def encode_batch(self, syms: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Symbols -> CSR ``(codes uint16, offsets int64[N+1])``."""
        t0 = telemetry.clock()
        codes, offsets = vectorized.encode_batch(syms, self.coders, self.lam)
        codes = checked_astype(codes, np.uint16, where="encode_batch codes")
        _C_ENCODE_ROWS.add(syms.shape[0])
        _H_ENCODE.observe_since(t0)
        return codes, offsets

    def row_conforms(self, row: Dict[str, Any]) -> bool:
        """Cheap scalar check: would this row take the fast path?

        Pure-Python per-column checks (no numpy) so the per-insert cost is a
        few dict lookups, not a 1-row batch encode.  Every non-conforming
        column is charged in :attr:`escape_counts` — the same per-column
        semantics as the batch ``encode_rows`` masks, so drift rates don't
        depend on which encode path a row took.
        """
        self._note_rows(1)
        ok = True
        for name, cp, _ in self.lowerings:
            try:
                good = cp.conforms(row[name], row)
            except (TypeError, KeyError, ValueError):
                good = False
            if not good:
                self._charge(name)
                ok = False
        return ok

    # -- decode ----------------------------------------------------------
    def decode_batch(self, codes: np.ndarray, offsets: np.ndarray,
                     n_tuples: Optional[int] = None) -> np.ndarray:
        return vectorized.decode_batch(
            codes, offsets, self.coders, n_tuples=n_tuples, lam=self.lam
        )

    def decode_select(
        self,
        codes: np.ndarray,
        offsets: np.ndarray,
        rows: np.ndarray,
        backend: str = "numpy",
    ) -> np.ndarray:
        """Random-access decode of selected tuples -> syms int64[R, S]."""
        t0 = telemetry.clock()
        if backend == "pallas":
            out = self._decode_select_pallas(codes, offsets, rows)
        else:
            out = vectorized.decode_select(codes, offsets, self.coders, rows, self.lam)
        _C_DECODE_ROWS.add(int(np.size(rows)))
        _H_DECODE.observe_since(t0)
        return out

    def _decode_select_pallas(
        self, codes: np.ndarray, offsets: np.ndarray, rows: np.ndarray
    ) -> np.ndarray:
        if not self.pallas_ok:
            raise PlanFallback("plan has conditional slots; Pallas ineligible")
        import jax.numpy as jnp
        from repro.kernels.delayed_decode import delayed_decode
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return np.zeros((0, self.S), np.int64)
        # Pad the batch to a pow2 bucket (floor 8) so jax traces one
        # kernel per bucket instead of one per distinct batch size — the
        # same bucketing the prepared-op cache keys on (DESIGN.md §11).
        n = rows.size
        padded = 1 << max(3, (n - 1).bit_length())
        if padded != n:
            rows = np.concatenate([rows, np.full(padded - n, rows[-1], np.int64)])
        starts = offsets[rows]
        lens = offsets[rows + 1] - starts
        cols = np.arange(self.S)[None, :]
        idx = starts[:, None] + np.minimum(cols, np.maximum(lens[:, None] - 1, 0))
        idx = np.minimum(idx, max(codes.size - 1, 0))
        dense = np.where(cols < lens[:, None], np.asarray(codes)[idx], 0).astype(
            np.int32
        )
        tables, m_bits = self.pallas_tables()
        out = delayed_decode(jnp.asarray(dense), tables, m_bits)
        return np.asarray(out).astype(np.int64)[:n]

    def pallas_tables(self) -> Tuple[Any, int]:
        """Lazy ``(tables f32[S, M, 7], m_bits)`` in the kernel's layout."""
        if self._tables is None:
            t0 = telemetry.clock()
            from repro.kernels.ops import pack_slot_tables
            self._tables, self._m_bits = pack_slot_tables(self.coders)
            _C_PALLAS_PACK.inc()
            _H_PALLAS_PACK.observe_since(t0)
        return self._tables, self._m_bits

    def decode_syms_to_rows(
        self, syms: np.ndarray, columns: Optional[Sequence[str]] = None
    ) -> List[Dict[str, Any]]:
        """Symbols -> row dicts (vectorized per-column reconstruction).

        ``columns`` restricts materialization to a projection: only the
        requested columns (plus any conditional-parent ancestors their
        decode needs for context) are reconstructed, and the returned
        dicts hold exactly the requested columns.
        """
        ctx: Dict[str, Any] = {}
        need: Optional[set] = None
        if columns is not None:
            unknown = set(columns) - set(self.order)
            if unknown:
                raise KeyError(f"unknown columns: {sorted(unknown)}")
            need = set(columns)
            # Parents precede children in lowering order, so a reversed
            # walk closes the ancestor chain in one pass.
            for name, cp, _ in reversed(self.lowerings):
                if name in need and isinstance(cp, _CondPlan):
                    need.add(cp.m.parent)
        for name, cp, off in self.lowerings:
            if need is not None and name not in need:
                continue
            ctx[name] = cp.decode(syms[:, off:off + cp.n_slots], ctx)
        names = (self.order if columns is None
                 else [n for n in self.order if n in set(columns)])
        # Bulk-convert numpy columns to Python objects (ints/floats/strs):
        # much faster than boxing one numpy scalar per field, and the row
        # dicts then hold the same native types the scalar decoder emits.
        cols = [c.tolist() if isinstance(c, np.ndarray) else list(c)
                for c in (ctx[nm] for nm in names)]
        return [dict(zip(names, vals)) for vals in zip(*cols)]


# ---------------------------------------------------------------------------
# Code-space predicate lowering (scan engine, DESIGN.md §8)
#
# The scan engine (repro.scan) translates value-space predicates into this
# plan version's symbol space once per scan, then evaluates them against raw
# code streams / decoded symbol prefixes without materializing rows.  The
# helpers live here because they reach into the per-column lowering internals
# (_CatPlan vocabularies, _NumPlan quantization grids).
# ---------------------------------------------------------------------------

def scan_lowering(plan: TablePlan, name: str) -> Optional[Tuple[str, Any, int]]:
    """``('cat'|'num', colplan, slot_offset)`` when predicates on column
    ``name`` are code-space evaluable under ``plan``, else None (string and
    conditional columns fall back to decode-then-filter)."""
    ent = plan.by_column.get(name)
    if ent is None:
        return None
    cp, off = ent
    if isinstance(cp, _CatPlan):
        return ("cat", cp, off)
    if isinstance(cp, _NumPlan):
        return ("num", cp, off)
    return None


def lower_cat_ids(cp: _CatPlan, values: Sequence[Any]) -> np.ndarray:
    """Translate literal values to this version's category ids (sorted).

    Literals outside the vocabulary are dropped: a *fast* row always encodes
    an in-vocabulary id, so a missing literal can never match a fast block.
    """
    ids = set()
    # blitzlint: waive[BL001] -- fit-time categorical lowering, not the per-op hot path
    for v in values:
        i = _safe_get(cp.m.value2id.get, v)
        if i >= 0:
            ids.add(int(i))
    return np.asarray(sorted(ids), dtype=np.int64)


def lower_cat_range_ids(cp: _CatPlan, lo: Any, hi: Any) -> Optional[np.ndarray]:
    """Ids of vocabulary values inside ``[lo, hi]`` — range predicates on
    int columns that specialized to a categorical vocabulary.  ``None`` when
    the vocabulary does not compare against the bounds (mixed types)."""
    ids = []
    try:
        for i, v in enumerate(cp.m.id2value):
            if (lo is None or v >= lo) and (hi is None or v <= hi):
                ids.append(i)
    except TypeError:
        return None
    return np.asarray(ids, dtype=np.int64)


def _num_decoded_at(m: NumericModel, q: int) -> float:
    """The value the decoder reconstructs for quantized step ``q``."""
    if m.integer:
        return float(int(round(m.vmin + q * m.p)))
    return m.vmin + (q + 0.5) * m.p


def lower_num_interval(
    m: NumericModel, lo: Optional[float], hi: Optional[float]
) -> Optional[Tuple[int, int]]:
    """``(qlo, qhi)`` with decoded(q) ∈ [lo, hi]  ⇔  qlo <= q <= qhi.

    Decode is monotone non-decreasing in q, so a value-space interval maps
    to one q-interval: seed each endpoint with the quantization guess, then
    correct against the actual decoded values (never off by more than a
    step or two).  ``None`` bounds are open; returns ``None`` when no
    conforming value can match.
    """
    steps = m.total_steps
    if lo is None:
        qlo = 0
    else:
        flo = float(lo)
        g = min(max(int(math.floor((flo - m.vmin) / m.p + 1e-9)), 0), steps - 1)
        while g > 0 and _num_decoded_at(m, g - 1) >= flo:
            g -= 1
        while g < steps and _num_decoded_at(m, g) < flo:
            g += 1
        qlo = g
    if hi is None:
        qhi = steps - 1
    else:
        fhi = float(hi)
        g = min(max(int(math.floor((fhi - m.vmin) / m.p + 1e-9)), 0), steps - 1)
        while g < steps - 1 and _num_decoded_at(m, g + 1) <= fhi:
            g += 1
        while g >= 0 and _num_decoded_at(m, g) > fhi:
            g -= 1
        qhi = g
    if qlo >= steps or qhi < 0 or qlo > qhi:
        return None
    return (int(qlo), int(qhi))


def num_q_of_syms(cp: _NumPlan, syms: np.ndarray) -> np.ndarray:
    """Quantized step q per row from a numeric column's symbol slots."""
    m = cp.m
    q = syms[:, 0] * m.G
    for t, w in enumerate(m.radix):
        q = q + syms[:, 1 + t] * w
    return q


def slot0_match_lut(coder, match_ids: np.ndarray) -> Optional[np.ndarray]:
    """``bool[TOTAL]``: does a raw slot-0 stream code decode to a match id?

    Valid because slot 0 is always physical (delayed coding starts with an
    option-count product of 1, below any lambda) and ``_lut_sym[code]`` is
    that code's exact slot-0 symbol regardless of the delayed payload its
    remaining bits carry — so gathering the LUT at each block's first code
    evaluates the predicate without decoding anything.
    """
    if not isinstance(coder, DiscreteCoder):
        return None
    if coder._lut_sym is None:
        coder.build_lut()
    return np.isin(coder._lut_sym, np.asarray(match_ids, dtype=np.int64))


def quantize_slack(model: Any) -> Optional[float]:
    """Worst-case ``|decoded - raw|`` for conforming values under ``model``.

    Zone maps hold *raw* value bounds while predicates match *decoded*
    values, so pruning must widen the zone test by this slack or a value
    quantized across a bound would be falsely pruned.  ``None`` = unbounded
    (never zone-prune on a column using this model); escapes decode to the
    exact raw value and need no slack.
    """
    if isinstance(model, (CategoricalModel, ConditionalCategoricalModel)):
        return 0.0
    if isinstance(model, NumericModel):
        return float(model.p)
    return None


def decode_select_prefix(
    plan: TablePlan, codes: np.ndarray, offsets: np.ndarray, rows: np.ndarray, upto: int
) -> np.ndarray:
    """Truncated random-access decode of the first ``upto`` slots.

    Delayed coding reads the stream strictly forward, so a slot prefix
    consumes a prefix of each row's code run: ``decode_batch`` over the
    truncated coder list with an explicit ``n_tuples`` (which skips the
    full-stream alignment assert) decodes it exactly.  Predicate
    evaluation uses this to touch only the slots the predicates name.
    """
    return vectorized.decode_select(
        codes, offsets, plan.coders[:upto], np.asarray(rows, np.int64), plan.lam
    )


def compile_plan(codec) -> TablePlan:
    """Lower a fitted TableCodec to a TablePlan, or raise PlanFallback."""
    if codec.block_tuples != 1:
        raise PlanFallback(
            f"block_tuples={codec.block_tuples}: multi-tuple blocks chain "
            "virtual bits across rows")
    lowerings: List[Tuple[str, Any, int]] = []
    plan_of: Dict[str, Tuple[Any, int]] = {}
    offset = 0
    for name in codec.order:
        m = codec.models[name]
        if isinstance(m, ConditionalCategoricalModel):
            cp: Any = _build_cond(m, plan_of, name)
        elif isinstance(m, CategoricalModel):
            cp = _CatPlan(m)
        elif isinstance(m, NumericModel):
            cp = _NumPlan(m)
        elif isinstance(m, StringModel):
            cp = _StrPlan(m)
        elif isinstance(m, TimeSeriesModel):
            raise PlanFallback(
                f"column {name!r}: time-series model is stateful across rows"
            )
        else:
            raise PlanFallback(
                f"column {name!r}: {type(m).__name__} has no slot lowering"
            )
        lowerings.append((name, cp, offset))
        plan_of[name] = (cp, offset)
        offset += cp.n_slots
    return TablePlan(codec, lowerings)
