"""Checked narrowing casts (blitzlint BL005 / DESIGN.md §10).

The code paths that narrow to ``uint16``/``int32`` do so because the
values are *structurally* bounded — delayed-coding emits codes below
``TOTAL``, alias tables index symbol alphabets far below 2**31 — but a
plain ``astype`` silently wraps when that reasoning rots.  These
wrappers keep the fast path a plain cast while the sanitizer is off and
validate the actual value range (raising
:class:`~repro.sanitize.SanitizeError`) under ``REPRO_SANITIZE=1``.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro import sanitize


class NarrowingCastError(sanitize.SanitizeError):
    """A checked narrowing cast would have wrapped or truncated."""


def _check_bounds(arr: np.ndarray, dtype: Any, where: str) -> None:
    info = np.iinfo(dtype)
    if arr.size == 0:
        return
    lo, hi = int(arr.min()), int(arr.max())
    if lo < info.min or hi > info.max:
        sanitize._fail(
            NarrowingCastError,
            f"{where}: values span [{lo}, {hi}] outside "
            f"{np.dtype(dtype).name} range [{info.min}, {info.max}]",
        )


def checked_astype(arr: np.ndarray, dtype: Any, *, where: str) -> np.ndarray:
    """``arr.astype(dtype)`` with an opt-in bounds check.

    ``where`` names the call site in the failure message (there is no
    useful traceback once the wrapped value has flowed downstream).
    """
    if sanitize.ENABLED:
        a = np.asarray(arr)
        if a.dtype.kind in "iu":
            _check_bounds(a, dtype, where)
    return arr.astype(dtype)


def checked_asarray(values: Any, dtype: Any, *, where: str) -> np.ndarray:
    """``np.asarray(values, dtype)`` with an opt-in bounds check (for
    call sites converting Python lists straight into a narrow dtype)."""
    if sanitize.ENABLED:
        a = np.asarray(values)
        if a.dtype.kind in "iu":
            _check_bounds(a, dtype, where)
        return a.astype(dtype)
    return np.asarray(values, dtype=dtype)
