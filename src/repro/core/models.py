"""Semantic column models (§4): value <-> slot-symbol translation.

Each model maps a column value to a short sequence of (coder, symbol) slots
and back.  Models *estimate distributions* rather than pinning static
dictionaries, so unseen values stay encodable through explicit escape paths
(the paper's "dynamic value set" requirement for OLTP inserts).

Models compose (§4.3): the string model nests categorical, numeric and
Markov sub-models; the numeric model nests a categorical level-1 and uniform
level-2 coders.
"""

from __future__ import annotations

import math
from collections import Counter, deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .coders import TOTAL, DiscreteCoder, UniformCoder, quantize_freqs
from .delayed import BlockDecoder, Slot


class BlockEncoder:
    """Collects slots for one block; models append via :meth:`add`."""

    __slots__ = ("slots",)

    def __init__(self) -> None:
        self.slots: List[Slot] = []

    def add(self, coder, sym: int) -> None:
        self.slots.append(
            Slot(k=coder.k(sym), code_for=lambda a, c=coder, s=sym: c.code_for(s, a))
        )


_RAW64 = UniformCoder(TOTAL)  # raw 16-bit payload slot
_BYTE = UniformCoder(256)
_DIGIT10 = UniformCoder(10)

#: Longest run of ASCII digits encoded through the digit path.  Bounded so
#: the per-token length symbol fits one small DiscreteCoder alphabet.
MAX_DIGIT_LEN = 16

#: Distinct-value cap for per-position word stats (StringModel.pos_words).
_POS_WORD_CAP = 64


def _is_digit_token(tok: str) -> bool:
    """True iff ``tok`` is a non-empty run of ASCII ``0-9`` we digit-encode.

    The rule is *value-only* and deterministic: any such token always takes
    the digit path (never the word dictionary), so the scalar encoder, the
    slot-plan compiler, and conformance checks agree without coordination.
    """
    return 0 < len(tok) <= MAX_DIGIT_LEN and all("0" <= c <= "9" for c in tok)


def _encode_raw_bytes(enc: BlockEncoder, payload: bytes) -> None:
    if len(payload) > 255:
        raise ValueError("escape payload too long (>255 bytes)")
    enc.add(_BYTE, len(payload))
    for b in payload:
        enc.add(_BYTE, b)


def _decode_raw_bytes(dec: BlockDecoder) -> bytes:
    n = dec.next_symbol(_BYTE)
    return bytes(dec.next_symbol(_BYTE) for _ in range(n))


def _encode_f64(enc: BlockEncoder, v: float) -> None:
    bits = int(np.float64(v).view(np.uint64))
    for i in range(4):
        enc.add(_RAW64, (bits >> (16 * i)) & 0xFFFF)


def _decode_f64(dec: BlockDecoder) -> float:
    bits = 0
    for i in range(4):
        bits |= dec.next_symbol(_RAW64) << (16 * i)
    return float(np.uint64(bits).view(np.float64))


# ---------------------------------------------------------------------------
# Categorical model (§4.1)
# ---------------------------------------------------------------------------

class CategoricalModel:
    """Frequency model over observed values + escape for unseen ones."""

    def __init__(
        self,
        values: Sequence[Any],
        esc_weight: float | None = None,
        digit_esc_weight: float | None = None,
    ) -> None:
        counts = Counter(values)
        self.id2value = list(counts.keys())
        self.value2id = {v: i for i, v in enumerate(self.id2value)}
        n = len(self.id2value)
        freqs = np.array([counts[v] for v in self.id2value], dtype=np.float64)
        if esc_weight is None:
            # Good-Turing flavour: escape mass ~ number of singletons.
            esc_weight = max(1.0, float((freqs == 1).sum()))
        self.esc = n
        # Optional second escape used by StringModel for all-digit tokens:
        # the caller owns what follows the symbol in the stream.
        self.esc_digits: int | None = None
        tail = [esc_weight]
        if digit_esc_weight is not None:
            self.esc_digits = n + 1
            tail.append(digit_esc_weight)
        self.coder = DiscreteCoder(quantize_freqs(np.append(freqs, tail)))
        self._probs = self.coder.tables.k_of.astype(np.float64) / TOTAL

    def encode_value(self, v: Any, enc: BlockEncoder, ctx=None) -> None:
        i = self.value2id.get(v)
        if i is None:
            enc.add(self.coder, self.esc)
            _encode_raw_bytes(enc, _to_bytes(v))
        else:
            enc.add(self.coder, i)

    def decode_value(self, dec: BlockDecoder, ctx=None) -> Any:
        sym = dec.next_symbol(self.coder)
        if sym == self.esc:
            return _from_bytes(_decode_raw_bytes(dec))
        return self.id2value[sym]

    def est_bits(self, v: Any) -> float:
        i = self.value2id.get(v)
        if i is None:
            return -math.log2(self._probs[self.esc]) + 8.0 * (len(_to_bytes(v)) + 1)
        return -math.log2(self._probs[i])

    def model_bytes(self) -> int:
        t = self.coder.tables
        return (t.threshold.nbytes + t.sym_u.nbytes + t.sym_v.nbytes +
                t.ja.nbytes + t.jb.nbytes + t.k_of.nbytes +
                sum(len(_to_bytes(v)) + 8 for v in self.id2value))


def _to_bytes(v: Any) -> bytes:
    """Type-tagged escape payload (unseen values keep their exact type)."""
    if isinstance(v, bytes):
        return b"B" + v
    if isinstance(v, str):
        return b"S" + v.encode("utf-8")
    if isinstance(v, (int, np.integer)):
        return b"I" + repr(int(v)).encode()
    if isinstance(v, (float, np.floating)):
        return b"F" + np.float64(v).tobytes()
    return b"S" + repr(v).encode("utf-8")


def _from_bytes(b: bytes) -> Any:
    tag, payload = b[:1], b[1:]
    if tag == b"B":
        return payload
    if tag == b"I":
        return int(payload.decode())
    if tag == b"F":
        return float(np.frombuffer(payload, np.float64)[0])
    return payload.decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Two-level numeric model (§4.2)
# ---------------------------------------------------------------------------

class NumericModel:
    """Two-level quantization: skew-aware buckets + uniform precision grid.

    Level 1 assigns frequency-proportional intervals to ``T`` equi-width
    buckets; level 2 splits each bucket into ``G`` equal segments of width
    <= precision ``p``.  Values are recovered to within ``p/2``; integer
    columns (``p=1``, ``integer=True``) are recovered exactly.  Out-of-range
    values escape to a raw float64 payload (the paper's bisection fallback
    carries the same cost model).
    """

    ESC_NAME = "<esc>"

    def __init__(
        self,
        values: Sequence[float],
        precision: float = 1.0,
        T: int = 512,
        integer: bool = False,
    ):
        vals = np.asarray([v for v in values], dtype=np.float64)
        if vals.size == 0:
            vals = np.zeros(1)
        self.p = float(precision)
        self.integer = bool(integer)
        self.vmin = float(
            np.floor(vals.min() / self.p) * self.p
        ) if self.integer else float(vals.min())
        vmax = float(vals.max())
        total_steps = int(math.floor((vmax - self.vmin) / self.p + 1e-9)) + 1
        self.total_steps = total_steps
        self.G = max(1, -(-total_steps // T))        # steps per bucket
        self.T = -(-total_steps // self.G)           # actual bucket count
        q = self._quantize(vals)
        buckets = np.clip(q // self.G, 0, self.T - 1)
        counts = np.bincount(buckets, minlength=self.T).astype(np.float64)
        counts = np.append(counts, max(1.0, 0.001 * vals.size))  # escape bucket
        self.esc = self.T
        self.l1 = DiscreteCoder(quantize_freqs(counts))
        self._probs = self.l1.tables.k_of.astype(np.float64) / TOTAL
        # level-2 digit chain, most-significant first
        self.l2: List[UniformCoder] = []
        g = self.G
        digits = []
        while g > 1:
            digits.append(min(g, TOTAL))
            g = -(-g // TOTAL)
        for arity in reversed(digits):
            self.l2.append(UniformCoder(arity))
        # radix weights for digit (de)composition
        self.radix = []
        w = 1
        for c in reversed(self.l2):
            self.radix.insert(0, w)
            w *= c.G

    def _quantize(self, v) -> np.ndarray:
        return np.floor(
            (np.asarray(v, dtype=np.float64) - self.vmin) / self.p + 1e-9
        ).astype(np.int64)

    def encode_value(self, v: float, enc: BlockEncoder, ctx=None) -> None:
        fv = float(v)
        q = int(self._quantize(fv)) if math.isfinite(fv) else -1
        if not (0 <= q < self.total_steps):
            enc.add(self.l1, self.esc)
            _encode_f64(enc, fv)
            return
        i, j = q // self.G, q % self.G
        enc.add(self.l1, i)
        for coder, w in zip(self.l2, self.radix):
            d = j // w
            j -= d * w
            enc.add(coder, d)

    def decode_value(
        self, dec: BlockDecoder, ctx: Optional[Dict[str, Any]] = None
    ) -> Any:
        i = dec.next_symbol(self.l1)
        if i == self.esc:
            v = _decode_f64(dec)
            return int(v) if self.integer else v
        j = 0
        for coder, w in zip(self.l2, self.radix):
            j += dec.next_symbol(coder) * w
        q = i * self.G + j
        if self.integer:
            return int(round(self.vmin + q * self.p))
        return self.vmin + (q + 0.5) * self.p

    def roundtrip(self, v: float) -> float:
        """The value the decoder will reconstruct for input ``v``."""
        q = int(self._quantize(v))
        if not (0 <= q < self.total_steps):
            return int(v) if self.integer else float(np.float64(v))
        if self.integer:
            return int(round(self.vmin + q * self.p))
        return self.vmin + (q + 0.5) * self.p

    def bucket_of(self, v: float) -> int:
        fv = float(v)
        if not math.isfinite(fv):
            return self.esc
        q = int(self._quantize(fv))
        if not (0 <= q < self.total_steps):
            return self.esc
        return q // self.G

    def est_bits(self, v: float) -> float:
        b = self.bucket_of(v)
        if b == self.esc:
            return -math.log2(self._probs[self.esc]) + 64.0
        return -math.log2(self._probs[b]) + math.log2(self.G)

    def model_bytes(self) -> int:
        t = self.l1.tables
        return (t.threshold.nbytes + t.sym_u.nbytes + t.sym_v.nbytes +
                t.ja.nbytes + t.jb.nbytes + t.k_of.nbytes + 64)


# ---------------------------------------------------------------------------
# Markov letter model (order-1 over bytes; §4.3 / App. E.2)
# ---------------------------------------------------------------------------

class ByteMarkov:
    """Order-1 byte model with END symbol; lazily built per-state coders."""

    START, END = 256, 256  # state 256 = start-of-word; symbol 256 = end

    def __init__(
        self, words: Sequence[bytes], smoothing: float = 0.1
    ) -> None:
        trans: Dict[int, Counter] = {}
        for w in words:
            prev = self.START
            for b in w:
                trans.setdefault(prev, Counter())[b] += 1
                prev = b
            trans.setdefault(prev, Counter())[self.END] += 1
        self._counts = trans
        self._smooth = smoothing
        self._coders: Dict[int, DiscreteCoder] = {}
        marg = Counter()
        for c in trans.values():
            marg.update(c)
        self._marginal_counts = marg

    def _coder(self, state: int) -> DiscreteCoder:
        c = self._coders.get(state)
        if c is None:
            cnt = self._counts.get(state, self._marginal_counts)
            freqs = np.full(257, self._smooth, dtype=np.float64)
            for b, n in cnt.items():
                freqs[b] += n
            c = DiscreteCoder(quantize_freqs(freqs))
            self._coders[state] = c
        return c

    def encode_word(self, w: bytes, enc: BlockEncoder) -> None:
        prev = self.START
        for b in w:
            enc.add(self._coder(prev), b)
            prev = b
        enc.add(self._coder(prev), self.END)

    def decode_word(self, dec: BlockDecoder) -> bytes:
        out = bytearray()
        prev = self.START
        while True:
            b = dec.next_symbol(self._coder(prev))
            if b == self.END:
                return bytes(out)
            out.append(b)
            prev = b

    def model_bytes(self) -> int:
        return sum(len(c) * 12 for c in self._counts.values())


# ---------------------------------------------------------------------------
# String model (§4.3, Figure 6)
# ---------------------------------------------------------------------------

_DELIMS = " ,.-_/:;@#|()"


class StringModel:
    """Prefix queue + word/delimiter split + global dictionary + Markov.

    The prefix queue holds the last ``K`` strings *within the current block*
    (granularity = the compression block, so random access stays closed).
    """

    K = 4
    MIN_PREFIX = 4

    def __init__(
        self,
        values: Sequence[str],
        dict_min_count: int = 2,
        dict_cap: int = 4096,
        block_tuples: int = 1,
    ):
        values = [v if isinstance(v, str) else str(v) for v in values]
        # Simulate the queue with the SAME block structure used at encode
        # time (the queue resets per block for random access): otherwise the
        # fitted (i, h, n_words) distributions mismatch reality and common
        # cases become expensive.
        queue: deque = deque(maxlen=self.K)
        i_seen, h_seen = [], []
        words_all: List[bytes] = []
        delims: List[str] = []
        nseg: List[int] = []
        digit_lens: List[int] = []
        # Per-(segment-count, word-position) token-kind stats: Counter keys
        # are a digit length L >= 1 or -1 for a dictionary/Markov word.  The
        # slot-plan compiler uses the majority kind to fix each template
        # position's mode (plan.py).
        self.pos_kinds: Dict[int, List[Counter]] = {}
        # Per-position word-value stats for non-digit tokens, capped at
        # ``_POS_WORD_CAP`` distinct values (a ``None`` key marks the
        # position as high-cardinality).  Lets the plan compiler detect
        # near-constant word positions and lower them to a vectorized
        # character-matrix check.
        self.pos_words: Dict[int, List[Counter]] = {}
        for idx, s in enumerate(values):
            if idx % max(1, block_tuples) == 0:
                queue.clear()
            i, h = self._best_match(s, queue)
            i_seen.append(i)
            if i < self.K:
                h_seen.append(h)
                rest = s[h:]
            else:
                rest = s
            segs = self._split(rest)
            row_n = (len(segs) + 1) // 2
            nseg.append(row_n)
            kinds_row = self.pos_kinds.setdefault(
                row_n, [Counter() for _ in range(row_n)]
            )
            words_row = self.pos_words.setdefault(
                row_n, [Counter() for _ in range(row_n)]
            )
            for t, tok in enumerate(segs):
                if t % 2 == 0:
                    if _is_digit_token(tok):
                        digit_lens.append(len(tok))
                        kinds_row[t // 2][len(tok)] += 1
                    else:
                        words_all.append(tok.encode("utf-8"))
                        kinds_row[t // 2][-1] += 1
                        wcounter = words_row[t // 2]
                        if None in wcounter or len(wcounter) > _POS_WORD_CAP:
                            wcounter[None] += 1
                        else:
                            wcounter[tok] += 1
                else:
                    delims.append(tok)
            queue.append(s)
        # Segment-count histogram: the slot-plan compiler (plan.py) uses it
        # to derive a fixed word/delimiter template for format-fixed columns.
        self.n_words_counts = Counter(nseg)
        # Per-(segment-count, word-position) digit cap: the max digit-token
        # length observed there at fit (0 = never a digit).  The digit path
        # pads every token to the position's cap so each position costs a
        # FIXED number of symbols — what lets the slot plan lower
        # variable-length numbers (street/sku/phone runs) to fixed slots
        # while staying bit-identical to this scalar coder.
        self.pos_digit_max: Dict[int, List[int]] = {
            W: [max((k for k in c if k >= 1), default=0) for c in counters]
            for W, counters in self.pos_kinds.items()
        }
        self.i_model = DiscreteCoder(
            quantize_freqs(np.bincount(i_seen, minlength=self.K + 1) + 0.5)
        )
        self.h_model = NumericModel(
            h_seen or [self.MIN_PREFIX], precision=1, T=256, integer=True
        )
        self.n_model = NumericModel(nseg or [1], precision=1, T=64, integer=True)
        self.delim_model = CategoricalModel(delims or [" "])
        # All-digit tokens never enter the dictionary or the Markov escape:
        # they flow through the fixed-rate digit path behind ``esc_digits``.
        lens_arr = np.array([L - 1 for L in digit_lens], dtype=np.int64)
        self.digit_len_model = DiscreteCoder(
            quantize_freqs(np.bincount(lens_arr, minlength=MAX_DIGIT_LEN) + 0.5)
        )
        wc = Counter(words_all)
        common = {w for w, c in wc.most_common(dict_cap) if c >= dict_min_count}
        self.dict_model = CategoricalModel(
            [w for w in words_all if w in common] or [b""],
            esc_weight=max(1.0, sum(c for w, c in wc.items() if w not in common)),
            digit_esc_weight=max(1.0, float(len(digit_lens))),
        )
        self.markov = ByteMarkov([w for w in words_all if w not in common] or [b"a"])
        self._block_queue: deque = deque(maxlen=self.K)

    @staticmethod
    def _split(s: str) -> List[str]:
        segs: List[str] = []
        cur = []
        for ch in s:
            if ch in _DELIMS:
                segs.append("".join(cur))
                segs.append(ch)
                cur = []
            else:
                cur.append(ch)
        segs.append("".join(cur))
        return segs  # words at even idx, delimiters at odd idx

    def _best_match(self, s: str, queue) -> tuple:
        best_i, best_h = self.K, 0
        for i, prev in enumerate(queue):
            h = 0
            for a, b in zip(s, prev):
                if a != b:
                    break
                h += 1
            if h >= self.MIN_PREFIX and h > best_h:
                best_i, best_h = i, h
        return best_i, best_h

    def reset_block(self) -> None:
        self._block_queue.clear()

    def digit_cap(self, n_words: int, t: int) -> int:
        """Digit-slot budget for word position ``t`` of an ``n_words``
        template (0 = the position never digit-encodes)."""
        caps = self.pos_digit_max.get(n_words)
        if caps is None or t >= len(caps):
            return 0
        return caps[t]

    def encode_value(self, v: str, enc: BlockEncoder, ctx=None) -> None:
        s = v if isinstance(v, str) else str(v)
        i, h = self._best_match(s, self._block_queue)
        enc.add(self.i_model, i)
        if i < self.K:
            self.h_model.encode_value(h, enc)
            rest = s[h:]
        else:
            rest = s
        segs = self._split(rest)
        n_words = (len(segs) + 1) // 2
        self.n_model.encode_value(n_words, enc)
        for t, tok in enumerate(segs):
            if t % 2 == 0:
                cap = self.digit_cap(n_words, t // 2) if _is_digit_token(tok) else 0
                if 0 < len(tok) <= cap:
                    enc.add(self.dict_model.coder, self.dict_model.esc_digits)
                    enc.add(self.digit_len_model, len(tok) - 1)
                    for ch in tok:
                        enc.add(_DIGIT10, ord(ch) - 48)
                    for _ in range(cap - len(tok)):  # pad to the fixed cap
                        enc.add(_DIGIT10, 0)
                    continue
                # digit tokens longer than the position's cap (or at
                # positions never seen as digits) take the word path and
                # escape through the Markov coder — dicts never hold them.
                wb = tok.encode("utf-8")
                wid = self.dict_model.value2id.get(wb)
                if wid is None:
                    enc.add(self.dict_model.coder, self.dict_model.esc)
                    self.markov.encode_word(wb, enc)
                else:
                    enc.add(self.dict_model.coder, wid)
            else:
                self.delim_model.encode_value(tok, enc)
        self._block_queue.append(s)

    def decode_value(self, dec: BlockDecoder, ctx=None) -> str:
        i = dec.next_symbol(self.i_model)
        prefix = ""
        if i < self.K:
            h = self.h_model.decode_value(dec)
            prefix = self._block_queue[i][:h]
        n_words = self.n_model.decode_value(dec)
        parts: List[str] = []
        for t in range(n_words):
            sym = dec.next_symbol(self.dict_model.coder)
            if sym == self.dict_model.esc:
                parts.append(
                    self.markov.decode_word(dec).decode("utf-8", errors="replace")
                )
            elif sym == self.dict_model.esc_digits:
                n_dig = dec.next_symbol(self.digit_len_model) + 1
                parts.append(
                    "".join(chr(48 + dec.next_symbol(_DIGIT10))
                            for _ in range(n_dig))
                )
                for _ in range(self.digit_cap(n_words, t) - n_dig):
                    dec.next_symbol(_DIGIT10)  # drain the cap padding
            else:
                parts.append(
                    self.dict_model.id2value[sym].decode("utf-8", errors="replace")
                )
            if t < n_words - 1:
                parts.append(self.delim_model.decode_value(dec))
        s = prefix + "".join(parts)
        self._block_queue.append(s)
        return s

    def est_bits(self, v: str) -> float:
        # crude: dictionary words cheap, escapes pay per byte
        s = v if isinstance(v, str) else str(v)
        bits = 4.0
        segs = self._split(s)
        nw = (len(segs) + 1) // 2
        for t, tok in enumerate(segs):
            if t % 2 == 0:
                if _is_digit_token(tok):
                    cap = self.digit_cap(nw, t // 2)
                    if 0 < len(tok) <= cap:
                        bits += 2.0 + math.log2(10.0) * cap
                        continue
                wb = tok.encode("utf-8")
                if wb in self.dict_model.value2id:
                    bits += self.dict_model.est_bits(wb)
                else:
                    bits += 5.0 * (len(wb) + 1)
            else:
                bits += self.delim_model.est_bits(tok)
        return bits

    def model_bytes(self) -> int:
        t = self.digit_len_model.tables
        return (self.dict_model.model_bytes() + self.delim_model.model_bytes() +
                self.markov.model_bytes() + self.h_model.model_bytes() +
                self.n_model.model_bytes() + t.k_of.nbytes + 64)


# ---------------------------------------------------------------------------
# Conditional wrapper (structure learning output, §2.2/§3)
# ---------------------------------------------------------------------------

class ConditionalCategoricalModel:
    """Child categorical distribution conditioned on a parent column's value.

    Implemented as the paper describes: an unordered map from each parent
    value to a probability distribution; unseen parent values fall back to
    the marginal model.
    """

    def __init__(
        self,
        pairs: Sequence,
        parent_name: str,
        min_group: int = 8,
        max_groups: int = 4096,
    ):
        self.parent = parent_name
        values = [v for _, v in pairs]
        self.marginal = CategoricalModel(values)
        groups: Dict[Any, List[Any]] = {}
        for pv, v in pairs:
            groups.setdefault(pv, []).append(v)
        self.cond: Dict[Any, CategoricalModel] = {}
        if len(groups) <= max_groups:
            for pv, vs in groups.items():
                if len(vs) >= min_group:
                    self.cond[pv] = CategoricalModel(vs)

    def _model(self, ctx) -> CategoricalModel:
        pv = ctx.get(self.parent) if ctx else None
        return self.cond.get(pv, self.marginal)

    def encode_value(
        self, v: Any, enc: Any, ctx: Optional[Dict[str, Any]] = None
    ) -> None:
        self._model(ctx).encode_value(v, enc)

    def decode_value(
        self, dec: Any, ctx: Optional[Dict[str, Any]] = None
    ) -> Any:
        return self._model(ctx).decode_value(dec)

    def est_bits(self, v) -> float:
        return self.marginal.est_bits(v)

    def model_bytes(self) -> int:
        return (self.marginal.model_bytes() +
                sum(m.model_bytes() for m in self.cond.values()))


# ---------------------------------------------------------------------------
# Time-series model (App. E.2): AR(1) residual wrapper
# ---------------------------------------------------------------------------

class TimeSeriesModel:
    """AR(1)-residual numeric model (ARMA family; archive mode only).

    Compresses residuals ``r_t = v_t - (c + phi * v_{t-1})`` which are more
    symmetric/less heavy-tailed than raw values (App. E.2, Table 3).  Breaks
    random access (needs the previous row), matching the paper's caveat.
    """

    def __init__(
        self, values: Sequence[float], precision: float = 1.0, T: int = 512
    ) -> None:
        v = np.asarray(values, dtype=np.float64)
        if v.size < 3:
            v = np.zeros(3)
        x, y = v[:-1], v[1:]
        vx = float(np.var(x))
        self.phi = float(np.cov(x, y, bias=True)[0, 1] / vx) if vx > 0 else 0.0
        self.c = float(y.mean() - self.phi * x.mean())
        resid = y - (self.c + self.phi * x)
        self.first = NumericModel(v[:1], precision=precision, T=T)
        self.resid = NumericModel(resid, precision=precision, T=T)
        self._prev: Optional[float] = None

    def reset_block(self) -> None:
        self._prev = None

    def encode_value(self, v: float, enc: BlockEncoder, ctx=None) -> None:
        # _prev tracks the *decoder's* reconstruction to avoid drift
        if self._prev is None:
            self.first.encode_value(v, enc)
            self._prev = float(self.first.roundtrip(v))
        else:
            r = float(v) - (self.c + self.phi * self._prev)
            self.resid.encode_value(r, enc)
            self._prev = self.c + self.phi * self._prev + float(self.resid.roundtrip(r))

    def decode_value(self, dec: BlockDecoder, ctx=None) -> float:
        if self._prev is None:
            v = self.first.decode_value(dec)
        else:
            r = self.resid.decode_value(dec)
            v = self.c + self.phi * self._prev + r
        self._prev = float(v)
        return float(v)

    def model_bytes(self) -> int:
        return self.first.model_bytes() + self.resid.model_bytes() + 16
