"""Blitzcrank core: semantic models + delayed coding (the paper's contribution).

Public API:
  * coders:      DiscreteCoder, UniformCoder, quantize_freqs
  * delayed:     encode_block / decode_block / BlockDecoder / Slot
  * vectorized:  encode_batch / decode_batch / decode_select / CondSlot
  * models:      CategoricalModel, NumericModel, StringModel, ...
  * blitzcrank:  ColumnSpec, TableCodec, CompressedTable
  * plan:        compile_plan / TablePlan (the batched fast path, DESIGN.md §2)
  * baselines:   arithmetic, rans, huffman
"""

from .coders import DiscreteCoder, UniformCoder, quantize_freqs, TOTAL
from .delayed import (
    BlockDecoder, Slot, decode_block, encode_block, encode_symbols, LAMBDA_DEFAULT
)
from .vectorized import CondSlot, decode_batch, decode_select, encode_batch
from .models import (
    BlockEncoder,
    ByteMarkov,
    CategoricalModel,
    ConditionalCategoricalModel,
    NumericModel,
    StringModel,
    TimeSeriesModel,
)
from .arena import DiskArena, ResidencyConfig, ResidencyManager
from .blitzcrank import (
    ColumnSpec, CompressedTable, FitStats, TableCodec, fit_column_model
)
from .plan import PlanFallback, TablePlan, compile_plan
from .structure import learn_order

__all__ = [
    "DiscreteCoder", "UniformCoder", "quantize_freqs", "TOTAL",
    "BlockDecoder", "Slot", "decode_block", "encode_block", "encode_symbols",
    "LAMBDA_DEFAULT", "CondSlot", "decode_batch", "decode_select",
    "encode_batch", "BlockEncoder", "ByteMarkov", "CategoricalModel",
    "ConditionalCategoricalModel", "NumericModel", "StringModel",
    "TimeSeriesModel", "ColumnSpec", "CompressedTable", "FitStats",
    "TableCodec", "fit_column_model", "PlanFallback", "TablePlan",
    "compile_plan", "learn_order", "DiskArena", "ResidencyConfig",
    "ResidencyManager",
]
