"""Blitzcrank core: semantic models + delayed coding (the paper's contribution).

Public API:
  * coders:      DiscreteCoder, UniformCoder, quantize_freqs
  * delayed:     encode_block / decode_block / BlockDecoder / Slot
  * vectorized:  encode_batch / decode_batch / decode_select
  * models:      CategoricalModel, NumericModel, StringModel, ...
  * blitzcrank:  ColumnSpec, TableCodec, CompressedTable
  * baselines:   arithmetic, rans, huffman
"""

from .coders import DiscreteCoder, UniformCoder, quantize_freqs, TOTAL
from .delayed import (BlockDecoder, Slot, decode_block, encode_block,
                      encode_symbols, LAMBDA_DEFAULT)
from .vectorized import decode_batch, decode_select, encode_batch
from .models import (BlockEncoder, ByteMarkov, CategoricalModel,
                     ConditionalCategoricalModel, NumericModel, StringModel,
                     TimeSeriesModel)
from .blitzcrank import ColumnSpec, CompressedTable, FitStats, TableCodec
from .structure import learn_order

__all__ = [
    "DiscreteCoder", "UniformCoder", "quantize_freqs", "TOTAL",
    "BlockDecoder", "Slot", "decode_block", "encode_block", "encode_symbols",
    "LAMBDA_DEFAULT", "decode_batch", "decode_select", "encode_batch",
    "BlockEncoder", "ByteMarkov", "CategoricalModel",
    "ConditionalCategoricalModel", "NumericModel", "StringModel",
    "TimeSeriesModel", "ColumnSpec", "CompressedTable", "FitStats",
    "TableCodec", "learn_order",
]
