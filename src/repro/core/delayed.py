"""Delayed coding (§5, Algorithms 4 & 5): fixed-length near-entropy coding.

Every slot (interval) is encoded with a full 16-bit code, but an interval of
length ``k`` has ``k`` admissible codes, and the *choice among them* is a
mixed-radix digit that carries the codes of later, "marked" (virtual) slots.

Encode = two passes over a block of slots:
  1. *Marking* (Alg. 4 step 1): a slot is virtual iff the option counter has
     reached ``lam`` (default 2**16) — its 16-bit code will be stored in the
     option choices of the preceding slots, then the counter gives back 16
     bits of capacity.
  2. *Filling* (Alg. 4 step 2): walk slots from the end, peeling mixed-radix
     digits ``a = data % k`` off the pending virtual payload and emitting
     ``code_for(sym, a)``; virtual slots push their code into ``data`` instead
     of the physical stream.

Decode (Alg. 5) is a single forward pass: fetch a 16-bit code (from the
stream, or from the virtual accumulator when ``V_size`` crossed ``lam``),
O(1)-inv-translate it, and fold its option digit back into ``V_info``.

This module is the *reference* (tuple-at-a-time, exact Python ints).
``repro.core.vectorized`` holds the batched numpy codec and
``repro.kernels.delayed_decode`` the Pallas TPU kernel; both are verified
against this implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

from .coders import TOTAL, TOTAL_BITS

LAMBDA_DEFAULT = TOTAL  # random-access mode (§5.7); archive mode uses larger


@dataclasses.dataclass
class Slot:
    """One interval to encode: option count ``k`` and the symbol's code map.

    ``code_for(a)`` must return the ``a``-th admissible 16-bit code of the
    symbol (0 <= a < k); non-continuous option sets (§5.6) are handled by the
    coder's own option-index mapping.
    """

    k: int
    code_for: Callable[[int], int]


def encode_block(slots: Sequence[Slot], lam: int = LAMBDA_DEFAULT) -> List[int]:
    """Encode one block of slots into a list of 16-bit codes (Algorithm 4)."""
    if lam < TOTAL:
        raise ValueError("lambda must be >= 2**16 (Theorem 2)")
    s = len(slots)
    # ---- step 1: mark -------------------------------------------------
    virtual = [False] * s
    size = 1
    for i, sl in enumerate(slots):
        if size >= lam:
            virtual[i] = True
            size >>= TOTAL_BITS
        if not (1 <= sl.k <= TOTAL):
            raise ValueError(f"slot {i}: bad option count {sl.k}")
        size *= sl.k
    # ---- step 2: fill from the end ------------------------------------
    data = 0
    out_rev: List[int] = []
    for i in range(s - 1, -1, -1):
        k = slots[i].k
        a = data % k
        data //= k
        c = slots[i].code_for(a)
        assert 0 <= c < TOTAL
        if virtual[i]:
            data = (data << TOTAL_BITS) + c
        else:
            out_rev.append(c)
    assert data == 0, "virtual payload not fully consumed (uniqueness, App. D)"
    return out_rev[::-1]


class BlockDecoder:
    """Streaming decoder for one block (Algorithm 5).

    The caller drives it coder-by-coder because slot coders can depend on
    previously decoded symbols (composite models, structure learning):

        dec = BlockDecoder(codes)
        sym = dec.next_symbol(coder)   # repeatedly, with the right coder
    """

    __slots__ = ("codes", "pos", "v_info", "v_size", "pending", "lam")

    def __init__(
        self, codes: Sequence[int], lam: int = LAMBDA_DEFAULT
    ) -> None:
        self.codes = codes
        self.pos = 0
        self.v_info = 0
        self.v_size = 1
        self.pending = -1  # next virtual code, if any
        self.lam = lam

    def next_symbol(self, coder) -> int:
        if self.pending >= 0:
            code = self.pending
            self.pending = -1
        else:
            code = self.codes[self.pos]
            self.pos += 1
        sym, a, k = coder.inv_translate(code)
        self.v_info = self.v_info * k + a
        self.v_size = self.v_size * k
        if self.v_size >= self.lam:
            self.pending = self.v_info & (TOTAL - 1)
            self.v_info >>= TOTAL_BITS
            self.v_size >>= TOTAL_BITS
        return sym

    def codes_consumed(self) -> int:
        return self.pos


def decode_block(
    codes: Sequence[int], coders: Sequence, lam: int = LAMBDA_DEFAULT
) -> Tuple[List[int], int]:
    """Decode a fixed, known sequence of slot coders. Returns (symbols, used)."""
    dec = BlockDecoder(codes, lam)
    syms = [dec.next_symbol(c) for c in coders]
    return syms, dec.codes_consumed()


def encode_symbols(
    syms: Sequence[int], coders: Sequence, lam: int = LAMBDA_DEFAULT
) -> List[int]:
    """Convenience: encode a symbol per coder (fixed-slot blocks)."""
    slots = [Slot(k=c.k(sym),
                  code_for=(lambda a, c=c, sym=sym: c.code_for(sym, a)))
             for sym, c in zip(syms, coders)]
    return encode_block(slots, lam)


def wasted_bits(slots_k: Sequence[int], lam: int = LAMBDA_DEFAULT) -> float:
    """Bits wasted by a block = log2 of the final option counter (§5.7)."""
    import math
    size = 1
    for k in slots_k:
        if size >= lam:
            size >>= TOTAL_BITS
        size *= k
    return math.log2(size)
