"""Batched delayed coding over fixed-slot schemas (numpy, exact).

The paper decodes one tuple at a time on a CPU; the TPU-native restructuring
(DESIGN.md §2) observes that the virtual-bits chain is sequential only
*within* a tuple and vectorizes *across* tuples.  This module is the host-side
(numpy) version of that layout and the oracle for the Pallas kernels:

* every tuple has the same ``S`` slots (a fixed tabular schema);
* slot ``s`` of all tuples is coded by the same coder (Discrete/Uniform);
* the compressed store is a ragged CSR pair ``(codes uint16[], offsets[N+1])``.

All arithmetic is uint64 and exact; invariants (counter < 2**32) are the
paper's (§5.1) and are asserted here.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Sequence, Tuple

import numpy as np

from .casts import checked_astype
from .coders import TOTAL, TOTAL_BITS, DiscreteCoder, UniformCoder
from .delayed import LAMBDA_DEFAULT

_U64 = np.uint64
_MASK16 = _U64(TOTAL - 1)
_SH16 = _U64(TOTAL_BITS)


class CondSlot:
    """A slot whose coder depends on symbols decoded at *earlier* slots.

    This is how conditional models (structure learning, §2.2/§3) enter the
    fixed-slot batch layout: the slot position is static, but the coder is
    selected per tuple by the symbols at ``chain_slots`` (the ancestor
    categorical slots, root first).  Selection packs the chain symbols into a
    mixed-radix key and groups the batch by key, so each group runs the
    ordinary vectorized coder kernels.  Keys absent from ``by_key`` (unseen
    parent combinations) fall back to ``default`` — the marginal coder, the
    same fallback the scalar model uses.
    """

    __slots__ = ("chain_slots", "bases", "by_key", "default")

    def __init__(
        self,
        chain_slots: Sequence[int],
        bases: Sequence[int],
        by_key: Dict[int, Any],
        default: Any,
    ) -> None:
        assert len(chain_slots) == len(bases)
        self.chain_slots = tuple(int(s) for s in chain_slots)
        self.bases = tuple(int(b) for b in bases)
        self.by_key = dict(by_key)
        self.default = default

    def packed_key(self, syms: np.ndarray) -> np.ndarray:
        key = np.zeros(syms.shape[0], dtype=np.int64)
        for s, b in zip(self.chain_slots, self.bases):
            key = key * b + syms[:, s]
        return key

    def groups(self, syms: np.ndarray) -> Iterator[Tuple[np.ndarray, Any]]:
        """Yield ``(mask, coder)`` partitioning the batch by chain key."""
        key = self.packed_key(syms)
        for kk in np.unique(key):
            yield key == kk, self.by_key.get(int(kk), self.default)


def _inv_translate_batch(
    coder: Any, codes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """inv_translate with the O(1) LUT (Fig 11 "decoding map") when built."""
    if isinstance(coder, DiscreteCoder) and coder._lut_sym is not None:
        return coder._lut_sym[codes], coder._lut_a[codes], coder._lut_k[codes]
    return coder.inv_translate_batch(codes)


def _k_of_batch(coder, syms: np.ndarray) -> np.ndarray:
    if isinstance(coder, UniformCoder):
        j = syms.astype(np.int64)
        lo = -((-j * TOTAL) // coder.G)
        hi = -((-(j + 1) * TOTAL) // coder.G)
        return (hi - lo).astype(np.int64)
    if isinstance(coder, DiscreteCoder):
        return coder.tables.k_of[syms].astype(np.int64)
    return np.array([coder.k(int(s)) for s in syms], dtype=np.int64)


def encode_batch(
    syms: np.ndarray, coders: Sequence, lam: int = LAMBDA_DEFAULT
) -> Tuple[np.ndarray, np.ndarray]:
    """Encode ``syms[N, S]`` -> (codes uint16 flat, offsets int64[N+1]).

    Vectorized Algorithm 4 across the N tuples.
    """
    syms = np.asarray(syms)
    N, S = syms.shape
    assert len(coders) == S
    lam64 = _U64(lam)

    # k[t, s]: option count of the chosen symbol in slot s.
    k = np.empty((N, S), dtype=np.int64)
    for s, c in enumerate(coders):
        if isinstance(c, CondSlot):
            for mask, sub in c.groups(syms):
                k[mask, s] = _k_of_batch(sub, syms[mask, s])
        else:
            k[:, s] = _k_of_batch(c, syms[:, s])

    # ---- step 1: mark (forward) ---------------------------------------
    virt = np.zeros((N, S), dtype=bool)
    size = np.ones(N, dtype=_U64)
    for s in range(S):
        hit = size >= lam64
        virt[:, s] = hit
        size = np.where(hit, size >> _SH16, size)
        size = size * k[:, s].astype(_U64)
    # invariant (§5.1): counter < 2**32 always
    assert (size < _U64(1) << _U64(32)).all()

    # ---- step 2: fill (backward) --------------------------------------
    data = np.zeros(N, dtype=_U64)
    codes_buf = np.zeros((N, S), dtype=np.uint16)
    for s in range(S - 1, -1, -1):
        ks = k[:, s].astype(_U64)
        a = data % ks
        data = data // ks
        a_i = a.astype(np.int64)
        if isinstance(coders[s], CondSlot):
            c = np.empty(N, dtype=np.int64)
            for mask, sub in coders[s].groups(syms):
                c[mask] = sub.code_for_batch(syms[mask, s], a_i[mask])
            c = c.astype(_U64)
        else:
            c = coders[s].code_for_batch(syms[:, s], a_i).astype(_U64)
        v = virt[:, s]
        data = np.where(v, (data << _SH16) + c, data)
        codes_buf[:, s] = checked_astype(c, np.uint16, where="encode_batch slot")
    assert (data == 0).all(), "virtual payload not consumed (App. D uniqueness)"

    phys = ~virt
    counts = phys.sum(axis=1)
    offsets = np.zeros(N + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    codes = codes_buf[phys]  # row-major -> slot-ascending per tuple
    return codes, offsets


def decode_batch(
    codes: np.ndarray,
    offsets: np.ndarray,
    coders: Sequence,
    n_tuples: int | None = None,
    lam: int = LAMBDA_DEFAULT,
) -> np.ndarray:
    """Decode the CSR store back to ``syms[N, S]`` (vectorized Algorithm 5)."""
    # All decode arithmetic is int64: the §5.1 invariant keeps the virtual
    # counters < 2**32 and every product < 2**48, so int64 is exact and we
    # avoid the per-slot uint64 casts on the hot path.
    codes_i = np.asarray(codes).astype(np.int64)
    offsets = np.asarray(offsets, dtype=np.int64)
    N = (offsets.size - 1) if n_tuples is None else n_tuples
    S = len(coders)

    syms = np.empty((N, S), dtype=np.int64)
    cursor = offsets[:N].copy()
    last = max(codes_i.size - 1, 0)
    v_info = np.zeros(N, dtype=np.int64)
    v_size = np.ones(N, dtype=np.int64)
    pending = np.zeros(N, dtype=bool)
    pend_code = np.zeros(N, dtype=np.int64)
    for s in range(S):
        stream_code = codes_i[np.minimum(cursor, last)]
        code = np.where(pending, pend_code, stream_code)
        cursor = cursor + (~pending)
        if isinstance(coders[s], CondSlot):
            # chain slots are all < s, hence already decoded into ``syms``
            sym = np.empty(N, dtype=np.int64)
            a = np.empty(N, dtype=np.int64)
            k = np.empty(N, dtype=np.int64)
            for mask, sub in coders[s].groups(syms):
                sy, aa, kk = _inv_translate_batch(sub, code[mask])
                sym[mask], a[mask], k[mask] = sy, aa, kk
        else:
            sym, a, k = _inv_translate_batch(coders[s], code)
        syms[:, s] = sym
        v_info = v_info * k + a
        v_size = v_size * k
        pending = v_size >= lam
        pend_code = v_info & (TOTAL - 1)
        v_info = np.where(pending, v_info >> TOTAL_BITS, v_info)
        v_size = np.where(pending, v_size >> TOTAL_BITS, v_size)
    if n_tuples is None:
        assert (cursor == offsets[1:]).all(), "stream misalignment"
    return syms


def decode_select(
    codes: np.ndarray,
    offsets: np.ndarray,
    coders: Sequence,
    rows: np.ndarray,
    lam: int = LAMBDA_DEFAULT,
) -> np.ndarray:
    """Random-access decode of a subset of tuples (the paper's point query).

    Gathers each selected tuple's code run (lengths vary, padded to the max)
    and runs the batched decoder on the gathered block.
    """
    rows = np.asarray(rows, dtype=np.int64)
    starts = offsets[rows]
    lens = offsets[rows + 1] - starts
    L = int(lens.max()) if rows.size else 0
    idx = starts[:, None] + np.arange(L)[None, :]
    idx = np.minimum(idx, codes.size - 1)
    block = codes[idx]  # [R, L]
    flat = block.reshape(-1)
    offs = np.arange(rows.size + 1, dtype=np.int64) * L
    return decode_batch(flat, offs, coders, n_tuples=rows.size, lam=lam)
