"""Batched delayed coding over fixed-slot schemas (numpy, exact).

The paper decodes one tuple at a time on a CPU; the TPU-native restructuring
(DESIGN.md §2) observes that the virtual-bits chain is sequential only
*within* a tuple and vectorizes *across* tuples.  This module is the host-side
(numpy) version of that layout and the oracle for the Pallas kernels:

* every tuple has the same ``S`` slots (a fixed tabular schema);
* slot ``s`` of all tuples is coded by the same coder (Discrete/Uniform);
* the compressed store is a ragged CSR pair ``(codes uint16[], offsets[N+1])``.

All arithmetic is uint64 and exact; invariants (counter < 2**32) are the
paper's (§5.1) and are asserted here.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .coders import TOTAL, TOTAL_BITS, DiscreteCoder, UniformCoder
from .delayed import LAMBDA_DEFAULT

_U64 = np.uint64
_MASK16 = _U64(TOTAL - 1)
_SH16 = _U64(TOTAL_BITS)


def _k_of_batch(coder, syms: np.ndarray) -> np.ndarray:
    if isinstance(coder, UniformCoder):
        j = syms.astype(np.int64)
        lo = -((-j * TOTAL) // coder.G)
        hi = -((-(j + 1) * TOTAL) // coder.G)
        return (hi - lo).astype(np.int64)
    if isinstance(coder, DiscreteCoder):
        return coder.tables.k_of[syms].astype(np.int64)
    return np.array([coder.k(int(s)) for s in syms], dtype=np.int64)


def encode_batch(syms: np.ndarray, coders: Sequence,
                 lam: int = LAMBDA_DEFAULT) -> Tuple[np.ndarray, np.ndarray]:
    """Encode ``syms[N, S]`` -> (codes uint16 flat, offsets int64[N+1]).

    Vectorized Algorithm 4 across the N tuples.
    """
    syms = np.asarray(syms)
    N, S = syms.shape
    assert len(coders) == S
    lam64 = _U64(lam)

    # k[t, s]: option count of the chosen symbol in slot s.
    k = np.empty((N, S), dtype=np.int64)
    for s, c in enumerate(coders):
        k[:, s] = _k_of_batch(c, syms[:, s])

    # ---- step 1: mark (forward) ---------------------------------------
    virt = np.zeros((N, S), dtype=bool)
    size = np.ones(N, dtype=_U64)
    for s in range(S):
        hit = size >= lam64
        virt[:, s] = hit
        size = np.where(hit, size >> _SH16, size)
        size = size * k[:, s].astype(_U64)
    # invariant (§5.1): counter < 2**32 always
    assert (size < _U64(1) << _U64(32)).all()

    # ---- step 2: fill (backward) --------------------------------------
    data = np.zeros(N, dtype=_U64)
    codes_buf = np.zeros((N, S), dtype=np.uint16)
    for s in range(S - 1, -1, -1):
        ks = k[:, s].astype(_U64)
        a = data % ks
        data = data // ks
        c = coders[s].code_for_batch(syms[:, s], a.astype(np.int64)).astype(_U64)
        v = virt[:, s]
        data = np.where(v, (data << _SH16) + c, data)
        codes_buf[:, s] = c.astype(np.uint16)
    assert (data == 0).all(), "virtual payload not consumed (App. D uniqueness)"

    phys = ~virt
    counts = phys.sum(axis=1)
    offsets = np.zeros(N + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    codes = codes_buf[phys]  # row-major -> slot-ascending per tuple
    return codes, offsets


def decode_batch(codes: np.ndarray, offsets: np.ndarray, coders: Sequence,
                 n_tuples: int | None = None, lam: int = LAMBDA_DEFAULT
                 ) -> np.ndarray:
    """Decode the CSR store back to ``syms[N, S]`` (vectorized Algorithm 5)."""
    codes = np.asarray(codes, dtype=np.uint16)
    offsets = np.asarray(offsets, dtype=np.int64)
    N = (offsets.size - 1) if n_tuples is None else n_tuples
    S = len(coders)
    lam64 = _U64(lam)

    syms = np.empty((N, S), dtype=np.int64)
    cursor = offsets[:N].copy()
    v_info = np.zeros(N, dtype=_U64)
    v_size = np.ones(N, dtype=_U64)
    pending = np.zeros(N, dtype=bool)
    pend_code = np.zeros(N, dtype=_U64)
    for s in range(S):
        stream_code = codes[np.minimum(cursor, codes.size - 1)].astype(_U64)
        code = np.where(pending, pend_code, stream_code)
        cursor = cursor + (~pending)
        sym, a, k = coders[s].inv_translate_batch(code.astype(np.int64))
        syms[:, s] = sym
        v_info = v_info * k.astype(_U64) + a.astype(_U64)
        v_size = v_size * k.astype(_U64)
        pending = v_size >= lam64
        pend_code = v_info & _MASK16
        v_info = np.where(pending, v_info >> _SH16, v_info)
        v_size = np.where(pending, v_size >> _SH16, v_size)
    if n_tuples is None:
        assert (cursor == offsets[1:]).all(), "stream misalignment"
    return syms


def decode_select(codes: np.ndarray, offsets: np.ndarray, coders: Sequence,
                  rows: np.ndarray, lam: int = LAMBDA_DEFAULT) -> np.ndarray:
    """Random-access decode of a subset of tuples (the paper's point query).

    Gathers each selected tuple's code run (lengths vary, padded to the max)
    and runs the batched decoder on the gathered block.
    """
    rows = np.asarray(rows, dtype=np.int64)
    starts = offsets[rows]
    lens = offsets[rows + 1] - starts
    L = int(lens.max()) if rows.size else 0
    idx = starts[:, None] + np.arange(L)[None, :]
    idx = np.minimum(idx, codes.size - 1)
    block = codes[idx]  # [R, L]
    flat = block.reshape(-1)
    offs = np.arange(rows.size + 1, dtype=np.int64) * L
    return decode_batch(flat, offs, coders, n_tuples=rows.size, lam=lam)
