"""Checkpointing: atomic, async, reshard-on-restore, optionally compressed.

Layout per step::

    <dir>/step_00001234.tmp/...   (written, fsynced)
    <dir>/step_00001234/          (atomic rename = commit)
        manifest.json             tree structure + shapes + dtypes
        arrays/<leaf-id>.npy      raw leaves,   or
        arrays/<leaf-id>.blz      Blitzcrank-compressed leaves (archive mode)

Restore targets *any* mesh: leaves are loaded on host and ``device_put``
with the target shardings — this is the elastic-rescale path (a 512-chip
checkpoint restores onto 256 chips and vice versa).  Optimizer moments
(f32, smooth) compress well under the two-level model; ``compress="blz"``
routes eligible leaves through it (lossless16 for bf16, |e| <= p/2 with
p = 1e-7·std for f32 moments — documented loss, off by default).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_n: int = 3
    async_save: bool = True
    compress: Optional[str] = None      # None | 'blz'

    def __post_init__(self):
        self.dir = pathlib.Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # fetch before async
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
            self._thread = None
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, treedef, extra)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _write(self, step: int, leaves: List[np.ndarray], treedef,
               extra: Optional[Dict]) -> None:
        try:
            final = self.dir / f"step_{step:08d}"
            tmp = self.dir / f"step_{step:08d}.tmp"
            if tmp.exists():
                shutil.rmtree(tmp)
            (tmp / "arrays").mkdir(parents=True)
            manifest = {
                "step": step,
                "treedef": jax.tree_util.tree_structure(
                    jax.tree_util.tree_unflatten(
                        treedef, list(range(len(leaves))))).__repr__(),
                "extra": extra or {},
                "leaves": [],
                "format_version": 1,
            }
            import pickle
            with open(tmp / "treedef.pkl", "wb") as f:
                pickle.dump(treedef, f)
            for i, arr in enumerate(leaves):
                rec = {"id": i, "shape": list(arr.shape),
                       "dtype": str(arr.dtype), "codec": "npy"}
                if (
                    self.compress == "blz"
                    and arr.size >= 4096
                    and arr.dtype
                    in (np.float32, np.dtype("bfloat16"), np.float16)
                ):
                    rec["codec"] = "blz"
                    self._write_blz(tmp / "arrays" / f"{i}.blz", arr, rec)
                else:
                    save_arr = arr
                    if arr.dtype.kind not in "fiub c":
                        # ml_dtypes (bfloat16, fp8) -> store raw bits
                        save_arr = arr.view(
                            {2: np.uint16, 1: np.uint8}[arr.dtype.itemsize])
                        rec["bitcast"] = str(arr.dtype)
                    np.save(tmp / "arrays" / f"{i}.npy", save_arr,
                            allow_pickle=False)
                manifest["leaves"].append(rec)
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic commit
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._last_error = e

    def _write_blz(self, path: pathlib.Path, arr: np.ndarray, rec: Dict):
        from repro.tensor.codec import fit_codec
        import pickle
        a = arr
        if a.dtype == np.dtype("bfloat16"):
            a16 = a.view(np.uint16)
            codec = fit_codec(a16, "lossless16")
            ct = codec.encode(a16)
            rec["view"] = "bfloat16"
        elif a.dtype == np.float16:
            codec = fit_codec(a.view(np.uint16), "lossless16")
            ct = codec.encode(a.view(np.uint16))
            rec["view"] = "float16"
        else:
            p = max(float(np.std(a)), 1e-12) * 1e-7
            codec = fit_codec(a, "twolevel", precision=p)
            ct = codec.encode(a)
            rec["view"] = "float32"
        with open(path, "wb") as f:
            pickle.dump({"codec": codec, "ct": ct}, f)

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            if not (p / "manifest.json").exists():
                continue  # uncommitted
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any, Dict]:
        """Returns (step, tree, extra).  ``shardings``: optional pytree of
        NamedShardings for the *current* mesh (elastic restore)."""
        import pickle
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with open(d / "treedef.pkl", "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for rec in manifest["leaves"]:
            i = rec["id"]
            if rec["codec"] == "blz":
                with open(d / "arrays" / f"{i}.blz", "rb") as f:
                    blob = pickle.load(f)
                arr = blob["codec"].decode(blob["ct"])
                if rec.get("view") in ("bfloat16", "float16"):
                    arr = arr.view(np.dtype(rec["view"]))
            else:
                arr = np.load(d / "arrays" / f"{i}.npy")
                if "bitcast" in rec:
                    import ml_dtypes  # noqa: F401  (registers np dtypes)
                    arr = arr.view(np.dtype(rec["bitcast"]))
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree, manifest["extra"]
