"""Trainer: the end-to-end driver (mesh + steps + data + FT + checkpoints).

Composes every substrate: jitted train step with full shardings
(launch.steps), deterministic data (data.pipeline), atomic/async
checkpoints with reshard-on-restore (train.checkpoint), watchdog +
preemption + restart supervision (train.fault_tolerance), and optional
cross-pod gradient compression (tensor.grad_compress).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs import SHAPES_BY_NAME, get_config
from repro.data.pipeline import SyntheticLM
from repro.dist import partitioning as parts
from repro.dist.sharding import use_rules
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeConfig
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import PreemptionGuard, StepWatchdog


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "phi4-mini-3.8b"
    shape: str = "train_4k"
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    watchdog_s: float = 0.0          # 0 = disabled
    layout: str = "tp"
    compress_ckpt: bool = False


class Trainer:
    def __init__(self, tc: TrainerConfig, mesh,
                 cfg: Optional[ModelConfig] = None,
                 shape: Optional[ShapeConfig] = None,
                 data: Optional[Iterator[Dict[str, np.ndarray]]] = None,
                 opt_cfg: Optional[opt_lib.OptimizerConfig] = None):
        self.tc = tc
        self.mesh = mesh
        self.cfg = cfg or get_config(tc.arch)
        self.shape = shape or SHAPES_BY_NAME[tc.shape]
        self.opt_cfg = opt_cfg or opt_lib.OptimizerConfig(
            total_steps=tc.steps)
        self.rules = steps_lib.rules_for(mesh, self.shape, tc.layout)
        self._data = data
        self.ckpt = (
            CheckpointManager(
                tc.ckpt_dir, compress="blz" if tc.compress_ckpt else None
            )
            if tc.ckpt_dir
            else None
        )
        self.guard = PreemptionGuard(install=False)
        self.watchdog = StepWatchdog(tc.watchdog_s) if tc.watchdog_s else None
        self.metrics_log: list = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self) -> None:
        cfg, shape, rules = self.cfg, self.shape, self.rules
        p_shape = steps_lib.abstract_params(cfg)
        self.p_shard = parts.param_shardings(rules, p_shape)
        o_shape = steps_lib.abstract_opt_state(p_shape)
        rep = parts.replicated(rules)
        self.o_shard = opt_lib.OptState(
            step=rep, m=parts.param_shardings(rules, o_shape.m),
            v=parts.param_shardings(rules, o_shape.v))
        batch_abs = steps_lib.input_specs(cfg, shape)
        self.b_shard = parts.batch_shardings(rules, batch_abs)
        fn = steps_lib.make_train_step(cfg, self.opt_cfg)
        metric_keys = {"loss": 0, "xent": 0, "aux": 0, "tokens": 0,
                       "grad_norm": 0, "lr": 0}
        with use_rules(rules):
            self.step_fn = jax.jit(
                fn, in_shardings=(self.p_shard, self.o_shard, self.b_shard),
                out_shardings=(self.p_shard, self.o_shard,
                               jax.tree.map(lambda _: rep, metric_keys)),
                donate_argnums=(0, 1))

    def init_state(self):
        with self.mesh, use_rules(self.rules):
            params = jax.jit(
                lambda k: tfm.init_params(self.cfg, k),
                out_shardings=self.p_shard)(jax.random.PRNGKey(self.tc.seed))
            opt_state = jax.jit(
                opt_lib.init, out_shardings=self.o_shard)(params)
        return params, opt_state

    def data_iter(self, start_step: int):
        if self._data is not None:
            return self._data
        return SyntheticLM(self.cfg.vocab, self.shape.seq_len,
                           self.shape.global_batch,
                           seed=self.tc.seed).batches(start_step)

    # ------------------------------------------------------------------
    def run(self, resume: bool = True,
            fail_at_step: Optional[int] = None) -> Dict[str, Any]:
        """Train; returns summary.  ``fail_at_step`` injects a crash (tests)."""
        start = 0
        params = opt_state = None
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            start, tree, extra = self.ckpt.restore(
                shardings={"params": self.p_shard,
                           "opt": self.o_shard._asdict()})
            params = tree["params"]
            opt_state = opt_lib.OptState(**tree["opt"])
        if params is None:
            params, opt_state = self.init_state()

        it = self.data_iter(start)
        t0 = time.time()
        last = {}
        for step in range(start, self.tc.steps):
            if self.guard.stop_requested:
                break
            batch = next(it)
            batch = {k: jax.device_put(v, s) for (k, v), s in
                     zip(batch.items(), jax.tree.leaves(self.b_shard))}
            if self.watchdog:
                self.watchdog.arm(step)
            with self.mesh, use_rules(self.rules):
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
            if self.watchdog:
                self.watchdog.disarm()
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            if (step + 1) % self.tc.log_every == 0 or step == start:
                last = {k: float(v) for k, v in metrics.items()}
                self.metrics_log.append({"step": step + 1, **last})
            if self.ckpt and (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step + 1, {
                    "params": params, "opt": opt_state._asdict()})
        if self.ckpt:
            self.ckpt.save(self.tc.steps, {
                "params": params, "opt": opt_state._asdict()}, block=True)
            self.ckpt.wait()
        return {"final_metrics": last, "steps_done": self.tc.steps - start,
                "wall_s": time.time() - t0, "params": params,
                "opt_state": opt_state}
