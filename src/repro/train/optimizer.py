"""AdamW with global-norm clipping and warmup-cosine schedule (hand-rolled,
pytree-native, sharding-transparent: moments inherit parameter shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: OptimizerConfig, params: Any, grads: Any, state: OptState
          ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
