"""Fault tolerance: step watchdog, preemption hooks, restart supervision.

Designed for 1000+-node posture (DESIGN.md §5): every mechanism is a
host-side policy around the jitted step, so it works identically on CPU
smoke tests and real pods.

* :class:`StepWatchdog` — arms a deadline per step; if a step stalls
  (straggler/hang) the callback fires (default: record + raise on the next
  poll so the supervisor restarts from the last checkpoint).
* :class:`PreemptionGuard` — SIGTERM/SIGINT handler that requests a
  graceful stop; the train loop checkpoints and exits cleanly.
* :func:`run_with_restarts` — supervisor: runs the training callable,
  catching failures and restarting from the latest checkpoint up to
  ``max_restarts`` times (simulating scheduler-level retries in-tests).
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, List, Optional


class StepWatchdog:
    def __init__(self, deadline_s: float,
                 on_stall: Optional[Callable[[int, float], None]] = None):
        self.deadline_s = deadline_s
        self.on_stall = on_stall
        self.stalls: List[int] = []
        self._timer: Optional[threading.Timer] = None
        self._step = -1
        self._lock = threading.Lock()

    def arm(self, step: int) -> None:
        with self._lock:
            self._cancel()
            self._step = step
            self._timer = threading.Timer(self.deadline_s, self._fire)
            self._timer.daemon = True
            self._timer.start()

    def disarm(self) -> None:
        with self._lock:
            self._cancel()

    def _cancel(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def _fire(self):
        self.stalls.append(self._step)
        if self.on_stall:
            self.on_stall(self._step, self.deadline_s)

    @property
    def stalled(self) -> bool:
        return bool(self.stalls)


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative stop request."""

    def __init__(self, install: bool = True):
        self.stop_requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.stop_requested = True

    def request_stop(self) -> None:  # also triggerable programmatically
        self.stop_requested = True

    def uninstall(self) -> None:
        for sig, h in self._prev.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed: bool
    errors: List[str]


def run_with_restarts(fn: Callable[[int], bool], max_restarts: int = 3,
                      backoff_s: float = 0.0) -> RestartReport:
    """Run ``fn(attempt) -> completed`` with restart-on-exception.

    ``fn`` must be resumable (restore from the latest checkpoint on entry) —
    the contract every node-failure recovery path relies on.
    """
    errors: List[str] = []
    for attempt in range(max_restarts + 1):
        try:
            if fn(attempt):
                return RestartReport(restarts=attempt, completed=True,
                                     errors=errors)
        except Exception as e:  # noqa: BLE001 - supervisor catches all
            errors.append(f"{type(e).__name__}: {e}")
            if backoff_s:
                time.sleep(backoff_s)
            continue
    return RestartReport(restarts=max_restarts, completed=False,
                         errors=errors)
