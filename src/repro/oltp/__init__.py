"""OLTP layer: the batched-first RowStore protocol over pluggable
compressors, the TPC-C-style data generators and single-store transaction
mix (DESIGN.md §3), and the full multi-table TPC-C over the `repro.db`
engine (DESIGN.md §5).

Public API:
  * store: RowStore, BlitzStore, ZstdStore, RamanStore, UncompressedStore,
           LRUFastPath, STORE_KINDS
  * tpcc (single-table shims): TABLES, gen_customer/gen_stock/gen_orderline,
           customer_row, zipf_keys, batched_point_gets, run_transaction_mix,
           row_bytes
  * tpcc (multi-table engine): TPCC_TABLES, generate_tpcc,
           build_tpcc_database, run_tpcc_mix, database_row_bytes
"""

from .store import (
    STORE_KINDS,
    BlitzStore,
    LRUFastPath,
    RamanStore,
    RowStore,
    UncompressedStore,
    ZstdStore,
)
from .tpcc import (
    TABLES,
    TPCC_TABLES,
    batched_point_gets,
    build_tpcc_database,
    customer_row,
    database_row_bytes,
    drifting_customer_row,
    gen_customer,
    gen_orderline,
    gen_stock,
    generate_tpcc,
    row_bytes,
    run_tpcc_mix,
    run_transaction_mix,
    zipf_keys,
)

__all__ = [
    "RowStore", "BlitzStore", "ZstdStore", "RamanStore",
    "UncompressedStore", "LRUFastPath", "STORE_KINDS",
    "TABLES", "gen_customer", "gen_stock", "gen_orderline", "customer_row",
    "drifting_customer_row", "zipf_keys", "batched_point_gets",
    "run_transaction_mix", "row_bytes",
    "TPCC_TABLES", "generate_tpcc", "build_tpcc_database", "run_tpcc_mix",
    "database_row_bytes",
]
