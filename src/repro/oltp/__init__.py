"""OLTP layer: the batched-first RowStore protocol over pluggable
compressors, plus the TPC-C-style data generators and transaction mix
(DESIGN.md §3).

Public API:
  * store: RowStore, BlitzStore, ZstdStore, RamanStore, UncompressedStore,
           LRUFastPath, STORE_KINDS
  * tpcc:  TABLES, gen_customer/gen_stock/gen_orderline, customer_row,
           zipf_keys, batched_point_gets, run_transaction_mix, row_bytes
"""

from .store import (STORE_KINDS, BlitzStore, LRUFastPath, RamanStore,
                    RowStore, UncompressedStore, ZstdStore)
from .tpcc import (TABLES, batched_point_gets, customer_row,
                   drifting_customer_row, gen_customer, gen_orderline,
                   gen_stock, row_bytes, run_transaction_mix, zipf_keys)

__all__ = [
    "RowStore", "BlitzStore", "ZstdStore", "RamanStore",
    "UncompressedStore", "LRUFastPath", "STORE_KINDS",
    "TABLES", "gen_customer", "gen_stock", "gen_orderline", "customer_row",
    "drifting_customer_row", "zipf_keys", "batched_point_gets",
    "run_transaction_mix", "row_bytes",
]
