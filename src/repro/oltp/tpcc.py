"""TPC-C-like data generation (paper §7, Table 2).

The paper replaces TPC-C's incompressible random bytes with realistic
columns: sampled names/streets, state->city->zip conditional hierarchies,
and format-based phone/district strings.  We synthesize equivalent corpora
offline (no network): Zipf-sampled name/street lexicons, a state/city/zip
hierarchy, and the exact format strings from Table 2.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import ColumnSpec

_FIRST = ["Taylor", "Alex", "Jordan", "Morgan", "Riley", "Casey", "Avery",
          "Quinn", "Hayden", "Rowan", "Emerson", "Skyler", "Dakota", "Reese",
          "Finley", "Sawyer", "Charlie", "Emery", "Tatum", "Ellis", "Mary",
          "James", "Patricia", "Robert", "Jennifer", "Michael", "Linda",
          "David", "Elizabeth", "William", "Barbara", "Richard", "Susan",
          "Joseph", "Jessica", "Thomas", "Sarah", "Daniel", "Karen", "Lisa"]
_STREET_NAME = ["Main", "Oak", "Pine", "Maple", "Cedar", "Elm", "Washington",
                "Lake", "Hill", "Walnut", "Spring", "North", "Ridge",
                "Church", "Willow", "Mill", "Sunset", "Railroad", "Jackson",
                "River"]
_STREET_KIND = ["St", "Ave", "Blvd", "Dr", "Ln", "Rd", "Way", "Ct"]
_STATES = ["CA", "TX", "NY", "FL", "IL", "PA", "OH", "GA", "NC", "MI"]
# real-world hierarchy: city names are state-specific, zips city-specific
_CITIES: Dict[str, List[str]] = {
    st: [f"{name}{'ville' if i % 3 == 0 else (' City' if i % 3 == 1 else ' Falls')}"
         f" {st}"
         for i, name in enumerate(_STREET_NAME[si % 7:si % 7 + 4 + si % 4])]
    for si, st in enumerate(_STATES)
}
_CORP = ["Acme Corp", "Globex LLC", "Initech Inc", "Umbrella Co",
         "Stark Industries", "Wayne Enterprises", "Hooli", "Vandelay Industries",
         "Wonka Factory", "Cyberdyne Systems", "Tyrell Corp", "Soylent Corp"]


def _zipf_choice(rng, items, size, a=1.3):
    r = rng.zipf(a, size=size)
    return [items[int(x - 1) % len(items)] for x in r]


CUSTOMER_SCHEMA = [
    ColumnSpec("c_id", "int"),
    ColumnSpec("c_first", "cat"),
    ColumnSpec("c_street", "str"),
    ColumnSpec("c_state", "cat"),
    ColumnSpec("c_city", "cat"),
    ColumnSpec("c_zip", "cat"),
    ColumnSpec("c_phone", "str"),
    ColumnSpec("c_credit_lim", "float", precision=0.01),
    ColumnSpec("c_balance", "float", precision=0.01),
    ColumnSpec("c_discount", "float", precision=0.0001),
    ColumnSpec("c_data", "str"),
]

STOCK_SCHEMA = [
    ColumnSpec("s_i_id", "int"),
    ColumnSpec("s_quantity", "int"),
    ColumnSpec("s_ytd", "int"),
    ColumnSpec("s_order_cnt", "int"),
    ColumnSpec("s_remote_cnt", "int"),
    ColumnSpec("s_dist_01", "str"),
    ColumnSpec("s_dist_02", "str"),
    ColumnSpec("s_data", "str"),
]

ORDERLINE_SCHEMA = [
    ColumnSpec("ol_o_id", "int"),
    ColumnSpec("ol_number", "int"),
    ColumnSpec("ol_i_id", "int"),
    ColumnSpec("ol_quantity", "int"),
    ColumnSpec("ol_amount", "float", precision=0.01),
    ColumnSpec("ol_dist_info", "str"),
]


def _zip_for(rng, state: str, city: str) -> str:
    # ~8 zip codes per city (ZIP-within-city conditional, Table 2)
    h = sum(ord(c) * (i + 7) for i, c in enumerate(state + city))
    base = (h % 8000) + int(rng.integers(0, 8))
    return f"{10000 + base:05d}"


def customer_row(rng, i: int, first: str | None = None) -> Dict:
    """One customer tuple (Table 2 formats) — the NewOrder insert factory.

    ``first`` lets :func:`gen_customer` supply its pre-drawn Zipf name
    without consuming an extra draw, keeping seeded streams reproducible.
    """
    st = _STATES[int(rng.zipf(1.5)) % len(_STATES)]
    city = _CITIES[st][int(rng.integers(0, len(_CITIES[st])))]
    return {
        "c_id": i,
        "c_first": (first if first is not None
                    else _FIRST[int(rng.zipf(1.3)) % len(_FIRST)]),
        "c_street": f"{int(rng.integers(1, 999))} "
                    f"{_STREET_NAME[int(rng.zipf(1.4)) % len(_STREET_NAME)]} "
                    f"{_STREET_KIND[int(rng.integers(0, len(_STREET_KIND)))]}",
        "c_state": st,
        "c_city": city,
        "c_zip": _zip_for(rng, st, city),
        "c_phone": f"({rng.integers(200, 999)}) {rng.integers(200, 999)}-"
                   f"{rng.integers(0, 9999):04d}",
        "c_credit_lim": float(rng.choice([50000.0, 10000.0, 25000.0])),
        "c_balance": float(np.round(rng.normal(-10.0, 2000.0), 2)),
        "c_discount": float(np.round(rng.uniform(0, 0.5), 4)),
        "c_data": f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} customer "
                  f"since {int(rng.integers(1990, 2024))}",
    }


def gen_customer(n: int, seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    firsts = _zipf_choice(rng, _FIRST, n)
    return [customer_row(rng, i, first=firsts[i]) for i in range(n)]


# -- workload drift (§5 dynamic value sets; DESIGN.md §4) -------------------
# A second generation of values disjoint from the load-time lexicons: names
# and employers the fitted models have never seen, city names (and therefore
# zips) outside the trained hierarchy, and a widening balance distribution.
_DRIFT_FIRST = ["Zephyr", "Onyx", "Juniper", "Caspian", "Marisol", "Thaddeus",
                "Isolde", "Evander", "Seraphina", "Lysander", "Ottilie",
                "Peregrine", "Anouk", "Balthazar", "Clementine", "Dashiell",
                "Eulalia", "Fitzgerald", "Guinevere", "Hyacinth", "Ignatius",
                "Jessamine", "Kingsley", "Lavinia", "Montgomery", "Novalie",
                "Octavian", "Persimmon", "Quillon", "Rosalind"]
_DRIFT_CITIES: Dict[str, List[str]] = {
    st: [f"New {name} Heights {st}" for name in _STREET_NAME[si % 5:si % 5 + 3]]
    for si, st in enumerate(_STATES)
}
_DRIFT_CORP = ["Nimbus Dynamics", "Quasar Holdings", "Vertex Biotech",
               "Aurora Freight", "Helios Mining", "Zenith Robotics",
               "Meridian Foods", "Polaris Media"]


def drifting_customer_row(rng, i: int, progress: float = 0.0) -> Dict:
    """NewOrder factory under workload drift (paper §5 dynamic value sets).

    ``progress`` in [0, 1] is how far the drift has advanced: with that
    probability each of the drifting columns draws from a second-generation
    value set the load-time models never saw (new first names, new
    city/zip pairs, new employers in ``c_data``), and the balance
    distribution widens by up to 10x — so late-run inserts escape the
    fitted plan on several columns at once unless the models are refit.
    At ``progress == 0`` this is exactly :func:`customer_row`.
    """
    row = customer_row(rng, i)
    p = min(1.0, max(0.0, float(progress)))
    if p <= 0.0:
        return row
    if rng.random() < p:
        row["c_first"] = _DRIFT_FIRST[int(rng.zipf(1.3)) % len(_DRIFT_FIRST)]
    if rng.random() < p:
        st = row["c_state"]
        city = _DRIFT_CITIES[st][int(rng.integers(0, len(_DRIFT_CITIES[st])))]
        row["c_city"] = city
        row["c_zip"] = _zip_for(rng, st, city)
    if rng.random() < p:
        row["c_data"] = (f"{_DRIFT_CORP[int(rng.zipf(1.3)) % len(_DRIFT_CORP)]}"
                         f" customer since {int(rng.integers(2024, 2030))}")
    # widening range: the spread grows up to 10x as the drift advances
    row["c_balance"] = float(np.round(
        rng.normal(-10.0, 2000.0 * (1.0 + 9.0 * p)), 2))
    return row


def gen_stock(n: int, seed: int = 1) -> List[Dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        rows.append({
            "s_i_id": i,
            "s_quantity": int(rng.integers(10, 100)),
            "s_ytd": int(rng.poisson(50)),
            "s_order_cnt": int(rng.poisson(20)),
            "s_remote_cnt": int(rng.poisson(2)),
            "s_dist_01": f"dist-str#{rng.integers(0,99):02d}#"
                         f"{rng.integers(0,99):02d}#{rng.integers(0,9999):04d}",
            "s_dist_02": f"dist-str#{rng.integers(0,99):02d}#"
                         f"{rng.integers(0,99):02d}#{rng.integers(0,9999):04d}",
            "s_data": f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} item grade "
                      f"{chr(65 + int(rng.integers(0, 6)))}",
        })
    return rows


def gen_orderline(n: int, seed: int = 2) -> List[Dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        rows.append({
            "ol_o_id": i // 10,
            "ol_number": i % 10,
            "ol_i_id": int(rng.zipf(1.2)) % 100000,
            "ol_quantity": int(rng.integers(1, 10)),
            "ol_amount": float(np.round(rng.uniform(0.01, 9999.99), 2)),
            "ol_dist_info": f"dist-str#{rng.integers(0,99):02d}#"
                            f"{rng.integers(0,99):02d}#"
                            f"{rng.integers(0,9999):04d}",
        })
    return rows


TABLES = {
    "customer": (CUSTOMER_SCHEMA, gen_customer),
    "stock": (STOCK_SCHEMA, gen_stock),
    "orderline": (ORDERLINE_SCHEMA, gen_orderline),
}


def zipf_keys(rng, n_rows: int, n_ops: int, a: float = 1.1) -> np.ndarray:
    """YCSB-C style Zipfian point-read key stream over ``[0, n_rows)``."""
    keys = rng.zipf(a, size=4 * n_ops) - 1
    keys = keys[keys < n_rows][:n_ops].astype(np.int64)
    while keys.size < n_ops:  # extremely skewed draws can come up short
        more = rng.zipf(a, size=4 * n_ops) - 1
        keys = np.concatenate([keys, more[more < n_rows]])[:n_ops]
    return keys.astype(np.int64)


def batched_point_gets(store, keys, batch: int = 256) -> List[Dict]:
    """Drive point gets through the store's batch API in fixed-size chunks.

    Stores exposing ``get_many`` (BlitzStore / CompressedTable) decode each
    chunk with one vectorized ``decode_select`` call; others fall back to
    scalar gets.  This is the read path the TPC-C style harness and the
    compression benchmarks time.
    """
    out: List[Dict] = []
    if hasattr(store, "get_many"):
        keys = list(keys)
        for lo in range(0, len(keys), batch):
            out.extend(store.get_many(keys[lo:lo + batch]))
    else:
        out = [store.get(int(k)) for k in keys]
    return out


def run_transaction_mix(store, n_ops: int, *, seed: int = 0, batch: int = 64,
                        zipf_a: float = 1.1,
                        p_payment: float = 0.5, p_order_status: float = 0.35,
                        p_new_order: float = 0.10, p_delivery: float = 0.05,
                        balance_col: str = "c_balance",
                        amount: float = 100.0,
                        new_row_fn=None, drift: float = 0.0,
                        sample_every: int = 0, on_sample=None) -> Dict:
    """Drive a TPC-C-style transaction mix through the RowStore protocol.

    Four transaction shapes over Zipfian keys (paper §7 dynamic traffic):

    * *Payment* — batched read-modify-write: ``get_many`` the keys, walk the
      balance column by ±``amount``, write back with one ``update_many``;
    * *OrderStatus* — batched point reads (``get_many`` only);
    * *NewOrder* — ``insert_many`` of fresh tuples from ``new_row_fn(rng, i)``
      (skipped, redistributed to reads, when no factory is given);
    * *Delivery* — ``delete_many`` of a few old keys (tombstones).

    ``drift > 0`` turns on workload drift (paper §5 dynamic value sets):
    NewOrder calls ``new_row_fn(rng, i, progress)`` with
    ``progress = drift · ops_done/n_ops`` (use a progress-aware factory such
    as :func:`drifting_customer_row`), and the Payment walk amplitude grows
    with progress so balances wander out of the fitted range — together they
    put real escape pressure on the fitted models as the run advances.

    Keys hitting tombstoned rows are skipped, as a real transaction would
    abort.  ``on_sample(ops_done)`` is invoked every ``sample_every`` ops —
    the hook the bytes-over-time benchmark charts.  Returns op counts.
    """
    rng = np.random.default_rng(seed)
    if new_row_fn is None:
        p_order_status += p_new_order
        p_new_order = 0.0
    counts = {"ops": 0, "payments": 0, "reads": 0, "inserts": 0,
              "deletes": 0, "aborts": 0}
    next_sample = sample_every
    while counts["ops"] < n_ops:
        k = min(batch, n_ops - counts["ops"])
        span = len(store)
        progress = drift * counts["ops"] / n_ops if drift else 0.0
        u = float(rng.random())
        if u < p_payment:
            keys = zipf_keys(rng, span, k, zipf_a)
            rows = store.get_many(keys)
            upd_i: List[int] = []
            upd_r: List[Dict] = []
            seen = set()
            amt = amount * (1.0 + 9.0 * progress)
            for key, r in zip(keys.tolist(), rows):
                if r is None:  # tombstoned: the transaction aborts
                    counts["aborts"] += 1
                    continue
                if key in seen:  # batch touches each row once
                    continue
                seen.add(key)
                r[balance_col] = round(
                    float(r[balance_col])
                    + float(rng.uniform(-amt, amt)), 2)
                upd_i.append(key)
                upd_r.append(r)
            store.update_many(upd_i, upd_r)
            counts["payments"] += len(upd_i)
        elif u < p_payment + p_order_status:
            keys = zipf_keys(rng, span, k, zipf_a)
            got = store.get_many(keys)
            counts["aborts"] += sum(r is None for r in got)
            counts["reads"] += k
        elif u < p_payment + p_order_status + p_new_order:
            if drift:
                rows = [new_row_fn(rng, span + j, progress) for j in range(k)]
            else:
                rows = [new_row_fn(rng, span + j) for j in range(k)]
            store.insert_many(rows)
            counts["inserts"] += k
        else:
            # Delivery drains uniformly (old orders), not the Zipfian head —
            # deleting hot keys would abort most of the later traffic.
            keys = rng.integers(0, span, max(1, k // 8))
            counts["deletes"] += store.delete_many(keys)
        counts["ops"] += k
        if sample_every and on_sample is not None \
                and counts["ops"] >= next_sample:
            on_sample(counts["ops"])
            next_sample += sample_every
    return counts


def row_bytes(rows: List[Dict]) -> int:
    """Uncompressed size: fixed-width numerics + string bytes (Silo-style)."""
    total = 0
    for r in rows:
        for v in r.values():
            if isinstance(v, str):
                total += len(v.encode()) + 1
            elif isinstance(v, float):
                total += 8
            else:
                total += 8
    return total
