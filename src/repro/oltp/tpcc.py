"""TPC-C data generation and transaction mixes (paper §6/§7, Table 2).

The paper replaces TPC-C's incompressible random bytes with realistic
columns: sampled names/streets, state->city->zip conditional hierarchies,
and format-based phone/district strings.  We synthesize equivalent corpora
offline (no network): Zipf-sampled name/street lexicons, a state/city/zip
hierarchy, and the exact format strings from Table 2.

Two layers live here:

* the original single-table entry points (``TABLES``, ``gen_customer``,
  ``run_transaction_mix`` over one :class:`~repro.oltp.store.RowStore`) —
  kept as-is so the existing benches and tests keep running; and
* the full multi-table TPC-C over the ``repro.db`` engine (DESIGN.md §5):
  seven :class:`~repro.db.TableSchema` s (warehouse, district, customer,
  item, stock, orders, order_line) with composite primary keys,
  :func:`generate_tpcc` population, :func:`build_tpcc_database`, and the
  cross-table :func:`run_tpcc_mix` (NewOrder touches item/stock/orders/
  order_line; Payment touches warehouse/district/customer) — the §6-shaped
  workload ``benchmarks/bench_db_tpcc.py`` measures.
"""

from __future__ import annotations

from types import MappingProxyType
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import ColumnSpec
from repro.db.schema import TableSchema

_FIRST = (
    "Taylor",
    "Alex",
    "Jordan",
    "Morgan",
    "Riley",
    "Casey",
    "Avery",
    "Quinn",
    "Hayden",
    "Rowan",
    "Emerson",
    "Skyler",
    "Dakota",
    "Reese",
    "Finley",
    "Sawyer",
    "Charlie",
    "Emery",
    "Tatum",
    "Ellis",
    "Mary",
    "James",
    "Patricia",
    "Robert",
    "Jennifer",
    "Michael",
    "Linda",
    "David",
    "Elizabeth",
    "William",
    "Barbara",
    "Richard",
    "Susan",
    "Joseph",
    "Jessica",
    "Thomas",
    "Sarah",
    "Daniel",
    "Karen",
    "Lisa",
)
_STREET_NAME = (
    "Main",
    "Oak",
    "Pine",
    "Maple",
    "Cedar",
    "Elm",
    "Washington",
    "Lake",
    "Hill",
    "Walnut",
    "Spring",
    "North",
    "Ridge",
    "Church",
    "Willow",
    "Mill",
    "Sunset",
    "Railroad",
    "Jackson",
    "River",
)
_STREET_KIND = ("St", "Ave", "Blvd", "Dr", "Ln", "Rd", "Way", "Ct")
_STATES = ("CA", "TX", "NY", "FL", "IL", "PA", "OH", "GA", "NC", "MI")
# real-world hierarchy: city names are state-specific, zips city-specific
_CITIES: Mapping[str, Tuple[str, ...]] = MappingProxyType({
    st: tuple(
        f"{name}{'ville' if i % 3 == 0 else (' City' if i % 3 == 1 else ' Falls')}"
        f" {st}"
        for i, name in enumerate(_STREET_NAME[si % 7:si % 7 + 4 + si % 4])
    )
    for si, st in enumerate(_STATES)
})
_CORP = (
    "Acme Corp",
    "Globex LLC",
    "Initech Inc",
    "Umbrella Co",
    "Stark Industries",
    "Wayne Enterprises",
    "Hooli",
    "Vandelay Industries",
    "Wonka Factory",
    "Cyberdyne Systems",
    "Tyrell Corp",
    "Soylent Corp",
)


def _zipf_choice(
    rng: np.random.Generator, items: Sequence[Any], size: int, a: float = 1.3
) -> List[Any]:
    r = rng.zipf(a, size=size)
    return [items[int(x - 1) % len(items)] for x in r]


CUSTOMER_SCHEMA = (
    ColumnSpec("c_id", "int"),
    ColumnSpec("c_first", "cat"),
    ColumnSpec("c_street", "str"),
    ColumnSpec("c_state", "cat"),
    ColumnSpec("c_city", "cat"),
    ColumnSpec("c_zip", "cat"),
    ColumnSpec("c_phone", "str"),
    ColumnSpec("c_credit_lim", "float", precision=0.01),
    ColumnSpec("c_balance", "float", precision=0.01),
    ColumnSpec("c_discount", "float", precision=0.0001),
    ColumnSpec("c_data", "str"),
)

STOCK_SCHEMA = (
    ColumnSpec("s_i_id", "int"),
    ColumnSpec("s_quantity", "int"),
    ColumnSpec("s_ytd", "int"),
    ColumnSpec("s_order_cnt", "int"),
    ColumnSpec("s_remote_cnt", "int"),
    ColumnSpec("s_dist_01", "str"),
    ColumnSpec("s_dist_02", "str"),
    ColumnSpec("s_data", "str"),
)

ORDERLINE_SCHEMA = (
    ColumnSpec("ol_o_id", "int"),
    ColumnSpec("ol_number", "int"),
    ColumnSpec("ol_i_id", "int"),
    ColumnSpec("ol_quantity", "int"),
    ColumnSpec("ol_amount", "float", precision=0.01),
    ColumnSpec("ol_dist_info", "str"),
)


def _zip_for(rng, state: str, city: str) -> str:
    # ~8 zip codes per city (ZIP-within-city conditional, Table 2)
    h = sum(ord(c) * (i + 7) for i, c in enumerate(state + city))
    base = (h % 8000) + int(rng.integers(0, 8))
    return f"{10000 + base:05d}"


def customer_row(rng, i: int, first: str | None = None) -> Dict:
    """One customer tuple (Table 2 formats) — the NewOrder insert factory.

    ``first`` lets :func:`gen_customer` supply its pre-drawn Zipf name
    without consuming an extra draw, keeping seeded streams reproducible.
    """
    st = _STATES[int(rng.zipf(1.5)) % len(_STATES)]
    city = _CITIES[st][int(rng.integers(0, len(_CITIES[st])))]
    return {
        "c_id": i,
        "c_first": (first if first is not None
                    else _FIRST[int(rng.zipf(1.3)) % len(_FIRST)]),
        "c_street": f"{int(rng.integers(1, 999))} "
                    f"{_STREET_NAME[int(rng.zipf(1.4)) % len(_STREET_NAME)]} "
                    f"{_STREET_KIND[int(rng.integers(0, len(_STREET_KIND)))]}",
        "c_state": st,
        "c_city": city,
        "c_zip": _zip_for(rng, st, city),
        "c_phone": f"({rng.integers(200, 999)}) {rng.integers(200, 999)}-"
                   f"{rng.integers(0, 9999):04d}",
        "c_credit_lim": float(rng.choice([50000.0, 10000.0, 25000.0])),
        "c_balance": float(np.round(rng.normal(-10.0, 2000.0), 2)),
        "c_discount": float(np.round(rng.uniform(0, 0.5), 4)),
        "c_data": f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} customer "
                  f"since {int(rng.integers(1990, 2024))}",
    }


def gen_customer(n: int, seed: int = 0) -> List[Dict]:
    rng = np.random.default_rng(seed)
    firsts = _zipf_choice(rng, _FIRST, n)
    return [customer_row(rng, i, first=firsts[i]) for i in range(n)]


# -- workload drift (§5 dynamic value sets; DESIGN.md §4) -------------------
# A second generation of values disjoint from the load-time lexicons: names
# and employers the fitted models have never seen, city names (and therefore
# zips) outside the trained hierarchy, and a widening balance distribution.
_DRIFT_FIRST = ("Zephyr", "Onyx", "Juniper", "Caspian", "Marisol", "Thaddeus",
                "Isolde", "Evander", "Seraphina", "Lysander", "Ottilie",
                "Peregrine", "Anouk", "Balthazar", "Clementine", "Dashiell",
                "Eulalia", "Fitzgerald", "Guinevere", "Hyacinth", "Ignatius",
                "Jessamine", "Kingsley", "Lavinia", "Montgomery", "Novalie",
                "Octavian", "Persimmon", "Quillon", "Rosalind")
_DRIFT_CITIES: Mapping[str, Tuple[str, ...]] = MappingProxyType({
    st: tuple(
        f"New {name} Heights {st}" for name in _STREET_NAME[si % 5:si % 5 + 3]
    )
    for si, st in enumerate(_STATES)
})
_DRIFT_CORP = (
    "Nimbus Dynamics",
    "Quasar Holdings",
    "Vertex Biotech",
    "Aurora Freight",
    "Helios Mining",
    "Zenith Robotics",
    "Meridian Foods",
    "Polaris Media",
)


def drifting_customer_row(rng, i: int, progress: float = 0.0) -> Dict:
    """NewOrder factory under workload drift (paper §5 dynamic value sets).

    ``progress`` in [0, 1] is how far the drift has advanced: with that
    probability each of the drifting columns draws from a second-generation
    value set the load-time models never saw (new first names, new
    city/zip pairs, new employers in ``c_data``), and the balance
    distribution widens by up to 10x — so late-run inserts escape the
    fitted plan on several columns at once unless the models are refit.
    At ``progress == 0`` this is exactly :func:`customer_row`.
    """
    row = customer_row(rng, i)
    p = min(1.0, max(0.0, float(progress)))
    if p <= 0.0:
        return row
    if rng.random() < p:
        row["c_first"] = _DRIFT_FIRST[int(rng.zipf(1.3)) % len(_DRIFT_FIRST)]
    if rng.random() < p:
        st = row["c_state"]
        city = _DRIFT_CITIES[st][int(rng.integers(0, len(_DRIFT_CITIES[st])))]
        row["c_city"] = city
        row["c_zip"] = _zip_for(rng, st, city)
    if rng.random() < p:
        row["c_data"] = (f"{_DRIFT_CORP[int(rng.zipf(1.3)) % len(_DRIFT_CORP)]}"
                         f" customer since {int(rng.integers(2024, 2030))}")
    # widening range: the spread grows up to 10x as the drift advances
    row["c_balance"] = float(np.round(
        rng.normal(-10.0, 2000.0 * (1.0 + 9.0 * p)), 2))
    return row


def gen_stock(n: int, seed: int = 1) -> List[Dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        rows.append({
            "s_i_id": i,
            "s_quantity": int(rng.integers(10, 100)),
            "s_ytd": int(rng.poisson(50)),
            "s_order_cnt": int(rng.poisson(20)),
            "s_remote_cnt": int(rng.poisson(2)),
            "s_dist_01": f"dist-str#{rng.integers(0,99):02d}#"
                         f"{rng.integers(0,99):02d}#{rng.integers(0,9999):04d}",
            "s_dist_02": f"dist-str#{rng.integers(0,99):02d}#"
                         f"{rng.integers(0,99):02d}#{rng.integers(0,9999):04d}",
            "s_data": f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} item grade "
                      f"{chr(65 + int(rng.integers(0, 6)))}",
        })
    return rows


def gen_orderline(n: int, seed: int = 2) -> List[Dict]:
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        rows.append({
            "ol_o_id": i // 10,
            "ol_number": i % 10,
            "ol_i_id": int(rng.zipf(1.2)) % 100000,
            "ol_quantity": int(rng.integers(1, 10)),
            "ol_amount": float(np.round(rng.uniform(0.01, 9999.99), 2)),
            "ol_dist_info": f"dist-str#{rng.integers(0,99):02d}#"
                            f"{rng.integers(0,99):02d}#"
                            f"{rng.integers(0,9999):04d}",
        })
    return rows


TABLES = MappingProxyType({
    "customer": (CUSTOMER_SCHEMA, gen_customer),
    "stock": (STOCK_SCHEMA, gen_stock),
    "orderline": (ORDERLINE_SCHEMA, gen_orderline),
})


def zipf_keys(rng, n_rows: int, n_ops: int, a: float = 1.1) -> np.ndarray:
    """YCSB-C style Zipfian point-read key stream over ``[0, n_rows)``."""
    keys = rng.zipf(a, size=4 * n_ops) - 1
    keys = keys[keys < n_rows][:n_ops].astype(np.int64)
    while keys.size < n_ops:  # extremely skewed draws can come up short
        more = rng.zipf(a, size=4 * n_ops) - 1
        keys = np.concatenate([keys, more[more < n_rows]])[:n_ops]
    return keys.astype(np.int64)


def batched_point_gets(store, keys, batch: int = 256) -> List[Dict]:
    """Drive point gets through the store's batch API in fixed-size chunks.

    Stores exposing ``get_many`` (BlitzStore / CompressedTable) decode each
    chunk with one vectorized ``decode_select`` call; others fall back to
    scalar gets.  This is the read path the TPC-C style harness and the
    compression benchmarks time.
    """
    out: List[Dict] = []
    if hasattr(store, "get_many"):
        keys = list(keys)
        for lo in range(0, len(keys), batch):
            out.extend(store.get_many(keys[lo:lo + batch]))
    else:
        out = [store.get(int(k)) for k in keys]
    return out


def run_transaction_mix(
    store,
    n_ops: int,
    *,
    seed: int = 0,
    batch: int = 64,
    zipf_a: float = 1.1,
    p_payment: float = 0.5,
    p_order_status: float = 0.35,
    p_new_order: float = 0.10,
    p_delivery: float = 0.05,
    balance_col: str = "c_balance",
    amount: float = 100.0,
    new_row_fn=None,
    drift: float = 0.0,
    sample_every: int = 0,
    on_sample=None,
) -> Dict:
    """Drive a TPC-C-style transaction mix through the RowStore protocol.

    Four transaction shapes over Zipfian keys (paper §7 dynamic traffic):

    * *Payment* — batched read-modify-write: ``get_many`` the keys, walk the
      balance column by ±``amount``, write back with one ``update_many``;
    * *OrderStatus* — batched point reads (``get_many`` only);
    * *NewOrder* — ``insert_many`` of fresh tuples from ``new_row_fn(rng, i)``
      (skipped, redistributed to reads, when no factory is given);
    * *Delivery* — ``delete_many`` of a few old keys (tombstones).

    ``drift > 0`` turns on workload drift (paper §5 dynamic value sets):
    NewOrder calls ``new_row_fn(rng, i, progress)`` with
    ``progress = drift · ops_done/n_ops`` (use a progress-aware factory such
    as :func:`drifting_customer_row`), and the Payment walk amplitude grows
    with progress so balances wander out of the fitted range — together they
    put real escape pressure on the fitted models as the run advances.

    Keys hitting tombstoned rows are skipped, as a real transaction would
    abort.  ``on_sample(ops_done)`` is invoked every ``sample_every`` ops —
    the hook the bytes-over-time benchmark charts.  Returns op counts.
    """
    rng = np.random.default_rng(seed)
    if new_row_fn is None:
        p_order_status += p_new_order
        p_new_order = 0.0
    counts = {
        "ops": 0, "payments": 0, "reads": 0, "inserts": 0, "deletes": 0, "aborts": 0
    }
    next_sample = sample_every
    while counts["ops"] < n_ops:
        k = min(batch, n_ops - counts["ops"])
        span = len(store)
        progress = drift * counts["ops"] / n_ops if drift else 0.0
        u = float(rng.random())
        if u < p_payment:
            keys = zipf_keys(rng, span, k, zipf_a)
            rows = store.get_many(keys)
            upd_i: List[int] = []
            upd_r: List[Dict] = []
            seen = set()
            amt = amount * (1.0 + 9.0 * progress)
            for key, r in zip(keys.tolist(), rows):
                if r is None:  # tombstoned: the transaction aborts
                    counts["aborts"] += 1
                    continue
                if key in seen:  # batch touches each row once
                    continue
                seen.add(key)
                r[balance_col] = round(
                    float(r[balance_col]) + float(rng.uniform(-amt, amt)), 2
                )
                upd_i.append(key)
                upd_r.append(r)
            store.update_many(upd_i, upd_r)
            counts["payments"] += len(upd_i)
        elif u < p_payment + p_order_status:
            keys = zipf_keys(rng, span, k, zipf_a)
            got = store.get_many(keys)
            counts["aborts"] += sum(r is None for r in got)
            counts["reads"] += k
        elif u < p_payment + p_order_status + p_new_order:
            if drift:
                rows = [new_row_fn(rng, span + j, progress) for j in range(k)]
            else:
                rows = [new_row_fn(rng, span + j) for j in range(k)]
            store.insert_many(rows)
            counts["inserts"] += k
        else:
            # Delivery drains uniformly (old orders), not the Zipfian head —
            # deleting hot keys would abort most of the later traffic.
            keys = rng.integers(0, span, max(1, k // 8))
            counts["deletes"] += store.delete_many(keys)
        counts["ops"] += k
        if sample_every and on_sample is not None and counts["ops"] >= next_sample:
            on_sample(counts["ops"])
            next_sample += sample_every
    return counts


def row_bytes(rows: List[Dict]) -> int:
    """Uncompressed size: fixed-width numerics + string bytes (Silo-style)."""
    total = 0
    for r in rows:
        for v in r.values():
            if isinstance(v, str):
                total += len(v.encode()) + 1
            elif isinstance(v, float):
                total += 8
            else:
                total += 8
    return total


# ======================================================================
# Full multi-table TPC-C over the `repro.db` engine (DESIGN.md §5)
# ======================================================================
#
# Scaled-down but structurally faithful: composite primary keys route
# rows to hash-partitioned shards, NewOrder crosses item/stock/district/
# orders/order_line, Payment crosses warehouse/district/customer.  The
# single-table schemas above remain the deprecation-shim path.

_ITEM_ADJ = ("Small", "Large", "Deluxe", "Rustic", "Sleek", "Durable",
             "Gorgeous", "Practical", "Refined", "Ergonomic", "Compact")
_ITEM_NOUN = (
    "Widget",
    "Gadget",
    "Bracket",
    "Fitting",
    "Sprocket",
    "Gear",
    "Lamp",
    "Chair",
    "Table",
    "Clock",
    "Knob",
    "Panel",
    "Valve",
)
_ITEM_MAT = (
    "Steel",
    "Wooden",
    "Granite",
    "Cotton",
    "Rubber",
    "Copper",
    "Bronze",
    "Marble",
    "Plastic",
    "Linen",
)

# growth=: headroom for append-mostly columns (ColumnSpec.growth) — minted
# order ids, advancing dates and accumulating ytd counters must keep
# conforming as the mix runs past the load-time value sets, instead of
# escaping on every NewOrder (the §5 dynamic-value-set failure mode).
WAREHOUSE_SCHEMA = (
    ColumnSpec("w_id", "int"),
    ColumnSpec("w_name", "cat"),
    ColumnSpec("w_street", "str"),
    ColumnSpec("w_state", "cat"),
    ColumnSpec("w_city", "cat"),
    ColumnSpec("w_zip", "cat"),
    ColumnSpec("w_tax", "float", precision=0.0001),
    ColumnSpec("w_ytd", "float", precision=0.01, growth=2.0),
)

DISTRICT_SCHEMA = (
    ColumnSpec("d_w_id", "int"),
    ColumnSpec("d_id", "int"),
    ColumnSpec("d_name", "cat"),
    ColumnSpec("d_street", "str"),
    ColumnSpec("d_state", "cat"),
    ColumnSpec("d_city", "cat"),
    ColumnSpec("d_zip", "cat"),
    ColumnSpec("d_tax", "float", precision=0.0001),
    ColumnSpec("d_ytd", "float", precision=0.01, growth=2.0),
    ColumnSpec("d_next_o_id", "int", growth=8.0),
)

CUSTOMER_DB_SCHEMA = ((ColumnSpec("c_w_id", "int"),
                       ColumnSpec("c_d_id", "int"))
                      + tuple(ColumnSpec("c_balance", "float", precision=0.01,
                                         growth=2.0)
                              if c.name == "c_balance" else c
                              for c in CUSTOMER_SCHEMA))

ITEM_SCHEMA = (
    ColumnSpec("i_id", "int"),
    ColumnSpec("i_im_id", "int"),
    ColumnSpec("i_name", "str"),
    ColumnSpec("i_price", "float", precision=0.01),
    ColumnSpec("i_data", "str"),
)

STOCK_DB_SCHEMA = ((ColumnSpec("s_w_id", "int"),)
                   + tuple(ColumnSpec(c.name, c.kind, growth=4.0)
                           if c.name in ("s_quantity", "s_ytd", "s_order_cnt")
                           else c
                           for c in STOCK_SCHEMA))

ORDERS_SCHEMA = (
    ColumnSpec("o_w_id", "int"),
    ColumnSpec("o_d_id", "int"),
    ColumnSpec("o_id", "int", growth=8.0),
    ColumnSpec("o_c_id", "int"),
    ColumnSpec("o_entry_d", "int", growth=0.01),   # epoch day
    ColumnSpec("o_carrier_id", "int"),             # 0 = undelivered
    ColumnSpec("o_ol_cnt", "int"),
    ColumnSpec("o_all_local", "int"),
)

ORDER_LINE_SCHEMA = (
    ColumnSpec("ol_w_id", "int"),
    ColumnSpec("ol_d_id", "int"),
    ColumnSpec("ol_o_id", "int", growth=8.0),
    ColumnSpec("ol_number", "int"),
    ColumnSpec("ol_i_id", "int"),
    ColumnSpec("ol_supply_w_id", "int"),
    ColumnSpec("ol_delivery_d", "int", growth=0.01),  # 0 = undelivered
    ColumnSpec("ol_quantity", "int"),
    ColumnSpec("ol_amount", "float", precision=0.01),
    ColumnSpec("ol_dist_info", "str"),
)

TPCC_TABLES: Mapping[str, TableSchema] = MappingProxyType({
    "warehouse": TableSchema("warehouse", WAREHOUSE_SCHEMA, "w_id"),
    "district": TableSchema("district", DISTRICT_SCHEMA,
                            ("d_w_id", "d_id")),
    "customer": TableSchema("customer", CUSTOMER_DB_SCHEMA,
                            ("c_w_id", "c_d_id", "c_id")),
    "item": TableSchema("item", ITEM_SCHEMA, "i_id"),
    "stock": TableSchema("stock", STOCK_DB_SCHEMA, ("s_w_id", "s_i_id")),
    "orders": TableSchema("orders", ORDERS_SCHEMA,
                          ("o_w_id", "o_d_id", "o_id")),
    "order_line": TableSchema("order_line", ORDER_LINE_SCHEMA,
                              ("ol_w_id", "ol_d_id", "ol_o_id",
                               "ol_number")),
})

ENTRY_DAY0 = 19800  # epoch day of the first order (~mid-2024)


def _address(rng) -> Dict[str, str]:
    st = _STATES[int(rng.zipf(1.5)) % len(_STATES)]
    city = _CITIES[st][int(rng.integers(0, len(_CITIES[st])))]
    return {
        "street": f"{int(rng.integers(1, 999))} "
                  f"{_STREET_NAME[int(rng.zipf(1.4)) % len(_STREET_NAME)]} "
                  f"{_STREET_KIND[int(rng.integers(0, len(_STREET_KIND)))]}",
        "state": st, "city": city, "zip": _zip_for(rng, st, city),
    }


def _dist_info(rng) -> str:
    return (f"dist-str#{rng.integers(0, 99):02d}#"
            f"{rng.integers(0, 99):02d}#{rng.integers(0, 9999):04d}")


def item_row(rng, i: int) -> Dict:
    name = (f"{_ITEM_ADJ[int(rng.zipf(1.3)) % len(_ITEM_ADJ)]} "
            f"{_ITEM_MAT[int(rng.integers(0, len(_ITEM_MAT)))]} "
            f"{_ITEM_NOUN[int(rng.zipf(1.3)) % len(_ITEM_NOUN)]}")
    data = (f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} sku "
            f"{int(rng.integers(1000, 9999))}")
    if rng.random() < 0.1:  # TPC-C: ~10% of items carry ORIGINAL
        data += " ORIGINAL"
    return {
        "i_id": i,
        "i_im_id": int(rng.integers(1, 10000)),
        "i_name": name,
        "i_price": float(np.round(rng.uniform(1.0, 100.0), 2)),
        "i_data": data,
    }


def stock_db_row(rng, w: int, i: int) -> Dict:
    return {"s_w_id": w, "s_i_id": i,
            "s_quantity": int(rng.integers(10, 100)),
            "s_ytd": int(rng.poisson(50)),
            "s_order_cnt": int(rng.poisson(20)),
            "s_remote_cnt": int(rng.poisson(2)),
            "s_dist_01": _dist_info(rng),
            "s_dist_02": _dist_info(rng),
            "s_data": f"{_CORP[int(rng.zipf(1.3)) % len(_CORP)]} item grade "
                      f"{chr(65 + int(rng.integers(0, 6)))}"}


def customer_db_row(rng, w: int, d: int, c: int) -> Dict:
    row = customer_row(rng, c)
    return {"c_w_id": w, "c_d_id": d, **row}


def order_rows(
    rng,
    w: int,
    d: int,
    o_id: int,
    c_id: int,
    n_items: int,
    item_ids,
    entry_d: int,
    delivered: bool,
) -> Tuple[Dict, List[Dict]]:
    """One order + its order lines (shared by the loader and NewOrder)."""
    ol_cnt = int(rng.integers(5, 16))
    order = {
        "o_w_id": w,
        "o_d_id": d,
        "o_id": o_id,
        "o_c_id": c_id,
        "o_entry_d": entry_d,
        "o_carrier_id": int(rng.integers(1, 11)) if delivered else 0,
        "o_ol_cnt": ol_cnt,
        "o_all_local": 1,
    }
    lines = []
    for ln in range(1, ol_cnt + 1):
        i_id = item_ids[int(rng.zipf(1.2)) % n_items]
        qty = int(rng.integers(1, 11))
        lines.append({
            "ol_w_id": w, "ol_d_id": d, "ol_o_id": o_id, "ol_number": ln,
            "ol_i_id": i_id, "ol_supply_w_id": w,
            "ol_delivery_d": entry_d if delivered else 0,
            "ol_quantity": qty,
            "ol_amount": float(np.round(qty * rng.uniform(1.0, 100.0), 2)),
            "ol_dist_info": _dist_info(rng)})
    return order, lines


def generate_tpcc(
    n_warehouses: int = 2,
    districts_per_wh: int = 4,
    customers_per_district: int = 60,
    n_items: int = 200,
    orders_per_district: int = 30,
    seed: int = 0,
) -> Dict[str, List[Dict]]:
    """Generate a scaled-down TPC-C population, one row list per table.

    Structure matches the spec (10 districts/warehouse, 3k customers/
    district, 100k items at full scale) with every count dialed down but
    proportionate; ``d_next_o_id`` points one past the last loaded order
    so :func:`run_tpcc_mix` can mint fresh order ids.
    """
    rng = np.random.default_rng(seed)
    item_ids = list(range(1, n_items + 1))
    pop: Dict[str, List[Dict]] = {n: [] for n in TPCC_TABLES}
    pop["item"] = [item_row(rng, i) for i in item_ids]
    for w in range(1, n_warehouses + 1):
        addr = _address(rng)
        pop["warehouse"].append({
            "w_id": w, "w_name": f"WH-{w:03d}",
            "w_street": addr["street"], "w_state": addr["state"],
            "w_city": addr["city"], "w_zip": addr["zip"],
            "w_tax": float(np.round(rng.uniform(0.0, 0.2), 4)),
            "w_ytd": 300000.0})
        pop["stock"].extend(stock_db_row(rng, w, i) for i in item_ids)
        for d in range(1, districts_per_wh + 1):
            addr = _address(rng)
            pop["district"].append({
                "d_w_id": w, "d_id": d, "d_name": f"DIST-{d:02d}",
                "d_street": addr["street"], "d_state": addr["state"],
                "d_city": addr["city"], "d_zip": addr["zip"],
                "d_tax": float(np.round(rng.uniform(0.0, 0.2), 4)),
                "d_ytd": 30000.0,
                "d_next_o_id": orders_per_district + 1})
            pop["customer"].extend(
                customer_db_row(rng, w, d, c)
                for c in range(1, customers_per_district + 1))
            # like the spec's NEW-ORDER table: the most recent ~30% of
            # loaded orders are still undelivered (carrier/delivery_d = 0),
            # so Delivery has work and 0 is in the fitted value sets
            first_new = orders_per_district - orders_per_district // 3 + 1
            for o_id in range(1, orders_per_district + 1):
                c_id = int(rng.integers(1, customers_per_district + 1))
                order, lines = order_rows(
                    rng,
                    w,
                    d,
                    o_id,
                    c_id,
                    n_items,
                    item_ids,
                    ENTRY_DAY0 + int(rng.integers(0, 60)),
                    delivered=o_id < first_new,
                )
                pop["orders"].append(order)
                pop["order_line"].extend(lines)
    return pop


def build_tpcc_database(
    backend: str = "blitzcrank",
    n_shards: int = 1,
    population: Optional[Dict[str, List[Dict]]] = None,
    store_kwargs: Optional[Dict[str, Any]] = None,
    per_table_kwargs: Optional[Dict[str, Dict]] = None,
    **gen_kwargs
):
    """Build a loaded multi-table TPC-C :class:`~repro.db.Database`.

    Every table is created with the generated population as its model-fit
    sample, then bulk-loaded through ``insert_many`` — the §6 load phase.
    Returns ``(db, population)``; pass ``population`` back in to load the
    same rows into another backend for store-vs-store comparisons.
    """
    from repro.db.database import Database  # deferred: avoids import cycle
    if population is None:
        population = generate_tpcc(**gen_kwargs)
    db = Database(backend=backend, n_shards=n_shards, store_kwargs=store_kwargs)
    for name, schema in TPCC_TABLES.items():
        rows = population[name]
        kwargs = (per_table_kwargs or {}).get(name, {})
        table = db.create_table(schema, sample_rows=rows, **kwargs)
        table.insert_many(rows)
    return db, population


def run_tpcc_mix(
    db,
    n_ops: int,
    *,
    seed: int = 0,
    batch: int = 32,
    p_new_order: float = 0.45,
    p_payment: float = 0.43,
    p_order_status: float = 0.08,
    p_delivery: float = 0.04,
    entry_day: int = ENTRY_DAY0 + 60,
    sample_every: int = 0,
    on_sample=None,
) -> Dict[str, int]:
    """Drive the cross-table TPC-C mix through a loaded Database.

    Transaction shapes (default weights are the spec's §5.2.3 mix, with
    StockLevel's 4% folded into OrderStatus since both are read-only):

    * *NewOrder* (45%) — RMW ``district`` (mint ``o_id`` from
      ``d_next_o_id``), batched ``item.get_many`` for prices, batched RMW
      on ``stock`` (quantity/ytd/order_cnt), one ``orders.insert_many``
      and one ``order_line.insert_many`` for all lines in the batch;
    * *Payment* (43%) — RMW ``warehouse.w_ytd``, ``district.d_ytd`` and a
      Zipfian customer's ``c_balance``;
    * *OrderStatus* (8%) — read a customer, a recent order and all its
      order lines (pure ``get_many`` traffic);
    * *Delivery* (4%) — oldest undelivered order per district: set
      ``o_carrier_id``, stamp ``ol_delivery_d`` on its lines, credit the
      customer's balance.

    Cross-transaction coalescing (group-commit idiom, DESIGN.md §11):
    each tick draws ``batch`` i.i.d. transaction types, partitions the
    window by type, and runs each group as ONE batched helper call — so
    the rows per ``get_many``/``update_many`` grow with the window while
    the type mix and the seeded key streams stay exactly the i.i.d.
    workload.  Table verbs replay prepared plans underneath
    (``Table.prepare(verb).run``), keeping the compiled decode path hot.
    The schedule depends only on ``seed``, never on backend timing, so
    every backend replays an identical workload.  Returns op counts;
    ``on_sample(ops_done)`` fires every ``sample_every`` ops.
    """
    rng = np.random.default_rng(seed)
    ses = db.session()  # prepared-handle surface (DESIGN.md §11)
    warehouse, district = ses.table("warehouse"), ses.table("district")
    customer, item = ses.table("customer"), ses.table("item")
    stock = ses.table("stock")
    orders, order_line = ses.table("orders"), ses.table("order_line")

    dist_keys = [k for k, _ in district.scan()]
    item_ids = sorted(k for k, _ in item.scan())
    n_items = len(item_ids)
    # per-district order-id state, read once from the loaded rows and then
    # written through on every NewOrder — the db rows stay authoritative
    next_o_id: Dict[Tuple[int, int], int] = {}
    for k, row in zip(dist_keys, district.get_many(dist_keys)):
        next_o_id[k] = int(row["d_next_o_id"])
    # Delivery starts at each district's oldest undelivered loaded order
    first_undelivered = dict(next_o_id)
    for _, orow in orders.scan():
        if orow["o_carrier_id"] == 0:
            wd = (orow["o_w_id"], orow["o_d_id"])
            first_undelivered[wd] = min(first_undelivered[wd], orow["o_id"])
    cust_per_district = len(customer) // max(1, len(dist_keys))

    def zipf_customer(wd: Tuple[int, int]) -> Tuple[int, int, int]:
        c = 1 + int(rng.zipf(1.1) - 1) % cust_per_district
        return (wd[0], wd[1], c)

    counts = {
        "ops": 0,
        "new_orders": 0,
        "payments": 0,
        "order_status": 0,
        "deliveries": 0,
        "order_lines": 0,
        "aborts": 0,
    }
    next_sample = sample_every
    thresholds = np.cumsum([p_new_order, p_payment, p_order_status, p_delivery])
    while counts["ops"] < n_ops:
        k = min(batch, n_ops - counts["ops"])
        # Coalesce: k i.i.d. type draws for this window, partitioned into
        # one batched helper call per type present.  side="right" keeps
        # the old `u < threshold` boundary semantics.
        u = rng.random(k)
        types = np.searchsorted(thresholds, u, side="right")
        # probability mass past the four weights (zero at the default
        # weights, which sum to 1): read-only OrderStatus traffic
        types[types > 3] = 2
        sizes = np.bincount(types, minlength=4)
        if sizes[0]:
            _tpcc_new_order(
                rng,
                int(sizes[0]),
                dist_keys,
                next_o_id,
                district,
                customer,
                item,
                stock,
                orders,
                order_line,
                item_ids,
                n_items,
                cust_per_district,
                entry_day,
                counts,
            )
        if sizes[1]:
            _tpcc_payment(
                rng,
                int(sizes[1]),
                dist_keys,
                warehouse,
                district,
                customer,
                zipf_customer,
                counts,
            )
        if sizes[2]:
            _tpcc_order_status(
                rng,
                int(sizes[2]),
                dist_keys,
                next_o_id,
                customer,
                orders,
                order_line,
                zipf_customer,
                counts,
            )
        if sizes[3]:
            _tpcc_delivery(
                rng,
                int(sizes[3]),
                dist_keys,
                next_o_id,
                first_undelivered,
                orders,
                order_line,
                customer,
                entry_day,
                counts,
            )
        counts["ops"] += k
        if sample_every and on_sample is not None and counts["ops"] >= next_sample:
            on_sample(counts["ops"])
            next_sample += sample_every
    return counts


def _tpcc_new_order(
    rng,
    k,
    dist_keys,
    next_o_id,
    district,
    customer,
    item,
    stock,
    orders,
    order_line,
    item_ids,
    n_items,
    cust_per_district,
    entry_day,
    counts,
) -> None:
    """k NewOrder transactions batched: one get_many/update_many/insert_many
    per touched table."""
    picks = [dist_keys[int(rng.integers(0, len(dist_keys)))] for _ in range(k)]
    new_orders: List[Dict] = []
    new_lines: List[Dict] = []
    dist_rows = {
        wd: r for wd, r in zip(picks, district.get_many(picks)) if r is not None
    }
    for wd in picks:
        drow = dist_rows.get(wd)
        if drow is None:  # pragma: no cover - districts are never deleted
            counts["aborts"] += 1
            continue
        o_id = next_o_id[wd]
        next_o_id[wd] = o_id + 1
        drow["d_next_o_id"] = o_id + 1
        c_id = 1 + int(rng.zipf(1.1) - 1) % cust_per_district
        order, lines = order_rows(
            rng, wd[0], wd[1], o_id, c_id, n_items, item_ids, entry_day, delivered=False
        )
        new_orders.append(order)
        new_lines.extend(lines)
    district.update_many(list(dist_rows), list(dist_rows.values()))
    # price lookups: one batched read over every line's item
    line_item_keys = [ln["ol_i_id"] for ln in new_lines]
    got_items = item.get_many(line_item_keys)
    # stock RMW: dedup keys so two lines on the same (w, i) both apply
    stock_keys = [(ln["ol_supply_w_id"], ln["ol_i_id"])
                  for ln in new_lines]
    srows = {
        kk: r for kk, r in zip(stock_keys, stock.get_many(stock_keys)) if r is not None
    }
    for ln, irow in zip(new_lines, got_items):
        if irow is not None:  # amount = qty * live item price
            ln["ol_amount"] = float(np.round(ln["ol_quantity"] * irow["i_price"], 2))
        srow = srows.get((ln["ol_supply_w_id"], ln["ol_i_id"]))
        if srow is None:
            continue
        q = srow["s_quantity"] - ln["ol_quantity"]
        srow["s_quantity"] = q if q >= 10 else q + 91
        srow["s_ytd"] += ln["ol_quantity"]
        srow["s_order_cnt"] += 1
    stock.update_many(list(srows), list(srows.values()))
    orders.insert_many(new_orders)
    order_line.insert_many(new_lines)
    counts["new_orders"] += len(new_orders)
    counts["order_lines"] += len(new_lines)


def _tpcc_payment(
    rng, k, dist_keys, warehouse, district, customer, zipf_customer, counts
) -> None:
    """k Payments batched: RMW across warehouse, district and customer."""
    picks = [dist_keys[int(rng.integers(0, len(dist_keys)))] for _ in range(k)]
    amounts: Dict[Tuple[int, int], float] = {}
    cust_updates: Dict[Tuple[int, int, int], float] = {}
    pick_cks: List[Tuple[int, int, int]] = []
    for wd in picks:
        amt = float(np.round(rng.uniform(1.0, 5000.0), 2))
        amounts[wd] = amounts.get(wd, 0.0) + amt
        ck = zipf_customer(wd)
        pick_cks.append(ck)
        cust_updates[ck] = cust_updates.get(ck, 0.0) + amt
    w_ids = sorted({wd[0] for wd in amounts})
    w_rows = {w: r for w, r in zip(w_ids, warehouse.get_many(w_ids))}
    for wd, amt in amounts.items():
        w_rows[wd[0]]["w_ytd"] = round(w_rows[wd[0]]["w_ytd"] + amt, 2)
    warehouse.update_many(list(w_rows), list(w_rows.values()))
    d_rows = {wd: r for wd, r in zip(list(amounts), district.get_many(list(amounts)))}
    for wd, amt in amounts.items():
        d_rows[wd]["d_ytd"] = round(d_rows[wd]["d_ytd"] + amt, 2)
    district.update_many(list(d_rows), list(d_rows.values()))
    cks = list(cust_updates)
    c_rows = customer.get_many(cks)
    upd_k, upd_r = [], []
    aborted: set = set()
    for ck, crow in zip(cks, c_rows):
        if crow is None:
            aborted.add(ck)
            continue
        crow["c_balance"] = round(float(crow["c_balance"]) - cust_updates[ck], 2)
        upd_k.append(ck)
        upd_r.append(crow)
    customer.update_many(upd_k, upd_r)
    # one payment transaction per pick, not per deduplicated customer row
    counts["aborts"] += sum(ck in aborted for ck in pick_cks)
    counts["payments"] += sum(ck not in aborted for ck in pick_cks)


def _tpcc_order_status(
    rng, k, dist_keys, next_o_id, customer, orders, order_line, zipf_customer, counts
) -> None:
    """k OrderStatus transactions: customer + recent order + its lines."""
    picks = [dist_keys[int(rng.integers(0, len(dist_keys)))] for _ in range(k)]
    customer.get_many([zipf_customer(wd) for wd in picks])
    o_keys = []
    for wd in picks:
        hi = next_o_id[wd]
        lo = max(1, hi - 20)  # a recent order of this district
        o_keys.append((wd[0], wd[1], int(rng.integers(lo, hi))))
    got = orders.get_many(o_keys)
    line_keys = []
    for ok, orow in zip(o_keys, got):
        if orow is None:
            counts["aborts"] += 1
            continue
        line_keys.extend(
            (ok[0], ok[1], ok[2], ln) for ln in range(1, orow["o_ol_cnt"] + 1)
        )
    if line_keys:
        order_line.get_many(line_keys)
    counts["order_status"] += len(o_keys)


def _tpcc_delivery(
    rng,
    k,
    dist_keys,
    next_o_id,
    first_undelivered,
    orders,
    order_line,
    customer,
    entry_day,
    counts,
) -> None:
    """k Delivery transactions: oldest undelivered order per district."""
    o_keys = []
    for _ in range(k):
        wd = dist_keys[int(rng.integers(0, len(dist_keys)))]
        o_id = first_undelivered[wd]
        if o_id >= next_o_id[wd]:  # nothing undelivered in this district
            counts["aborts"] += 1
            continue
        first_undelivered[wd] = o_id + 1
        o_keys.append((wd[0], wd[1], o_id))
    if not o_keys:
        return
    o_rows = {ok: r for ok, r in zip(o_keys, orders.get_many(o_keys)) if r is not None}
    carrier = int(rng.integers(1, 11))
    line_keys: List[Tuple[int, int, int, int]] = []
    cust_credit: Dict[Tuple[int, int, int], float] = {}
    for ok, orow in o_rows.items():
        orow["o_carrier_id"] = carrier
        line_keys.extend(
            (ok[0], ok[1], ok[2], ln) for ln in range(1, orow["o_ol_cnt"] + 1)
        )
    orders.update_many(list(o_rows), list(o_rows.values()))
    l_rows = {lk: r for lk, r in
              zip(line_keys, order_line.get_many(line_keys))
              if r is not None}
    for lk, lrow in l_rows.items():
        lrow["ol_delivery_d"] = entry_day
        ck = (lk[0], lk[1], o_rows[(lk[0], lk[1], lk[2])]["o_c_id"])
        cust_credit[ck] = cust_credit.get(ck, 0.0) + lrow["ol_amount"]
    order_line.update_many(list(l_rows), list(l_rows.values()))
    cks = list(cust_credit)
    upd_k, upd_r = [], []
    for ck, crow in zip(cks, customer.get_many(cks)):
        if crow is None:
            continue
        crow["c_balance"] = round(float(crow["c_balance"]) + cust_credit[ck], 2)
        upd_k.append(ck)
        upd_r.append(crow)
    customer.update_many(upd_k, upd_r)
    counts["deliveries"] += len(o_rows)


def database_row_bytes(db) -> int:
    """Silo-style fixed-width raw bytes of every live row in every table —
    a model-free uncompressed reference (``bench_db_tpcc.py`` reports it
    alongside the factor, which is quoted store-vs-store)."""
    total = 0
    for table in db:
        total += row_bytes([r for _, r in table.scan()])
    return total
