"""In-memory row stores with pluggable compressors (paper §6.1/§7 setting).

Every store implements insert/get over a primary-key index (a plain vector,
as in the paper's microbenchmarks).  Compressors:

* ``BlitzStore``      — TableCodec (semantic models + delayed coding)
* ``ZstdStore``       — per-tuple zstd with a trained dictionary (the
                        paper's Zstandard baseline, §6 "training mode")
* ``RamanStore``      — per-column canonical Huffman, concatenated
                        variable-length tuples (static dictionary: unseen
                        values need an escape; new tuples buffered and
                        re-trained like §7.1 describes)
* ``UncompressedStore`` — Silo-style plain rows

Plus the §6.5 fast path: an LRU write-back cache of decompressed tuples.
"""

from __future__ import annotations

import json
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import ColumnSpec, TableCodec
from repro.core.huffman import BitReader, BitWriter, HuffmanCode


class UncompressedStore:
    name = "silo"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample=None):
        self.schema = list(schema)
        self.rows: List[bytes] = []

    def insert(self, row: Dict[str, Any]) -> int:
        self.rows.append(json.dumps(
            [row[c.name] for c in self.schema]).encode())
        return len(self.rows) - 1

    def get(self, i: int) -> Dict[str, Any]:
        vals = json.loads(self.rows[i])
        return {c.name: v for c, v in zip(self.schema, vals)}

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)


class BlitzStore:
    name = "blitzcrank"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample,
                 correlation: bool = False, block_tuples: int = 1,
                 sample: int = 1 << 15):
        self.codec = TableCodec.fit(rows_sample, schema,
                                    correlation=correlation,
                                    sample=sample, block_tuples=block_tuples)
        self.blocks: List[np.ndarray] = []
        self.block_tuples = block_tuples
        self._pending: List[Dict] = []
        self.n = 0

    def insert(self, row: Dict[str, Any]) -> int:
        self._pending.append(row)
        if len(self._pending) >= self.block_tuples:
            self.blocks.append(self.codec.compress_block(self._pending))
            self._pending = []
        self.n += 1
        return self.n - 1

    def get(self, i: int) -> Dict[str, Any]:
        b, off = divmod(i, self.block_tuples)
        if b >= len(self.blocks):
            return dict(self._pending[off])
        rows = self.codec.decompress_block(self.blocks[b],
                                           min(self.block_tuples,
                                               self.n - b * self.block_tuples))
        return rows[off]

    @property
    def nbytes(self) -> int:
        return sum(2 * b.size for b in self.blocks)

    @property
    def model_bytes(self) -> int:
        return self.codec.model_bytes()


class ZstdStore:
    name = "zstd"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample,
                 dict_kb: int = 110, level: int = 3):
        import zstandard as zstd
        self.schema = list(schema)
        samples = [json.dumps([r[c.name] for c in self.schema]).encode()
                   for r in rows_sample]
        try:
            dict_data = zstd.train_dictionary(dict_kb * 1024, samples)
            self._dict = dict_data
            self.cctx = zstd.ZstdCompressor(level=level, dict_data=dict_data)
            self.dctx = zstd.ZstdDecompressor(dict_data=dict_data)
            self.dict_bytes = len(dict_data.as_bytes())
        except Exception:  # tiny sample sets cannot train a dictionary
            self._dict = None
            self.cctx = zstd.ZstdCompressor(level=level)
            self.dctx = zstd.ZstdDecompressor()
            self.dict_bytes = 0
        self.rows: List[bytes] = []

    def insert(self, row: Dict[str, Any]) -> int:
        raw = json.dumps([row[c.name] for c in self.schema]).encode()
        self.rows.append(self.cctx.compress(raw))
        return len(self.rows) - 1

    def get(self, i: int) -> Dict[str, Any]:
        vals = json.loads(self.dctx.decompress(self.rows[i]))
        return {c.name: v for c, v in zip(self.schema, vals)}

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)

    @property
    def model_bytes(self) -> int:
        return self.dict_bytes


class RamanStore:
    """Per-column Huffman over value ids (static dictionary baseline §6).

    Values unseen at train time go through a length-prefixed byte escape.
    Numeric columns are coded on their value dictionary too (Raman & Swart
    treat fields as symbols); tuples are concatenated variable-length codes.
    """

    name = "raman"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample):
        self.schema = list(schema)
        self.columns = {}
        for c in self.schema:
            vals = [r[c.name] for r in rows_sample]
            uniq: Dict[Any, int] = {}
            counts: List[float] = []
            for v in vals:
                j = uniq.setdefault(v, len(uniq))
                if j == len(counts):
                    counts.append(0.0)
                counts[j] += 1
            # reserve an escape symbol
            uniq["\x00<esc>"] = len(uniq)
            counts.append(max(1.0, 0.01 * len(vals)))
            self.columns[c.name] = (uniq,
                                    list(uniq.keys()),
                                    HuffmanCode(np.asarray(counts)))
        self.rows: List[bytes] = []
        self.lens: List[int] = []

    def insert(self, row: Dict[str, Any]) -> int:
        bw = BitWriter()
        for c in self.schema:
            uniq, _, hc = self.columns[c.name]
            v = row[c.name]
            j = uniq.get(v)
            if j is None:
                hc.encode(uniq["\x00<esc>"], bw)
                payload = json.dumps(v).encode()
                bw.write(len(payload), 16)
                for byte in payload:
                    bw.write(byte, 8)
            else:
                hc.encode(j, bw)
        buf, nbits = bw.getvalue()
        self.rows.append(buf)
        self.lens.append(nbits)
        return len(self.rows) - 1

    def get(self, i: int) -> Dict[str, Any]:
        br = BitReader(self.rows[i])
        out = {}
        for c in self.schema:
            uniq, keys, hc = self.columns[c.name]
            j = hc.decode(br)
            if keys[j] == "\x00<esc>":
                ln = br.peek(16)
                br.skip(16)
                data = bytearray()
                for _ in range(ln):
                    data.append(br.peek(8))
                    br.skip(8)
                out[c.name] = json.loads(bytes(data))
            else:
                out[c.name] = keys[j]
        return out

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)

    @property
    def model_bytes(self) -> int:
        total = 0
        for name, (uniq, keys, hc) in self.columns.items():
            total += sum(len(str(k)) + 10 for k in keys)
        return total


class LRUFastPath:
    """§6.5 write-back cache of decompressed tuples above any store."""

    def __init__(self, store, capacity: int):
        self.store = store
        self.capacity = capacity
        self.cache: OrderedDict[int, Dict] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def read_modify_write(self, i: int, update_fn) -> None:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
        else:
            self.misses += 1
            row = self.store.get(i)
            self.cache[i] = row
            if len(self.cache) > self.capacity:
                self.cache.popitem(last=False)  # write-back: drop (demo)
        update_fn(row)

    def get(self, i: int) -> Dict[str, Any]:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
            return row
        self.misses += 1
        return self.store.get(i)


STORE_KINDS = {
    "silo": UncompressedStore,
    "blitzcrank": BlitzStore,
    "zstd": ZstdStore,
    "raman": RamanStore,
}
