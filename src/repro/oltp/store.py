"""In-memory row stores with pluggable compressors (paper §6.1/§7 setting).

Every store implements insert/get over a primary-key index (a plain vector,
as in the paper's microbenchmarks).  Compressors:

* ``BlitzStore``      — TableCodec (semantic models + delayed coding)
* ``ZstdStore``       — per-tuple zstd with a trained dictionary (the
                        paper's Zstandard baseline, §6 "training mode")
* ``RamanStore``      — per-column canonical Huffman, concatenated
                        variable-length tuples (static dictionary: unseen
                        values need an escape; new tuples buffered and
                        re-trained like §7.1 describes)
* ``UncompressedStore`` — Silo-style plain rows

Plus the §6.5 fast path: an LRU write-back cache of decompressed tuples.
"""

from __future__ import annotations

import json
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import ColumnSpec, TableCodec
from repro.core.blitzcrank import CompressedTable, _raw_row_bytes
from repro.core.huffman import BitReader, BitWriter, HuffmanCode


class UncompressedStore:
    name = "silo"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample=None):
        self.schema = list(schema)
        self.rows: List[bytes] = []

    def insert(self, row: Dict[str, Any]) -> int:
        self.rows.append(json.dumps(
            [row[c.name] for c in self.schema]).encode())
        return len(self.rows) - 1

    def get(self, i: int) -> Dict[str, Any]:
        vals = json.loads(self.rows[i])
        return {c.name: v for c, v in zip(self.schema, vals)}

    def update(self, i: int, row: Dict[str, Any]) -> None:
        self.rows[i] = json.dumps([row[c.name] for c in self.schema]).encode()

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)


class BlitzStore:
    """TableCodec store over the CSR code arena (DESIGN.md §2.5).

    Rows live in a :class:`CompressedTable` — one uint16 arena plus int64
    block offsets — so batched point reads (:meth:`get_many`) decode through
    ``decode_select`` with no per-tuple Python loop whenever the codec
    compiled.  Updates (the §6.5 write-back path) go to an uncompressed
    delta overlay consulted before the arena, as a real delta-store would.
    """

    name = "blitzcrank"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample,
                 correlation: bool = False, block_tuples: int = 1,
                 sample: int = 1 << 15, use_pallas: bool | None = None):
        self.codec = TableCodec.fit(rows_sample, schema,
                                    correlation=correlation,
                                    sample=sample, block_tuples=block_tuples)
        self.table = CompressedTable(self.codec, use_pallas=use_pallas)
        self.block_tuples = block_tuples
        self._updates: Dict[int, Dict] = {}

    @property
    def n(self) -> int:
        return len(self.table)

    def insert(self, row: Dict[str, Any]) -> int:
        self.table.append(row)
        return len(self.table) - 1

    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        base = len(self.table)
        self.table.extend(rows)
        return range(base, len(self.table))

    def get(self, i: int) -> Dict[str, Any]:
        u = self._updates.get(int(i))
        if u is not None:
            return dict(u)
        return self.table.get(i)

    def get_many(self, indices: Sequence[int],
                 backend: str | None = None) -> List[Dict[str, Any]]:
        idxs = [int(i) for i in indices]  # materialize: may be an iterator
        rows = self.table.get_many(idxs, backend=backend)
        if self._updates:
            rows = [dict(self._updates[i]) if i in self._updates else r
                    for i, r in zip(idxs, rows)]
        return rows

    def update(self, i: int, row: Dict[str, Any]) -> None:
        """Write a modified row back (delta overlay over the code arena)."""
        self._updates[int(i)] = dict(row)

    @property
    def nbytes(self) -> int:
        return self.table.nbytes + sum(_raw_row_bytes(r) + 8
                                       for r in self._updates.values())

    @property
    def model_bytes(self) -> int:
        return self.codec.model_bytes()


class ZstdStore:
    name = "zstd"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample,
                 dict_kb: int = 110, level: int = 3):
        import zstandard as zstd
        self.schema = list(schema)
        samples = [json.dumps([r[c.name] for c in self.schema]).encode()
                   for r in rows_sample]
        try:
            dict_data = zstd.train_dictionary(dict_kb * 1024, samples)
            self._dict = dict_data
            self.cctx = zstd.ZstdCompressor(level=level, dict_data=dict_data)
            self.dctx = zstd.ZstdDecompressor(dict_data=dict_data)
            self.dict_bytes = len(dict_data.as_bytes())
        except Exception:  # tiny sample sets cannot train a dictionary
            self._dict = None
            self.cctx = zstd.ZstdCompressor(level=level)
            self.dctx = zstd.ZstdDecompressor()
            self.dict_bytes = 0
        self.rows: List[bytes] = []

    def insert(self, row: Dict[str, Any]) -> int:
        raw = json.dumps([row[c.name] for c in self.schema]).encode()
        self.rows.append(self.cctx.compress(raw))
        return len(self.rows) - 1

    def update(self, i: int, row: Dict[str, Any]) -> None:
        raw = json.dumps([row[c.name] for c in self.schema]).encode()
        self.rows[i] = self.cctx.compress(raw)

    def get(self, i: int) -> Dict[str, Any]:
        vals = json.loads(self.dctx.decompress(self.rows[i]))
        return {c.name: v for c, v in zip(self.schema, vals)}

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)

    @property
    def model_bytes(self) -> int:
        return self.dict_bytes


class RamanStore:
    """Per-column Huffman over value ids (static dictionary baseline §6).

    Values unseen at train time go through a length-prefixed byte escape.
    Numeric columns are coded on their value dictionary too (Raman & Swart
    treat fields as symbols); tuples are concatenated variable-length codes.
    """

    name = "raman"

    def __init__(self, schema: Sequence[ColumnSpec], rows_sample):
        self.schema = list(schema)
        self.columns = {}
        for c in self.schema:
            vals = [r[c.name] for r in rows_sample]
            uniq: Dict[Any, int] = {}
            counts: List[float] = []
            for v in vals:
                j = uniq.setdefault(v, len(uniq))
                if j == len(counts):
                    counts.append(0.0)
                counts[j] += 1
            # reserve an escape symbol
            uniq["\x00<esc>"] = len(uniq)
            counts.append(max(1.0, 0.01 * len(vals)))
            self.columns[c.name] = (uniq,
                                    list(uniq.keys()),
                                    HuffmanCode(np.asarray(counts)))
        self.rows: List[bytes] = []
        self.lens: List[int] = []

    def insert(self, row: Dict[str, Any]) -> int:
        bw = BitWriter()
        for c in self.schema:
            uniq, _, hc = self.columns[c.name]
            v = row[c.name]
            j = uniq.get(v)
            if j is None:
                hc.encode(uniq["\x00<esc>"], bw)
                payload = json.dumps(v).encode()
                bw.write(len(payload), 16)
                for byte in payload:
                    bw.write(byte, 8)
            else:
                hc.encode(j, bw)
        buf, nbits = bw.getvalue()
        self.rows.append(buf)
        self.lens.append(nbits)
        return len(self.rows) - 1

    def update(self, i: int, row: Dict[str, Any]) -> None:
        j = self.insert(row)
        self.rows[i] = self.rows.pop(j)
        self.lens[i] = self.lens.pop(j)

    def get(self, i: int) -> Dict[str, Any]:
        br = BitReader(self.rows[i])
        out = {}
        for c in self.schema:
            uniq, keys, hc = self.columns[c.name]
            j = hc.decode(br)
            if keys[j] == "\x00<esc>":
                ln = br.peek(16)
                br.skip(16)
                data = bytearray()
                for _ in range(ln):
                    data.append(br.peek(8))
                    br.skip(8)
                out[c.name] = json.loads(bytes(data))
            else:
                out[c.name] = keys[j]
        return out

    @property
    def nbytes(self) -> int:
        return sum(len(r) for r in self.rows)

    @property
    def model_bytes(self) -> int:
        total = 0
        for name, (uniq, keys, hc) in self.columns.items():
            total += sum(len(str(k)) + 10 for k in keys)
        return total


class LRUFastPath:
    """§6.5 write-back cache of decompressed tuples above any store.

    Modified rows are marked dirty and written back to the underlying store
    (via its ``update`` method) when they are evicted — and on :meth:`sync`
    — so ``read_modify_write`` never loses data once the cache fills.
    """

    def __init__(self, store, capacity: int):
        self.store = store
        self.capacity = capacity
        self.cache: OrderedDict[int, Dict] = OrderedDict()
        self.dirty: set = set()
        self.hits = 0
        self.misses = 0
        self.writebacks = 0

    def _writeback(self, i: int, row: Dict[str, Any]) -> None:
        self.dirty.discard(i)
        self.writebacks += 1
        if hasattr(self.store, "update"):
            self.store.update(i, row)
        else:  # pragma: no cover - every bundled store supports update
            raise TypeError(
                f"{type(self.store).__name__} cannot accept write-backs")

    def _evict(self) -> None:
        while len(self.cache) > self.capacity:
            i, row = self.cache.popitem(last=False)
            if i in self.dirty:
                self._writeback(i, row)

    def read_modify_write(self, i: int, update_fn) -> None:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
        else:
            self.misses += 1
            row = self.store.get(i)
            self.cache[i] = row
        # Apply the update and mark dirty BEFORE evicting: with a full (or
        # zero-capacity) cache the evicted row may be this one, and the
        # write-back must carry the new value.
        update_fn(row)
        self.dirty.add(i)
        self._evict()

    def get(self, i: int) -> Dict[str, Any]:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
            return row
        self.misses += 1
        return self.store.get(i)

    def sync(self) -> None:
        """Flush all dirty cached rows back to the underlying store."""
        for i in list(self.dirty):
            self._writeback(i, self.cache[i])


STORE_KINDS = {
    "silo": UncompressedStore,
    "blitzcrank": BlitzStore,
    "zstd": ZstdStore,
    "raman": RamanStore,
}
